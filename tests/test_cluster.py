"""Cluster layer (serving/cluster.py): routing-policy determinism,
failover redispatch + accounting reset + resource release, elastic
scale-out remapping, and the fleet-level replay claim — session-affine
routing beats session-blind routing on cross-turn hit rate."""
import numpy as np
import pytest

from repro.config import reduce_config
from repro.configs import get_config
from repro.serving import EngineConfig, SamplingParams
from repro.serving.cluster import (LeastLoadedRouter, PrefixAwareRouter,
                                   ReplicaCluster, RoundRobinRouter,
                                   SessionAffinityRouter, make_router)
from repro.serving.request import Phase


def _cluster(n_replicas=2, routing="affine", shared_tier=False, **ecfg_kw):
    cfg = reduce_config(get_config("llama3.2-1b"))
    ecfg = EngineConfig(max_len=128, kv_budget_bytes=16e6, **ecfg_kw)
    return ReplicaCluster(cfg, ecfg, n_replicas=n_replicas, routing=routing,
                          shared_tier=shared_tier)


# ---------------------------------------------------------------------------
# routing policies
# ---------------------------------------------------------------------------
def test_affinity_deterministic_under_fixed_ring_seed():
    """Same replicas + same ring salt => identical session→replica map
    across router instances; a different salt reshuffles it."""
    keys = [f"s{i}" for i in range(64)]

    def build(salt):
        r = SessionAffinityRouter(salt=salt)
        for n in ("replica0", "replica1", "replica2"):
            r.add_replica(n)
        return r

    a, b = build("seed0"), build("seed0")
    map_a = {k: a.route(k) for k in keys}
    assert map_a == {k: b.route(k) for k in keys}
    # repeated lookups are stable (affinity, not load balancing)
    assert map_a == {k: a.route(k) for k in keys}
    # all replicas get traffic and a different salt moves some sessions
    assert len(set(map_a.values())) == 3
    c = build("seed1")
    assert any(c.route(k) != map_a[k] for k in keys)


def test_round_robin_spreads_and_ignores_sessions():
    r = RoundRobinRouter()
    for n in ("replica0", "replica1"):
        r.add_replica(n)
    # same session key alternates replicas: deliberately session-blind
    routes = [r.route("s0") for _ in range(4)]
    assert routes == ["replica0", "replica1", "replica0", "replica1"]


def test_least_loaded_picks_min(monkeypatch):
    r = LeastLoadedRouter()
    for n in ("replica0", "replica1"):
        r.add_replica(n)
    monkeypatch.setattr(LeastLoadedRouter, "_load",
                        staticmethod(lambda eng: eng))
    assert r.route("k", {"replica0": 3, "replica1": 1}) == "replica1"
    # ties break by name
    assert r.route("k", {"replica0": 2, "replica1": 2}) == "replica0"


def test_make_router_rejects_unknown():
    with pytest.raises(ValueError):
        make_router("random")


class _FakeMgr:
    def __init__(self, depth):
        self.depth = depth

    def peek_prefix_blocks(self, tokens):
        return self.depth


class _FakeSched:
    def __init__(self, load):
        self.load = load

    def live_count(self):
        return self.load


class _FakeEng:
    def __init__(self, depth, load=0):
        self.manager = _FakeMgr(depth)
        self.scheduler = _FakeSched(load)


def test_prefix_router_routes_to_longest_match():
    r = PrefixAwareRouter()
    for n in ("replica0", "replica1"):
        r.add_replica(n)
    engines = {"replica0": _FakeEng(depth=1), "replica1": _FakeEng(depth=3)}
    assert r.route("s0", engines, tokens=[1, 2, 3]) == "replica1"
    # ties break by name
    engines = {"replica0": _FakeEng(depth=2), "replica1": _FakeEng(depth=2)}
    assert r.route("s0", engines, tokens=[1, 2, 3]) == "replica0"


def test_prefix_router_falls_back_to_least_loaded():
    r = PrefixAwareRouter()
    for n in ("replica0", "replica1"):
        r.add_replica(n)
    engines = {"replica0": _FakeEng(depth=0, load=5),
               "replica1": _FakeEng(depth=0, load=2)}
    # no prefix anywhere -> least loaded; same without tokens
    assert r.route("s0", engines, tokens=[1, 2, 3]) == "replica1"
    assert r.route("s0", engines) == "replica1"


# ---------------------------------------------------------------------------
# elastic scale-out
# ---------------------------------------------------------------------------
def test_add_replica_remaps_about_one_over_n():
    """Consistent hashing: a 5th replica takes ~1/5 of the session
    space; everything else stays put (no full reshuffle)."""
    r = SessionAffinityRouter()
    for i in range(4):
        r.add_replica(f"replica{i}")
    keys = [f"s{i}" for i in range(400)]
    before = {k: r.route(k) for k in keys}
    r.add_replica("replica4")
    after = {k: r.route(k) for k in keys}
    moved = [k for k in keys if before[k] != after[k]]
    # every moved key moved TO the new replica (nothing reshuffles
    # between surviving replicas)
    assert all(after[k] == "replica4" for k in moved)
    assert 0.05 <= len(moved) / len(keys) <= 0.45   # ~1/5 expected


# ---------------------------------------------------------------------------
# failover
# ---------------------------------------------------------------------------
def test_failover_redispatches_each_request_once_with_reset():
    cluster = _cluster(n_replicas=2)
    rng = np.random.default_rng(0)
    reqs = [cluster.submit([int(t) for t in rng.integers(0, 250, size=48)],
                           session_id=f"s{i}",
                           params=SamplingParams(max_new_tokens=3))
            for i in range(6)]
    cluster.step()                     # some requests are mid-generation
    victim = sorted(cluster.engines)[0]
    victim_eng = cluster.engines[victim]
    lost = ([r.request_id for r in victim_eng.scheduler.waiting]
            + list(victim_eng.scheduler.running)
            + [r.request_id for r in victim_eng.scheduler.preempted]
            + list(victim_eng.scheduler.blocked))
    n_lost = cluster.fail_replica(victim)
    assert n_lost == len(lost) and n_lost > 0
    assert cluster.redispatched == n_lost
    # each lost request redispatched exactly once, with generation
    # restarted and dead-engine accounting wiped
    redispatched_ids = [rid for rid, _f, _t in cluster.redispatch_log]
    assert sorted(redispatched_ids) == sorted(lost)
    survivor = cluster.engines[sorted(cluster.engines)[0]]
    queued = [r.request_id for r in survivor.scheduler.waiting]
    for rid in lost:
        assert queued.count(rid) == 1
    by_id = {r.request_id: r for r in reqs}
    for rid in lost:
        req = by_id[rid]
        assert req.phase is Phase.WAITING
        assert req.generated == [] and req.slot == -1
        assert req.block_ids == []
        assert req.prefix_hit_blocks == 0 and req.hot_hit_blocks == 0
        assert req.prefill_tokens is None and req.prefill_pos == 0
        assert req.t_first_token is None
    # the dead replica's manager/tier registrations are released, not
    # leaked; its ManagerStats survive for fleet aggregation
    assert victim_eng.manager.metas == {}
    assert victim_eng.manager._payloads == {}
    assert all(t.used == 0 for t in victim_eng.manager.hierarchy.tiers)
    assert victim_eng.worker is None
    assert victim in cluster.manager_stats()
    # the fleet completes every request on the survivor
    stats = cluster.run()
    assert stats["done"] == 6
    assert all(len(r.generated) == 3 for r in reqs)
    assert stats["redispatched"] == n_lost
    assert stats["reprefill_tokens"] > 0
    cluster.shutdown()


def test_fail_last_replica_refused_without_damage():
    cluster = _cluster(n_replicas=1)
    with pytest.raises(RuntimeError):
        cluster.fail_replica(sorted(cluster.engines)[0])
    # the refusal must not have mutated anything: the cluster still
    # routes and serves
    assert cluster.n_replicas == 1
    req = cluster.submit([1, 2, 3, 4], session_id="s0",
                         params=SamplingParams(max_new_tokens=1))
    cluster.run()
    assert len(req.generated) == 1
    cluster.shutdown()


def test_failed_replica_name_stays_reserved():
    cluster = _cluster(n_replicas=2)
    victim = sorted(cluster.engines)[0]
    cluster.fail_replica(victim)
    # reusing the dead name would collide the stats rollups
    with pytest.raises(ValueError):
        cluster.add_replica(victim)
    fresh = cluster.add_replica()
    assert fresh not in (victim,)
    assert victim in cluster.manager_stats()
    assert victim not in cluster.manager_stats(include_failed=False)
    cluster.shutdown()


def test_fleet_manager_stats_sum_replicas():
    cluster = _cluster(n_replicas=2, routing="round_robin")
    rng = np.random.default_rng(1)
    prompt = [int(t) for t in rng.integers(0, 250, size=40)]
    for i in range(4):
        cluster.submit(list(prompt), session_id=f"s{i}",
                       params=SamplingParams(max_new_tokens=2))
    cluster.run()
    per = cluster.manager_stats()
    fleet = cluster.fleet_manager_stats()
    assert fleet.accesses == sum(m.accesses for m in per.values())
    assert fleet.hot_hits == sum(m.hot_hits for m in per.values())
    assert fleet.hot_hits_t0 + fleet.hot_hits_t1 == fleet.hot_hits
    stats = cluster.stats()
    assert stats["done"] == 4
    assert stats["fleet"]["accesses"] == fleet.accesses
    cluster.shutdown()


# ---------------------------------------------------------------------------
# fleet-shared tier 4
# ---------------------------------------------------------------------------
def _shared_cluster(n_replicas=2, routing="round_robin"):
    """Shared-tier cluster with trace-scale (16-token) KV blocks, so the
    short test prompts span several full, publishable blocks."""
    import dataclasses
    cfg = dataclasses.replace(reduce_config(get_config("llama3.2-1b")),
                              kv_block_tokens=16)
    ecfg = EngineConfig(max_len=128, kv_budget_bytes=16e6, page_tokens=16)
    return ReplicaCluster(cfg, ecfg, n_replicas=n_replicas, routing=routing,
                          shared_tier=True)


def test_shared_tier_cross_replica_import():
    """With the fleet-shared tier on, a prompt one replica already
    served is imported by the other replica as tier-4 fetches instead
    of a full re-prefill."""
    cluster = _shared_cluster()
    assert cluster.fleet_store is not None
    rng = np.random.default_rng(2)
    prompt = [int(t) for t in rng.integers(0, 250, size=64)]
    # round-robin: sessions alternate replicas, same prompt content
    ra = cluster.submit(list(prompt), session_id="sA",
                        params=SamplingParams(max_new_tokens=1))
    cluster.run()
    rb = cluster.submit(list(prompt), session_id="sB",
                        params=SamplingParams(max_new_tokens=1))
    cluster.run()
    assert ra.shared_hit_blocks == 0            # first writer publishes
    assert rb.shared_hit_blocks > 0             # second replica imports
    st = cluster.fleet_store.stats()
    assert st["fetches"] >= rb.shared_hit_blocks
    assert st["dedup_publishes"] > 0            # content interned once
    fleet = cluster.fleet_manager_stats()
    assert fleet.shared_tier_hits == rb.shared_hit_blocks
    assert fleet.shared_publishes > 0
    cluster.shutdown()


def test_failover_with_shared_tier_keeps_survivor_blocks():
    """A failed replica's teardown releases only its own fleet refs:
    the survivor's published blocks stay resident and fetchable."""
    cluster = _shared_cluster()
    rng = np.random.default_rng(3)
    for i in range(4):
        cluster.submit([int(t) for t in rng.integers(0, 250, size=48)],
                       session_id=f"s{i}",
                       params=SamplingParams(max_new_tokens=1))
    cluster.run()
    store = cluster.fleet_store
    live_before = store.stats()["live_refs"]
    assert live_before > 0
    victim = sorted(cluster.engines)[0]
    cluster.fail_replica(victim)
    st = store.stats()
    # refs dropped (the victim's), but no key another replica still
    # references was reclaimed and the survivor still serves
    assert 0 < st["live_refs"] < live_before
    survivor = next(iter(cluster.engines.values()))
    view = survivor.manager._fleet_view
    for bid, key in view._map.items():
        assert store.contains_key(key)
        assert store.ref_count(key) >= 1
    cluster.run()
    cluster.shutdown()


# ---------------------------------------------------------------------------
# the fleet-level replay claim (paper: affinity keeps prefix caches warm)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_affine_beats_round_robin_on_lmsys():
    """Session-affine routing must measurably beat round-robin on the
    LMSYS trace at 2 replicas: round-robin alternates a session's turns
    across replica-private caches, so cross-turn prefix reuse
    fragments."""
    from repro.traces.serving_replay import (ClusterReplayConfig,
                                             run_cluster_replay)
    kw = dict(workload="lmsys", policy="bayesian", n_sessions=8,
              max_turns=4, n_replicas=2)
    aff = run_cluster_replay(ClusterReplayConfig(routing="affine", **kw))
    rr = run_cluster_replay(ClusterReplayConfig(routing="round_robin", **kw))
    assert aff.seen_blocks == rr.seen_blocks       # same trace ground truth
    assert aff.fleet_hit_rate >= rr.fleet_hit_rate + 0.05
    assert aff.redispatched == rr.redispatched == 0


@pytest.mark.slow
def test_shared_tier_recovers_fragmented_hit_points_on_lmsys():
    """The fleet-shared tier must recover a measurable share of the hit
    points 2-way replica-private fragmentation loses: at the benchmark
    scale the private n=2 affine fleet hit is ~78.8%; counting shared
    tier-4 imports (a fabric fetch instead of a re-prefill) the shared
    run must clear 82% — at least 3 points over its own private hot
    rate."""
    from repro.traces.serving_replay import (ClusterReplayConfig,
                                             run_cluster_replay)
    kw = dict(workload="lmsys", policy="bayesian", n_sessions=12,
              max_turns=6, n_replicas=2, routing="affine")
    shared = run_cluster_replay(ClusterReplayConfig(shared_tier=True, **kw))
    assert shared.shared_hit_blocks > 0
    # the hot-hit rate is unchanged by sharing (same routing, same
    # private tiers 0-1) — the win is imports counted on top of it
    assert shared.fleet_hit_rate_incl_shared >= 0.82
    assert shared.fleet_hit_rate_incl_shared >= \
        shared.fleet_hit_rate + 0.03
    # every import was priced: tier-4 demand fetches on the managers
    fetched = sum(p.shared_hit_blocks for p in shared.per_replica)
    assert fetched == shared.shared_hit_blocks


def test_add_replica_warmup_removes_postjoin_ttft_spike():
    """Scale-out warm-up: sessions remapped to the joiner get their
    prefix blocks pushed before it takes traffic, so the joiner's
    post-join TTFT p95 stays within the steady-state envelope (the
    acceptance bound is 1.2x)."""
    from repro.traces.serving_replay import (ClusterReplayConfig,
                                             run_cluster_replay)
    r = run_cluster_replay(ClusterReplayConfig(
        workload="lmsys", policy="bayesian", n_sessions=6, max_turns=4,
        n_replicas=2, routing="affine", shared_tier=True,
        add_replica_after_turns=8, warmup_on_add=True))
    assert r.joined_replica                        # the join happened
    assert r.warmed_sessions > 0 and r.warmed_blocks > 0
    assert r.postjoin_ttft_p95 > 0                 # the joiner served turns
    assert r.postjoin_ttft_p95 <= 1.2 * r.steady_ttft_p95
