"""Property tests (hypothesis) for the content-segment index
(core/dedup.py SegmentIndex): inserted content is always re-findable,
matches are disjoint and in prompt order, a single-block mutation loses
at most its containing segment, and index contents are a pure function
of the inserted pairs (insertion-order invariant under a fixed salt).

Skips cleanly when hypothesis isn't installed (same guard as
test_loadgen.py).
"""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.dedup import SegmentIndex

BT = 4                                   # small blocks -> fast digests

tokens_st = st.lists(st.integers(0, 7), min_size=BT, max_size=BT * 12)


def _full(tokens):
    """Token list truncated to whole blocks."""
    return tokens[:(len(tokens) // BT) * BT]


def _insert_all(idx, tokens, prefix="b"):
    blocks = [tokens[i:i + BT] for i in range(0, len(_full(tokens)), BT)]
    idx.insert_sequence(tokens, [f"{prefix}{i}" for i in range(len(blocks))])
    return blocks


@settings(max_examples=80, deadline=None)
@given(tokens=tokens_st)
def test_inserted_always_refindable(tokens):
    """Every inserted full block matches when queried back: the match
    over the very tokens just inserted is one segment covering all of
    them from block 0."""
    idx = SegmentIndex(BT)
    blocks = _insert_all(idx, tokens)
    matches = idx.match(tokens)
    assert len(matches) == 1
    assert matches[0].start_block == 0
    assert matches[0].n_blocks == len(blocks)
    # and each individual block re-finds via its digest
    for blk in blocks:
        assert idx.lookup(idx.block_digest(blk)) is not None


@settings(max_examples=80, deadline=None)
@given(inserted=tokens_st, query=tokens_st)
def test_matches_disjoint_and_ordered(inserted, query):
    """Matches never overlap and never reorder: segment spans are
    strictly ascending and disjoint in block index, each at least
    min_blocks long, and every reported block id is really registered
    for that query block's digest."""
    idx = SegmentIndex(BT, min_blocks=1)
    _insert_all(idx, inserted)
    matches = idx.match(query)
    prev_end = -1
    qblocks = [query[i:i + BT] for i in range(0, len(_full(query)), BT)]
    for m in matches:
        assert m.start_block > prev_end          # disjoint, in order
        assert m.n_blocks >= idx.min_blocks
        assert m.end_block <= len(qblocks)
        for j, bid in enumerate(m.block_ids):
            d = idx.block_digest(qblocks[m.start_block + j])
            assert idx.lookup(d) == bid
        prev_end = m.end_block - 1
    # blocks outside every segment genuinely miss
    covered = {i for m in matches for i in range(m.start_block, m.end_block)}
    for i, blk in enumerate(qblocks):
        if i not in covered:
            assert idx.lookup(idx.block_digest(blk)) is None


@settings(max_examples=60, deadline=None)
@given(tokens=st.lists(st.integers(0, 7), min_size=BT * 3,
                       max_size=BT * 10),
       data=st.data())
def test_single_block_mutation_local_loss(tokens, data):
    """Flipping one token mid-prompt loses at most the containing
    block: every other full block still matches, so the mutated query
    yields segments covering exactly the unmutated blocks."""
    idx = SegmentIndex(BT)
    blocks = _insert_all(idx, tokens)
    victim = data.draw(st.integers(0, len(blocks) - 1), label="victim")
    off = data.draw(st.integers(0, BT - 1), label="offset")
    pos = victim * BT + off
    mutated = list(tokens)
    mutated[pos] = (mutated[pos] + 1) % 8
    matches = idx.match(mutated)
    covered = {i for m in matches for i in range(m.start_block, m.end_block)}
    # the victim block may or may not still hit (its mutated content can
    # collide with another inserted block) but no *other* block is lost
    assert covered >= set(range(len(blocks))) - {victim}
    assert covered <= set(range(len(blocks)))


@settings(max_examples=60, deadline=None)
@given(pairs=st.lists(
    st.tuples(st.lists(st.integers(0, 7), min_size=BT, max_size=BT),
              st.integers(0, 9)),
    min_size=1, max_size=12))
def test_insertion_order_invariance(pairs):
    """Index contents are a pure function of the inserted (block, id)
    pairs: inserting in reverse order yields identical lookups, sizes
    and canonical ids under a fixed salt."""
    fwd = SegmentIndex(BT, salt="fixed")
    rev = SegmentIndex(BT, salt="fixed")
    for blk, n in pairs:
        fwd.insert_block(blk, f"id{n}")
    for blk, n in reversed(pairs):
        rev.insert_block(blk, f"id{n}")
    assert fwd.size() == rev.size()
    for blk, _ in pairs:
        d = fwd.block_digest(blk)
        assert rev.block_digest(blk) == d        # same salt, same digest
        assert fwd.lookup(d) == rev.lookup(d)    # same canonical id


@settings(max_examples=40, deadline=None)
@given(pairs=st.lists(
    st.tuples(st.lists(st.integers(0, 7), min_size=BT, max_size=BT),
              st.integers(0, 9)),
    min_size=2, max_size=10),
       data=st.data())
def test_remove_block_unregisters(pairs, data):
    """Removing a block id leaves the index equal to never having
    inserted it: its digests fall back to the next-smallest id or
    vanish."""
    idx = SegmentIndex(BT, salt="fixed")
    ref = SegmentIndex(BT, salt="fixed")
    drop = data.draw(st.integers(0, 9), label="drop")
    for blk, n in pairs:
        idx.insert_block(blk, f"id{n}")
        if n != drop:
            ref.insert_block(blk, f"id{n}")
    idx.remove_block(f"id{drop}")
    assert idx.size() == ref.size()
    for blk, _ in pairs:
        d = idx.block_digest(blk)
        assert idx.lookup(d) == ref.lookup(d)
