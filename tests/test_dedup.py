"""Content store, radix tree (vs dict oracle), delta checkpoints, and
cross-replica content dedup through the fleet-shared tier."""
import numpy as np
import pytest

try:        # property tests skip individually when hypothesis is absent
    from hypothesis import given, settings, strategies as st
except ImportError:                                 # pragma: no cover
    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    settings = given

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from repro.core.dedup import (CheckpointManifest, ContentStore, RadixTree,
                              content_hash, delta_checkpoint)


def test_refcounting():
    s = ContentStore()
    a, dup = s.intern("h1", "blk0")
    assert not dup and a == "blk0"
    b, dup = s.intern("h1", "blk1")
    assert dup and b == "blk0"
    assert s.refcount("blk0") == 2
    assert s.release("h1") is None          # one ref remains
    assert s.release("h1") == "blk0"        # freed


def test_radix_prefix_match():
    t = RadixTree(4)
    t.insert(list(range(12)), ["a", "b", "c"])
    assert t.match(list(range(12))) == ["a", "b", "c"]
    assert t.match(list(range(8)) + [99, 98, 97, 96]) == ["a", "b"]
    assert t.match([5, 6, 7, 8]) == []
    t.remove_block("b")
    assert t.match(list(range(12))) == ["a"]


@given(st.lists(st.lists(st.integers(0, 3), min_size=0, max_size=24),
                min_size=1, max_size=24))
@settings(max_examples=40, deadline=None)
def test_radix_vs_oracle(seqs):
    """Longest block-aligned shared prefix == brute-force oracle."""
    bt = 4
    t = RadixTree(bt)
    inserted = []
    for i, s in enumerate(seqs):
        n = (len(s) // bt) * bt
        ids = [f"s{i}b{j}" for j in range(n // bt)]
        t.insert(s, ids)
        inserted.append((tuple(s[:n]), ids))
    probe = seqs[0]
    got = t.match(probe)
    # oracle: longest matching block-prefix over all inserted sequences
    best = 0
    n = (len(probe) // bt) * bt
    for toks, ids in inserted:
        m = 0
        while (m + 1) * bt <= min(len(toks), n) and \
                tuple(probe[m * bt:(m + 1) * bt]) == \
                toks[m * bt:(m + 1) * bt]:
            m += 1
        best = max(best, m)
    assert len(got) == best


def test_content_hash_distinguishes_models():
    assert content_hash([1, 2, 3], salt="a") != \
        content_hash([1, 2, 3], salt="b")
    assert content_hash([1, 2, 3]) == content_hash([1, 2, 3])


def test_delta_checkpoint_counts_every_appearance():
    s = ContentStore()
    blocks = [("h1", 10.0), ("h2", 10.0), ("h1", 10.0), ("h1", 10.0)]
    m = delta_checkpoint(blocks, s)
    assert m.written_bytes == 20.0
    assert m.raw_bytes == 40.0
    assert m.savings == pytest.approx(0.5)


def test_content_store_lookup_does_not_touch_refcount():
    s = ContentStore()
    s.intern("h1", "blk0")
    assert s.lookup("h1") == "blk0"
    assert s.lookup("h1") == "blk0"
    assert s.lookup("nope") is None
    assert s.refcount("blk0") == 1


def test_radix_probe_matches_without_hit_bump():
    t = RadixTree(4)
    t.insert(list(range(12)), ["a", "b", "c"])
    before = t.match(list(range(12)))          # bumps hits once
    node = t.root.children[tuple(range(4))]
    hits0 = node.hits
    assert t.probe(list(range(12))) == before == ["a", "b", "c"]
    assert t.probe(list(range(8))) == ["a", "b"]
    assert node.hits == hits0                  # probe left hits untouched


# ---------------------------------------------------------------------------
# Cross-replica content dedup through the fleet-shared tier
# ---------------------------------------------------------------------------
def _two_bound_managers():
    from repro.configs.paper_models import LLAMA3_70B
    from repro.core.cache_manager import PredictiveCacheManager
    from repro.core.tiers import FleetKVStore
    from repro.traces.replay import replay_tier_specs

    specs = replay_tier_specs(LLAMA3_70B, hot_blocks=8, t1_blocks=8)
    store = FleetKVStore(next(s for s in specs if s.tier_id == 4))
    mgrs = []
    for name in ("replicaA", "replicaB"):
        m = PredictiveCacheManager(LLAMA3_70B, specs=specs)
        assert m.bind_fleet_store(store, name)
        mgrs.append(m)
    return store, mgrs[0], mgrs[1]


def test_same_content_two_replicas_one_fleet_copy():
    """Identical content registered+published by two replicas occupies
    tier-4 bytes ONCE, under one content key with two owner refs."""
    store, ma, mb = _two_bound_managers()
    toks = list(range(ma.block_tokens))
    bid_a, _ = ma.register_block(toks)
    bid_b, _ = mb.register_block(toks)
    assert ma.publish_block(bid_a) and mb.publish_block(bid_b)
    key = f"c:{content_hash(toks, salt=ma.cfg.name)}"
    assert store.ref_count(key) == 2
    assert store.tier.used == ma.block_bytes        # one copy, not two
    assert store.publishes == 1 and store.dedup_publishes >= 1


def test_import_shared_block_is_a_tier4_fetch_not_a_recompute():
    """Replica B imports content A published: payload arrives, the hit
    is charged to tier 4 (fetch stall), and B re-publishes its own
    reference so A's teardown cannot strand the content."""
    store, ma, mb = _two_bound_managers()
    toks = list(range(ma.block_tokens))
    bid_a, _ = ma.register_block(
        toks, payload=np.ones((2, 2), dtype=np.float32))
    ma.publish_block(bid_a)
    got = mb.import_shared_block(toks)
    assert got is not None
    bid_b, payload = got
    assert payload is not None
    assert mb.stats.shared_tier_hits == 1
    assert mb.stats.tier_hits.get(4, 0) == 1
    assert mb.stats.fetch_time > 0
    assert mb.stats.reregistrations == 0            # not a cold miss
    key = f"c:{content_hash(toks, salt=ma.cfg.name)}"
    assert store.ref_count(key) == 2
    # a second import is a no-op: the content is now locally known
    assert mb.import_shared_block(toks) is None


def test_release_all_frees_only_own_refs():
    """One replica's release_all (failover teardown) drops its fleet
    references; the other replica's bytes and refs survive."""
    store, ma, mb = _two_bound_managers()
    toks = list(range(ma.block_tokens))
    bid_a, _ = ma.register_block(toks,
                                 payload=np.ones((2,), dtype=np.float32))
    ma.publish_block(bid_a)
    got = mb.import_shared_block(toks)
    assert got is not None
    key = f"c:{content_hash(toks, salt=ma.cfg.name)}"
    assert store.ref_count(key) == 2
    ma.release_all()
    assert store.ref_count(key) == 1                # B's ref survives
    assert store.has_payload(key)
    payload, _ = store.fetch(key)
    assert payload is not None
