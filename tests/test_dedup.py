"""Content store, radix tree (vs dict oracle), delta checkpoints."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.dedup import (CheckpointManifest, ContentStore, RadixTree,
                              content_hash, delta_checkpoint)


def test_refcounting():
    s = ContentStore()
    a, dup = s.intern("h1", "blk0")
    assert not dup and a == "blk0"
    b, dup = s.intern("h1", "blk1")
    assert dup and b == "blk0"
    assert s.refcount("blk0") == 2
    assert s.release("h1") is None          # one ref remains
    assert s.release("h1") == "blk0"        # freed


def test_radix_prefix_match():
    t = RadixTree(4)
    t.insert(list(range(12)), ["a", "b", "c"])
    assert t.match(list(range(12))) == ["a", "b", "c"]
    assert t.match(list(range(8)) + [99, 98, 97, 96]) == ["a", "b"]
    assert t.match([5, 6, 7, 8]) == []
    t.remove_block("b")
    assert t.match(list(range(12))) == ["a"]


@given(st.lists(st.lists(st.integers(0, 3), min_size=0, max_size=24),
                min_size=1, max_size=24))
@settings(max_examples=40, deadline=None)
def test_radix_vs_oracle(seqs):
    """Longest block-aligned shared prefix == brute-force oracle."""
    bt = 4
    t = RadixTree(bt)
    inserted = []
    for i, s in enumerate(seqs):
        n = (len(s) // bt) * bt
        ids = [f"s{i}b{j}" for j in range(n // bt)]
        t.insert(s, ids)
        inserted.append((tuple(s[:n]), ids))
    probe = seqs[0]
    got = t.match(probe)
    # oracle: longest matching block-prefix over all inserted sequences
    best = 0
    n = (len(probe) // bt) * bt
    for toks, ids in inserted:
        m = 0
        while (m + 1) * bt <= min(len(toks), n) and \
                tuple(probe[m * bt:(m + 1) * bt]) == \
                toks[m * bt:(m + 1) * bt]:
            m += 1
        best = max(best, m)
    assert len(got) == best


def test_content_hash_distinguishes_models():
    assert content_hash([1, 2, 3], salt="a") != \
        content_hash([1, 2, 3], salt="b")
    assert content_hash([1, 2, 3]) == content_hash([1, 2, 3])


def test_delta_checkpoint_counts_every_appearance():
    s = ContentStore()
    blocks = [("h1", 10.0), ("h2", 10.0), ("h1", 10.0), ("h1", 10.0)]
    m = delta_checkpoint(blocks, s)
    assert m.written_bytes == 20.0
    assert m.raw_bytes == 40.0
    assert m.savings == pytest.approx(0.5)
