"""Block allocator + paged KV cache invariants: alloc/free roundtrip,
refcounted sharing, copy-on-write isolation, manager-pinned pages."""
import numpy as np
import pytest

from repro.config import ModelConfig, FAMILY_DECODER
from repro.core.tiers import CapacityError
from repro.models.model import build_model
from repro.serving.block_allocator import BlockAllocator
from repro.serving.kvcache import PagedKVCache


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------
def test_alloc_free_roundtrip():
    a = BlockAllocator(8, reserved=(0,))
    pages = a.alloc(7)
    assert sorted(pages) == list(range(1, 8))
    assert a.n_free == 0
    for p in pages:
        assert a.deref(p)
    assert a.n_free == 7
    assert a.stats.allocated == 7 and a.stats.freed == 7


def test_exhaustion_raises():
    a = BlockAllocator(4, reserved=(0,))
    a.alloc(3)
    with pytest.raises(CapacityError):
        a.alloc(1)


def test_reserved_page_never_allocated():
    a = BlockAllocator(4, reserved=(0,))
    assert 0 not in a.alloc(3)
    assert not a.deref(0)          # deref of reserved page is a no-op


def test_refcount_share_frees_only_at_zero():
    a = BlockAllocator(4)
    (p,) = a.alloc(1)
    a.ref(p, share=True)
    a.ref(p, share=True)
    assert a.refcount(p) == 3
    assert not a.deref(p)
    assert not a.deref(p)
    assert a.deref(p)              # last reference frees
    assert a.stats.shares == 2
    with pytest.raises(ValueError):
        a.deref(p)                 # double-free detected


def test_ref_of_free_page_rejected():
    a = BlockAllocator(4)
    with pytest.raises(ValueError):
        a.ref(2)


# ---------------------------------------------------------------------------
# paged cache CoW
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def paged_kv():
    cfg = ModelConfig(name="tiny", family=FAMILY_DECODER, n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=128, vocab_size=256)
    model = build_model(cfg)
    return PagedKVCache(model, n_slots=4, max_len=256, page_tokens=64)


def _fake_state(cfg, n_tokens, seed=0):
    rng = np.random.default_rng(seed)
    shape = (cfg.n_layers, 1, n_tokens, cfg.n_kv_heads, cfg.hd)
    return {"k": rng.normal(size=shape).astype(np.float32),
            "v": rng.normal(size=shape).astype(np.float32)}


def test_share_then_write_triggers_cow(paged_kv):
    kv = paged_kv
    cfg = kv.cfg
    bt = 128                                       # manager block size
    s0 = kv.acquire(1, bt)
    kv.write_prefill(s0, _fake_state(cfg, bt, seed=1), bt)
    kv.register_block_pages("blkA", s0, 0, bt)
    before = kv.extract_block(s0, 0, bt)

    # CoW-share the block into a second slot, then overwrite the shared
    # region there: the writer must get private copies
    s1 = kv.acquire(2, bt)
    assert kv.can_share("blkA")
    assert kv.share_block(s1, "blkA", 0) == bt
    assert kv.allocator.stats.shares >= 2
    kv.write_range(s1, _fake_state(cfg, bt, seed=2), 0, bt)
    assert kv.allocator.stats.cow_copies >= 2      # both shared pages copied

    after = kv.extract_block(s0, 0, bt)            # original untouched
    np.testing.assert_array_equal(before, after)
    changed = kv.extract_block(s1, 0, bt)
    assert np.abs(changed - before).max() > 0
    kv.release(s0)
    kv.release(s1)
    kv.drop_block_pages("blkA")


def test_release_keeps_pinned_block_pages(paged_kv):
    kv = paged_kv
    cfg = kv.cfg
    bt = 128
    s0 = kv.acquire(3, bt)
    kv.write_prefill(s0, _fake_state(cfg, bt, seed=3), bt)
    kv.register_block_pages("blkB", s0, 0, bt)
    payload = kv.extract_block(s0, 0, bt)
    kv.release(s0)                                 # slot gone, block pinned
    s1 = kv.acquire(4, bt)
    kv.share_block(s1, "blkB", 0)
    kv.set_length(s1, bt)
    np.testing.assert_array_equal(kv.extract_block(s1, 0, bt), payload)
    kv.release(s1)
    kv.drop_block_pages("blkB")
    assert kv.allocator.in_use == 0


def test_pool_backpressure_reclaims_pinned_blocks():
    """A full pool unpins manager blocks (oldest first) instead of
    crashing: long-running engines with a large tier-0 budget keep
    admitting; dropped blocks fall back to payload injection."""
    cfg = ModelConfig(name="tiny2", family=FAMILY_DECODER, n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=128, vocab_size=256)
    model = build_model(cfg)
    kv = PagedKVCache(model, n_slots=2, max_len=128, page_tokens=64,
                      reserve_pages=4)        # 1 + 4 + 4 = 9 pages total
    bt = 128                                  # 2 pages per block
    for i in range(6):                        # pins would need 12 pages
        s = kv.acquire(i, bt)
        kv.write_prefill(s, _fake_state(cfg, bt, seed=i), bt)
        kv.register_block_pages(f"blk{i}", s, 0, bt)
        kv.release(s)
    # oldest pins were reclaimed, newest survive, nothing crashed
    assert not kv.can_share("blk0")
    assert kv.can_share("blk5")
    assert kv.allocator.n_free >= 0


def test_preempt_restore_roundtrip_paged(paged_kv):
    kv = paged_kv
    cfg = kv.cfg
    s = kv.acquire(5, 100)
    kv.write_prefill(s, _fake_state(cfg, 100, seed=4), 100)
    payload, length = kv.evict_slot_to_payload(s)
    kv.release(s)
    s2 = kv.acquire(6, 100)
    kv.restore_slot(s2, payload, length)
    np.testing.assert_array_equal(kv.extract_block(s2, 0, 100), payload)
    kv.release(s2)
