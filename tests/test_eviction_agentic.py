"""Head tracker, eviction policies, prefetcher, Markov predictor."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.config import reduce_config
from repro.configs import get_config
from repro.configs.paper_models import DEEPSEEK_V3, LLAMA3_70B
from repro.core.agentic import (MarkovToolPredictor, SessionFeatures,
                                classify_session)
from repro.core.eviction import (BayesianPolicy, BlockMeta, EMAPolicy,
                                 HeadImportanceTracker, LRUPolicy)
from repro.core.prefetch import RoPEPrefetcher


def test_head_tracker_gqa_grouping():
    t = HeadImportanceTracker(LLAMA3_70B)          # 64 q heads, 8 kv
    assert t.n_tracked == 8
    mass = np.zeros(64)
    mass[5] = 1.0                                  # q head 5 -> kv head 0
    t.update(0, mass)
    assert t.matrix[0, 0] > t.matrix[0, 1]


def test_head_tracker_mla_collapses():
    t = HeadImportanceTracker(DEEPSEEK_V3)
    assert t.n_tracked == 1
    assert t.matrix.shape == (61, 1)


def test_lru_orders_by_recency():
    p = LRUPolicy()
    metas = [BlockMeta(f"b{i}", 1.0, last_access=float(i))
             for i in range(5)]
    assert p.select_victim(metas, 10.0).block_id == "b0"
    assert [m.block_id for m in p.select_victims(metas, 10.0, 2)] == \
        ["b0", "b1"]


def test_bayesian_policy_pins_predicted_reuse():
    p = BayesianPolicy(horizon=100.0)
    old_sys = BlockMeta("sys", 1.0, last_access=0.0, reuse_prob=0.95,
                        recompute_cost=0.0)
    fresh_scratch = BlockMeta("scratch", 1.0, last_access=50.0,
                              reuse_prob=0.02, recompute_cost=0.0)
    # despite being 50 ticks fresher, scratch is evicted first
    assert p.select_victim([old_sys, fresh_scratch], 60.0).block_id == \
        "scratch"


def test_pinned_never_selected():
    p = LRUPolicy()
    metas = [BlockMeta("a", 1.0, last_access=0.0, pinned=True),
             BlockMeta("b", 1.0, last_access=9.0)]
    assert p.select_victim(metas, 10.0).block_id == "b"


def test_prefetcher_window_covers_positions():
    pf = RoPEPrefetcher(block_tokens=128, n_layers=4, base_window=512)
    blocks = [f"b{i}" for i in range(64)]
    reqs = pf.plan(blocks, position=1000, resident=lambda b: False)
    ids = [int(r.block_id[1:]) for r in reqs]
    assert min(ids) == 1000 // 128
    assert max(ids) >= (1000 + 256) // 128
    # adaptation: misses shrink the window
    w0 = pf.window
    for _ in range(10):
        pf.feedback(False)
    assert pf.window < w0


def test_layer_window_monotone():
    pf = RoPEPrefetcher(128, n_layers=8)
    assert pf.layer_window(0) < pf.layer_window(7)


@given(st.lists(st.sampled_from(["a", "b", "c", "agent:x"]),
                min_size=2, max_size=60))
@settings(max_examples=40, deadline=None)
def test_markov_rows_sum_to_one(seq):
    m = MarkovToolPredictor()
    prev = None
    for t in seq:
        m.observe_transition(prev, t, kv_bytes=100.0)
        prev = t
    for t in set(seq):
        probs = m.transition_probs(t)
        assert sum(probs.values()) == pytest.approx(1.0)
        assert all(0 <= v <= 1 for v in probs.values())


def test_markov_learns_dominant_transition():
    m = MarkovToolPredictor()
    for _ in range(20):
        m.observe_transition("search", "fetch", kv_bytes=10.0)
    m.observe_transition("search", "calc", kv_bytes=10.0)
    assert m.predict_next("search", 1)[0][0] == "fetch"
    assert m.transition_type("search", "search") == "same_tool_repeat"
    assert m.transition_type("search", "agent:r") == "agent_handoff"


def test_session_classification_monotone():
    light = classify_session(SessionFeatures(1000, 1, 1, 1e6))
    heavy = classify_session(SessionFeatures(200_000, 20, 8, 64 * 1024 ** 3))
    order = ["light", "medium", "heavy", "extreme"]
    assert order.index(light) < order.index(heavy)
