"""Numerical consistency: decode-with-cache == teacher-forced forward;
chunked sequence mixers == sequential oracles; MLA absorbed decode ==
naive attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, reduce_config, FAMILY_DECODER
from repro.configs import get_config
from repro.models import build_model
from repro.models import attention as attn
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod


def grow(state, n):
    def f(k, x):
        if k in ("k", "v", "latent"):
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, n - x.shape[2])
            return jnp.pad(x, pad)
        return x
    return {k: f(k, v) for k, v in state.items()}


@pytest.mark.parametrize("arch", ["llama3.2-1b", "rwkv6-1.6b",
                                  "zamba2-1.2b"])
def test_decode_matches_teacher_forcing(arch):
    """prefill(t[:k]) + decode steps == prefill(t[:k+j]) logits."""
    cfg = reduce_config(get_config(arch))
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=40).tolist()
    # reference: prefill over the longer prefix
    lg_ref, _ = jax.jit(m.prefill)(
        params, {"tokens": jnp.asarray([toks], jnp.int32)})
    # candidate: prefill prefix, then decode the remaining tokens
    k = 32
    lg, state = jax.jit(m.prefill)(
        params, {"tokens": jnp.asarray([toks[:k]], jnp.int32)})
    if "k" in state or "latent" in state:
        state = grow(state, 64)
    dstep = jax.jit(m.decode_step)      # one wrapper: trace/compile once
    for t in toks[k:]:
        lg, state = dstep(params, state, jnp.asarray([t], jnp.int32))
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(lg_ref, np.float32),
                               rtol=0.05, atol=0.15)


def test_ssd_chunked_vs_reference():
    rng = np.random.default_rng(0)
    b, s, h, p, n = 2, 96, 3, 8, 4
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    y1, s1 = ssm_mod.ssd_chunked(x, dt, a, B, C, chunk=32)
    y2, s2 = ssm_mod.ssd_reference(x, dt, a, B, C)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)


def test_wkv_chunked_vs_reference():
    rng = np.random.default_rng(1)
    b, s, h, dk = 2, 64, 2, 8
    r = jnp.asarray(rng.normal(size=(b, s, h, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, dk)), jnp.float32)
    logw = -jnp.asarray(rng.uniform(0.001, 4.9, size=(b, s, h, dk)),
                        jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, dk)), jnp.float32)
    y1, s1 = rwkv_mod.wkv_chunked(r, k, v, logw, u, chunk=16)
    y2, s2 = rwkv_mod.wkv_reference(r, k, v, logw, u)
    np.testing.assert_allclose(y1, y2, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(s1, s2, rtol=1e-3, atol=1e-3)


def test_causal_attention_chunk_invariance():
    rng = np.random.default_rng(2)
    b, s, hq, hkv, hd = 2, 96, 8, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
    o1 = attn.causal_attention(q, k, v, chunk=32)
    o2 = attn.causal_attention(q, k, v, chunk=96)
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)


def test_mla_decode_matches_prefill_last_position():
    """Absorbed decode == naive prefill at the same position (MLA)."""
    cfg = ModelConfig(name="mla-test", family=FAMILY_DECODER,
                      n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      head_dim=16, d_ff=128, vocab_size=256,
                      d_latent=32, d_rope=8)
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    toks = rng.integers(0, 256, size=17).tolist()
    lg_ref, _ = jax.jit(m.prefill)(
        params, {"tokens": jnp.asarray([toks], jnp.int32)})
    _, state = jax.jit(m.prefill)(
        params, {"tokens": jnp.asarray([toks[:-1]], jnp.int32)})
    state = grow(state, 32)
    lg, _ = jax.jit(m.decode_step)(params, state,
                                   jnp.asarray([toks[-1]], jnp.int32))
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(lg_ref, np.float32),
                               rtol=0.05, atol=0.15)


def test_prefill_suffix_matches_full_prefill():
    cfg = reduce_config(get_config("llama3.2-1b"))
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(4))
    rng = np.random.default_rng(4)
    toks = rng.integers(0, cfg.vocab_size, size=48).tolist()
    lg_ref, state_ref = jax.jit(m.prefill)(
        params, {"tokens": jnp.asarray([toks], jnp.int32)})
    _, state = jax.jit(m.prefill)(
        params, {"tokens": jnp.asarray([toks[:32]], jnp.int32)})
    prefix = (state["k"], state["v"])
    lg, (ks, vs) = m.prefill_suffix(
        params, {"tokens": jnp.asarray([toks[32:]], jnp.int32)},
        prefix, 32)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(lg_ref, np.float32),
                               rtol=0.05, atol=0.15)
