"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finiteness (assignment requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import reduce_config
from repro.configs import REGISTRY
from repro.models import build_model

ARCHS = sorted(REGISTRY)


def make_batch(cfg, b=2, s=32):
    batch = {"tokens": jnp.ones((b, s), jnp.int32),
             "labels": jnp.ones((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones((b, cfg.n_patches, cfg.d_model),
                                    jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((b, cfg.enc_len, cfg.d_model),
                                   jnp.bfloat16)
    return batch


@pytest.fixture(scope="module")
def built():
    cache = {}
    def get(arch):
        if arch not in cache:
            cfg = reduce_config(REGISTRY[arch])
            m = build_model(cfg)
            params = m.init_params(jax.random.PRNGKey(0))
            cache[arch] = (cfg, m, params)
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(built, arch):
    cfg, m, params = built(arch)
    loss, metrics = jax.jit(m.train_loss)(params, make_batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_shapes(built, arch):
    cfg, m, params = built(arch)
    b, s = 2, 32
    batch = {k: v for k, v in make_batch(cfg, b, s).items()
             if k != "labels"}
    logits, state = jax.jit(m.prefill)(params, batch)
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    lg2, state2 = jax.jit(m.decode_step)(
        params, state, jnp.ones((b,), jnp.int32))
    assert lg2.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(lg2.astype(jnp.float32))))
    assert int(state2["lengths"][0]) == int(state["lengths"][0]) + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_cover_params(built, arch):
    cfg, m, params = built(arch)
    import jax.tree_util as jtu
    n_specs = len(jtu.tree_leaves(m.abstract_params()))
    n_params = len(jtu.tree_leaves(params))
    assert n_specs == n_params
