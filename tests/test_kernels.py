"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (assignment requirement)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.mla_paged_decode import mla_paged_decode
from repro.kernels.paged_attention import paged_decode_attention

RNG = np.random.default_rng(0)


def _arr(shape, dtype):
    a = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(a, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,hd,page,pages", [
    (2, 8, 2, 64, 64, 4),
    (3, 4, 4, 32, 32, 3),       # MHA
    (1, 8, 1, 128, 64, 2),      # MQA
])
def test_paged_decode_sweep(b, hq, hkv, hd, page, pages, dtype):
    n = b * pages + 2
    q = _arr((b, hq, hd), dtype)
    kp = _arr((n, page, hkv, hd), dtype)
    vp = _arr((n, page, hkv, hd), dtype)
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    ln = jnp.asarray(RNG.integers(1, pages * page, size=b), jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, ln, interpret=True)
    exp = ref.paged_decode_attention_ref(q, kp, vp, bt, ln)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,hq,hkv,hd,bq,bk", [
    (2, 128, 4, 2, 32, 64, 64),
    (1, 256, 8, 8, 64, 128, 64),
    (2, 64, 2, 1, 16, 32, 32),
])
def test_flash_prefill_sweep(b, s, hq, hkv, hd, bq, bk, dtype):
    q = _arr((b, s, hq, hd), dtype)
    k = _arr((b, s, hkv, hd), dtype)
    v = _arr((b, s, hkv, hd), dtype)
    out = flash_prefill(q, k, v, block_q=bq, block_k=bk, interpret=True)
    exp = ref.flash_prefill_ref(q, k, v)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,hq,dl,dr,page,pages", [
    (2, 4, 64, 16, 32, 3),
    (1, 8, 128, 32, 64, 2),
])
def test_mla_paged_decode_sweep(b, hq, dl, dr, page, pages):
    n = b * pages + 1
    ql = _arr((b, hq, dl), jnp.float32)
    qr = _arr((b, hq, dr), jnp.float32)
    lat = _arr((n, page, dl + dr), jnp.float32)
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    ln = jnp.asarray(RNG.integers(1, pages * page, size=b), jnp.int32)
    out = mla_paged_decode(ql, qr, lat, bt, ln, d_latent=dl,
                           interpret=True)
    exp = ref.mla_paged_decode_ref(ql, qr, lat, bt, ln, dl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


def test_paged_decode_handles_ragged_lengths():
    """Length-masking: padding pages beyond `lengths` never contribute."""
    b, hq, hkv, hd, page, pages = 2, 4, 2, 32, 32, 4
    n = b * pages
    q = _arr((b, hq, hd), jnp.float32)
    kp = _arr((n, page, hkv, hd), jnp.float32)
    vp = _arr((n, page, hkv, hd), jnp.float32)
    bt = jnp.arange(n, dtype=jnp.int32).reshape(b, pages)
    ln = jnp.asarray([1, 33], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, ln, interpret=True)
    # corrupt pages beyond the valid length: output must not change
    kp2 = kp.at[2:].set(999.0)
    vp2 = vp.at[2:].set(999.0)
    kp2 = kp2.at[:, :, :, :].set(
        jnp.where(jnp.arange(n)[:, None, None, None] >= 2, 999.0, kp))
    out2 = paged_decode_attention(q, kp2, vp2, bt, ln, interpret=True)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out2[0]),
                               rtol=1e-5, atol=1e-5)


def test_int8_paged_decode_vs_oracle_and_fp():
    """int8 pages + in-kernel dequant: matches the dequantize-then-attend
    oracle exactly; quantization error vs fp attention stays small."""
    from repro.kernels.paged_attention import paged_decode_attention_int8
    from repro.models.attention import quantize_kv
    b, hq, hkv, hd, page, pages = 2, 8, 2, 64, 64, 3
    n = b * pages + 1
    k = _arr((n, page, hkv, hd), jnp.float32)
    v = _arr((n, page, hkv, hd), jnp.float32)
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    q = _arr((b, hq, hd), jnp.float32)
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    ln = jnp.asarray([pages * page, 70], jnp.int32)
    out = paged_decode_attention_int8(q, kq, vq, ks, vs, bt, ln,
                                      interpret=True)
    exp = ref.paged_decode_attention_int8_ref(q, kq, vq, ks, vs, bt, ln)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)
    exp_fp = ref.paged_decode_attention_ref(q, k, v, bt, ln)
    assert float(jnp.max(jnp.abs(out - exp_fp))) < 0.05
