"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (assignment requirement)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.mla_paged_decode import mla_paged_decode
from repro.kernels.paged_attention import paged_decode_attention

RNG = np.random.default_rng(0)


def _arr(shape, dtype):
    a = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(a, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,hd,page,pages", [
    (2, 8, 2, 64, 64, 4),
    (3, 4, 4, 32, 32, 3),       # MHA
    (1, 8, 1, 128, 64, 2),      # MQA
])
def test_paged_decode_sweep(b, hq, hkv, hd, page, pages, dtype):
    n = b * pages + 2
    q = _arr((b, hq, hd), dtype)
    kp = _arr((n, page, hkv, hd), dtype)
    vp = _arr((n, page, hkv, hd), dtype)
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    ln = jnp.asarray(RNG.integers(1, pages * page, size=b), jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, ln, interpret=True)
    exp = ref.paged_decode_attention_ref(q, kp, vp, bt, ln)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,hq,hkv,hd,bq,bk", [
    (2, 128, 4, 2, 32, 64, 64),
    (1, 256, 8, 8, 64, 128, 64),
    (2, 64, 2, 1, 16, 32, 32),
])
def test_flash_prefill_sweep(b, s, hq, hkv, hd, bq, bk, dtype):
    q = _arr((b, s, hq, hd), dtype)
    k = _arr((b, s, hkv, hd), dtype)
    v = _arr((b, s, hkv, hd), dtype)
    out = flash_prefill(q, k, v, block_q=bq, block_k=bk, interpret=True)
    exp = ref.flash_prefill_ref(q, k, v)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,hq,dl,dr,page,pages", [
    (2, 4, 64, 16, 32, 3),
    (1, 8, 128, 32, 64, 2),
])
def test_mla_paged_decode_sweep(b, hq, dl, dr, page, pages):
    n = b * pages + 1
    ql = _arr((b, hq, dl), jnp.float32)
    qr = _arr((b, hq, dr), jnp.float32)
    lat = _arr((n, page, dl + dr), jnp.float32)
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    ln = jnp.asarray(RNG.integers(1, pages * page, size=b), jnp.int32)
    out = mla_paged_decode(ql, qr, lat, bt, ln, d_latent=dl,
                           interpret=True)
    exp = ref.mla_paged_decode_ref(ql, qr, lat, bt, ln, dl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


def test_paged_decode_handles_ragged_lengths():
    """Length-masking: padding pages beyond `lengths` never contribute."""
    b, hq, hkv, hd, page, pages = 2, 4, 2, 32, 32, 4
    n = b * pages
    q = _arr((b, hq, hd), jnp.float32)
    kp = _arr((n, page, hkv, hd), jnp.float32)
    vp = _arr((n, page, hkv, hd), jnp.float32)
    bt = jnp.arange(n, dtype=jnp.int32).reshape(b, pages)
    ln = jnp.asarray([1, 33], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, ln, interpret=True)
    # corrupt pages beyond the valid length: output must not change
    kp2 = kp.at[2:].set(999.0)
    vp2 = vp.at[2:].set(999.0)
    kp2 = kp2.at[:, :, :, :].set(
        jnp.where(jnp.arange(n)[:, None, None, None] >= 2, 999.0, kp))
    out2 = paged_decode_attention(q, kp2, vp2, bt, ln, interpret=True)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out2[0]),
                               rtol=1e-5, atol=1e-5)


def test_int8_paged_decode_vs_oracle_and_fp():
    """int8 pages + in-kernel dequant: matches the dequantize-then-attend
    oracle exactly; quantization error vs fp attention stays small."""
    from repro.kernels.paged_attention import paged_decode_attention_int8
    from repro.models.attention import quantize_kv
    b, hq, hkv, hd, page, pages = 2, 8, 2, 64, 64, 3
    n = b * pages + 1
    k = _arr((n, page, hkv, hd), jnp.float32)
    v = _arr((n, page, hkv, hd), jnp.float32)
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    q = _arr((b, hq, hd), jnp.float32)
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    ln = jnp.asarray([pages * page, 70], jnp.int32)
    out = paged_decode_attention_int8(q, kq, vq, ks, vs, bt, ln,
                                      interpret=True)
    exp = ref.paged_decode_attention_int8_ref(q, kq, vq, ks, vs, bt, ln)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)
    exp_fp = ref.paged_decode_attention_ref(q, k, v, bt, ln)
    assert float(jnp.max(jnp.abs(out - exp_fp))) < 0.05


# ---------------------------------------------------------------------------
# chunked prefill over paged KV (kernels/paged_prefill.py)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,c,hq,hkv,hd,page,pages,offs", [
    (2, 16, 4, 2, 32, 8, 5, (19, 0)),     # unaligned + zero offset
    (1, 32, 8, 8, 64, 32, 3, (64,)),      # MHA, page-aligned offset
    (2, 8, 4, 1, 16, 16, 4, (5, 48)),     # MQA
])
def test_paged_prefill_sweep(b, c, hq, hkv, hd, page, pages, offs, dtype):
    from repro.kernels.paged_prefill import paged_prefill_attention
    n = b * pages + 2
    q = _arr((b, c, hq, hd), dtype)
    kc = _arr((b, c, hkv, hd), dtype)
    vc = _arr((b, c, hkv, hd), dtype)
    kp = _arr((n, page, hkv, hd), dtype)
    vp = _arr((n, page, hkv, hd), dtype)
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    off = jnp.asarray(offs, jnp.int32)
    out = paged_prefill_attention(q, kc, vc, kp, vp, bt, off,
                                  interpret=True)
    exp = ref.paged_prefill_attention_ref(q, kc, vc, kp, vp, bt, off)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,c,hq,dl,dr,page,pages,offs", [
    (2, 16, 4, 32, 8, 16, 4, (23, 0)),
    (1, 8, 8, 64, 16, 32, 2, (32,)),
])
def test_mla_paged_prefill_sweep(b, c, hq, dl, dr, page, pages, offs):
    from repro.kernels.paged_prefill import mla_paged_prefill
    n = b * pages + 1
    ql = _arr((b, c, hq, dl), jnp.float32)
    qr = _arr((b, c, hq, dr), jnp.float32)
    lc = _arr((b, c, dl + dr), jnp.float32)
    lp = _arr((n, page, dl + dr), jnp.float32)
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    off = jnp.asarray(offs, jnp.int32)
    out = mla_paged_prefill(ql, qr, lc, lp, bt, off, d_latent=dl,
                            interpret=True)
    exp = ref.mla_paged_prefill_ref(ql, qr, lc, lp, bt, off, dl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


def test_paged_prefill_ignores_pool_garbage_past_offset():
    """Tokens at pool positions >= offset (stale pages, the page the
    chunk will land on) must never contribute to chunk attention."""
    from repro.kernels.paged_prefill import paged_prefill_attention
    b, c, hq, hkv, hd, page, pages = 1, 8, 4, 2, 32, 8, 4
    n = pages + 1
    q = _arr((b, c, hq, hd), jnp.float32)
    kc = _arr((b, c, hkv, hd), jnp.float32)
    vc = _arr((b, c, hkv, hd), jnp.float32)
    kp = _arr((n, page, hkv, hd), jnp.float32)
    vp = _arr((n, page, hkv, hd), jnp.float32)
    bt = jnp.arange(1, n, dtype=jnp.int32).reshape(1, pages)
    off = jnp.asarray([11], jnp.int32)    # mid-page offset
    out = paged_prefill_attention(q, kc, vc, kp, vp, bt, off,
                                  interpret=True)
    # poison everything at and past the offset
    mask = (jnp.arange(page)[None, :, None, None] +
            page * jnp.arange(n)[:, None, None, None] - page) >= 11
    out2 = paged_prefill_attention(q, kc, vc,
                                   jnp.where(mask, 999.0, kp),
                                   jnp.where(mask, 999.0, vp),
                                   bt, off, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)


def test_paged_prefill_chunk_is_causal():
    """Future chunk tokens must not influence earlier chunk queries."""
    from repro.kernels.paged_prefill import paged_prefill_attention
    b, c, hq, hkv, hd, page, pages = 1, 8, 4, 2, 32, 8, 2
    n = pages + 1
    q = _arr((b, c, hq, hd), jnp.float32)
    kc = _arr((b, c, hkv, hd), jnp.float32)
    vc = _arr((b, c, hkv, hd), jnp.float32)
    kp = _arr((n, page, hkv, hd), jnp.float32)
    vp = _arr((n, page, hkv, hd), jnp.float32)
    bt = jnp.arange(1, n, dtype=jnp.int32).reshape(1, pages)
    off = jnp.asarray([16], jnp.int32)
    out = paged_prefill_attention(q, kc, vc, kp, vp, bt, off,
                                  interpret=True)
    kc2 = kc.at[:, 5:].set(999.0)
    vc2 = vc.at[:, 5:].set(999.0)
    out2 = paged_prefill_attention(q, kc2, vc2, kp, vp, bt, off,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(out[:, :5]),
                               np.asarray(out2[:, :5]),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# segment prefill: per-query positions over non-contiguous gaps
# ---------------------------------------------------------------------------
def _cpos(rows, c):
    """[B, C] int32 chunk-position array from per-row position lists
    (strictly ascending valid entries, -1 padding)."""
    return jnp.asarray([list(r) + [-1] * (c - len(r)) for r in rows],
                       jnp.int32)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,c,hq,hkv,hd,page,pages,rows", [
    # GQA: two runs straddling a resumed island + a ragged padded row
    (2, 8, 4, 2, 32, 8, 5, ([3, 4, 5, 6, 21, 22, 23, 24],
                            [0, 1, 2, 3, 4, 5])),
    # MHA: page-aligned runs around a whole resumed page
    (1, 16, 8, 8, 64, 32, 3, ([8, 9, 10, 11, 12, 13, 14, 15,
                               64, 65, 66, 67, 68, 69, 70, 71],)),
    # MQA: run crossing a page boundary + a far gap
    (2, 8, 4, 1, 16, 16, 4, ([5, 6, 7, 8, 9, 50, 51, 52],
                             [10, 11, 12, 13, 14, 15, 16, 17])),
])
def test_paged_prefill_segments_sweep(b, c, hq, hkv, hd, page, pages,
                                      rows, dtype):
    from repro.kernels.paged_prefill import paged_prefill_segments
    n = b * pages + 2
    q = _arr((b, c, hq, hd), dtype)
    kc = _arr((b, c, hkv, hd), dtype)
    vc = _arr((b, c, hkv, hd), dtype)
    kp = _arr((n, page, hkv, hd), dtype)
    vp = _arr((n, page, hkv, hd), dtype)
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    cpos = _cpos(rows, c)
    out = paged_prefill_segments(q, kc, vc, kp, vp, bt, cpos,
                                 interpret=True)
    exp = ref.paged_prefill_segments_ref(q, kc, vc, kp, vp, bt, cpos)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,c,hq,dl,dr,page,pages,rows", [
    (2, 8, 4, 32, 8, 16, 4, ([2, 3, 4, 5, 40, 41, 42, 43],
                             [0, 1, 2, 3, 4])),
    (1, 8, 8, 64, 16, 32, 2, ([16, 17, 18, 19, 48, 49, 50, 51],)),
])
def test_mla_prefill_segments_sweep(b, c, hq, dl, dr, page, pages, rows):
    from repro.kernels.paged_prefill import mla_paged_prefill_segments
    n = b * pages + 1
    ql = _arr((b, c, hq, dl), jnp.float32)
    qr = _arr((b, c, hq, dr), jnp.float32)
    lc = _arr((b, c, dl + dr), jnp.float32)
    lp = _arr((n, page, dl + dr), jnp.float32)
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    cpos = _cpos(rows, c)
    out = mla_paged_prefill_segments(ql, qr, lc, lp, bt, cpos,
                                     d_latent=dl, interpret=True)
    exp = ref.mla_paged_prefill_segments_ref(ql, qr, lc, lp, bt, cpos, dl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


def test_paged_prefill_segments_degenerate_contiguous():
    """cpos = offset + arange(C) (one segment, no gaps) must reproduce
    the scalar-offset kernel exactly — same pages touched, same mask."""
    from repro.kernels.paged_prefill import (paged_prefill_attention,
                                             paged_prefill_segments)
    b, c, hq, hkv, hd, page, pages = 2, 8, 4, 2, 32, 8, 5
    n = b * pages + 2
    q = _arr((b, c, hq, hd), jnp.float32)
    kc = _arr((b, c, hkv, hd), jnp.float32)
    vc = _arr((b, c, hkv, hd), jnp.float32)
    kp = _arr((n, page, hkv, hd), jnp.float32)
    vp = _arr((n, page, hkv, hd), jnp.float32)
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    offs = (19, 0)
    off = jnp.asarray(offs, jnp.int32)
    cpos = _cpos([[o + i for i in range(c)] for o in offs], c)
    out_seg = paged_prefill_segments(q, kc, vc, kp, vp, bt, cpos,
                                     interpret=True)
    out_off = paged_prefill_attention(q, kc, vc, kp, vp, bt, off,
                                      interpret=True)
    np.testing.assert_array_equal(np.asarray(out_seg), np.asarray(out_off))


def test_mla_prefill_segments_degenerate_contiguous():
    from repro.kernels.paged_prefill import (mla_paged_prefill,
                                             mla_paged_prefill_segments)
    b, c, hq, dl, dr, page, pages = 2, 8, 4, 32, 8, 16, 4
    n = b * pages + 1
    ql = _arr((b, c, hq, dl), jnp.float32)
    qr = _arr((b, c, hq, dr), jnp.float32)
    lc = _arr((b, c, dl + dr), jnp.float32)
    lp = _arr((n, page, dl + dr), jnp.float32)
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    offs = (23, 0)
    off = jnp.asarray(offs, jnp.int32)
    cpos = _cpos([[o + i for i in range(c)] for o in offs], c)
    out_seg = mla_paged_prefill_segments(ql, qr, lc, lp, bt, cpos,
                                         d_latent=dl, interpret=True)
    out_off = mla_paged_prefill(ql, qr, lc, lp, bt, off, d_latent=dl,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(out_seg), np.asarray(out_off))


def test_paged_prefill_segments_ignore_unresident_slots():
    """Pool slots the chunk itself will occupy (not yet scattered) and
    slots at/after the last query position must never contribute."""
    from repro.kernels.paged_prefill import paged_prefill_segments
    b, c, hq, hkv, hd, page, pages = 1, 8, 4, 2, 32, 8, 6
    n = pages + 1
    positions = [3, 4, 5, 6, 21, 22, 23, 24]
    q = _arr((b, c, hq, hd), jnp.float32)
    kc = _arr((b, c, hkv, hd), jnp.float32)
    vc = _arr((b, c, hkv, hd), jnp.float32)
    kp = _arr((n, page, hkv, hd), jnp.float32)
    vp = _arr((n, page, hkv, hd), jnp.float32)
    bt = jnp.arange(1, n, dtype=jnp.int32).reshape(1, pages)
    cpos = _cpos([positions], c)
    out = paged_prefill_segments(q, kc, vc, kp, vp, bt, cpos,
                                 interpret=True)
    # poison every pool slot that is a chunk position or >= the last one
    pool_pos = (jnp.arange(page)[None, :, None, None] +
                page * jnp.arange(n)[:, None, None, None] - page)
    own = jnp.zeros_like(pool_pos, bool)
    for p_ in positions:
        own = own | (pool_pos == p_)
    mask = own | (pool_pos >= positions[-1])
    out2 = paged_prefill_segments(q, kc, vc,
                                  jnp.where(mask, 999.0, kp),
                                  jnp.where(mask, 999.0, vp),
                                  bt, cpos, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)
