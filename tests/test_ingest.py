"""Real-trace ingestion (traces/ingest.py): ShareGPT and LMSYS dump
parsing, block-aligned content-id stability, the generator-compatible
turn shape, and the ``file:`` workload dispatch."""
import json

import pytest

from repro.traces.generators import TraceConfig, workload_sessions
from repro.traces.ingest import load_sessions, text_blocks

SYSTEM = "You are a helpful assistant. " * 40          # > 1 block
LONG_USER = "please summarize the following document " * 60
REPLY = "here is the summary you asked for " * 50


def _sharegpt_dump(tmp_path, n=3):
    recs = []
    for i in range(n):
        recs.append({"id": f"conv{i}", "conversations": [
            {"from": "system", "value": SYSTEM},
            {"from": "human", "value": f"{LONG_USER} variant {i}"},
            {"from": "gpt", "value": f"{REPLY} variant {i}"},
            {"from": "human", "value": f"second question {i} " * 30},
            {"from": "gpt", "value": f"second answer {i} " * 30},
        ]})
    p = tmp_path / "sharegpt.json"
    p.write_text(json.dumps(recs))
    return p


def test_text_blocks_stable_and_sized():
    blocks = text_blocks(LONG_USER)
    assert len(blocks) >= 2
    assert blocks == text_blocks(LONG_USER)            # deterministic
    assert all(isinstance(b, tuple) and len(b) == 1 for b in blocks)
    assert text_blocks("") == []
    # different text -> different leading id
    assert text_blocks("totally different words here")[0] != blocks[0]


def test_text_blocks_digest_stability():
    """Digest regression: chunk boundaries are integer arithmetic
    (``block_tokens * 3 // 4`` words per block) and the tail rule is
    explicit, so these exact digests must never drift — content ids are
    the cross-process identity that dedup, the radix index, the segment
    index and the fleet-shared tier all key on."""
    # 240 words = two full 96-word chunks + a 48-word tail (~64 est.
    # tokens = exactly half a block -> kept as its own block)
    blocks = text_blocks("the quick brown fox " * 60)
    assert blocks == [(1217754630,), (1217754630,), (1410415445,)]
    # repeated text -> identical full-block digests
    assert blocks[0] == blocks[1]


def test_text_blocks_tail_rule():
    """A trailing fragment estimated under half a block merges into the
    previous chunk instead of minting a nearly-empty full-size block id;
    at or above half a block it stands alone."""
    words = [f"w{i}" for i in range(96)]

    def blk(n):
        return text_blocks(" ".join(f"w{i}" for i in range(n)))

    full = blk(96)
    assert len(full) == 1
    # 47-word tail ~ 63 est. tokens < 64 -> merged (one block, and its
    # digest differs from the unextended full block)
    merged = blk(96 + 47)
    assert len(merged) == 1
    assert merged[0] != full[0]
    # 50-word tail ~ 67 est. tokens >= 64 -> its own block; the leading
    # full block's digest is untouched by the extension
    kept = blk(96 + 50)
    assert len(kept) == 2
    assert kept[0] == full[0]
    # a single short text is never merged away
    assert len(text_blocks("just a few words")) == 1
    del words


def test_sharegpt_sessions_shape(tmp_path):
    sessions = load_sessions(_sharegpt_dump(tmp_path))
    assert len(sessions) == 3
    for turns in sessions:
        assert len(turns) == 2                         # two exchanges
        flat = [ev for turn in turns for ev in turn]
        # exactly one session-start marker, on the very first event
        assert [ev.new_session for ev in flat].count(True) == 1
        assert flat[0].new_session
        types = {ev.block_type for ev in flat}
        assert types == {"system_prompt", "user_context",
                         "intermediate_reasoning"}
    # the shared system prompt maps to identical ids across sessions
    sys_ids = [tuple(ev.content_id for ev in s[0]
                     if ev.block_type == "system_prompt")
               for s in sessions]
    assert sys_ids[0] == sys_ids[1] == sys_ids[2]
    # turn 2 re-reads turn 1's *input* blocks (history), never its output
    t1 = sessions[0][0]
    t2 = sessions[0][1]
    t1_inputs = {ev.content_id for ev in t1
                 if ev.block_type == "user_context"}
    t1_outputs = {ev.content_id for ev in t1
                  if ev.block_type == "intermediate_reasoning"}
    t2_reads = {ev.content_id for ev in t2
                if ev.block_type == "user_context"}
    assert t1_inputs & t2_reads
    assert not (t1_outputs & t2_reads)


def test_lmsys_jsonl(tmp_path):
    p = tmp_path / "lmsys.jsonl"
    lines = []
    for i in range(2):
        lines.append(json.dumps({"conversation_id": i, "conversation": [
            {"role": "system", "content": SYSTEM},
            {"role": "user", "content": f"{LONG_USER} {i}"},
            {"role": "assistant", "content": f"{REPLY} {i}"},
        ]}))
    p.write_text("\n".join(lines))
    sessions = load_sessions(p)
    assert len(sessions) == 2
    assert all(len(turns) == 1 for turns in sessions)
    sid0 = sessions[0][0][0].session
    assert sid0 == "ing-0"


def test_malformed_records_skipped(tmp_path):
    p = tmp_path / "mixed.json"
    p.write_text(json.dumps([
        {"unrelated": "record"},
        {"conversations": [{"from": "human", "value": "hi"}]},  # no reply
        {"conversations": [{"from": "human", "value": LONG_USER},
                           {"from": "gpt", "value": REPLY}]},
    ]))
    sessions = load_sessions(p)
    assert len(sessions) == 1


def test_empty_dump_raises(tmp_path):
    p = tmp_path / "empty.json"
    p.write_text("[]")
    with pytest.raises(ValueError):
        load_sessions(p)


def test_workload_sessions_file_dispatch(tmp_path):
    path = _sharegpt_dump(tmp_path, n=4)
    sessions = workload_sessions(f"file:{path}",
                                 TraceConfig(n_sessions=2))
    assert len(sessions) == 2                          # capped by config
    assert sessions[0][0][0].block_type == "system_prompt"
