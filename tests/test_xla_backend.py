"""The compiled ``xla`` kernel backend vs the ``ref.py`` oracles and the
interpret-mode Pallas kernels: numerical equivalence sweeps across head
layouts / ragged lengths / block-table paddings / masked slots, backend
resolution rules, and an engine-level greedy token-identity A/B."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import backend as kb
from repro.kernels import ops, ref

RNG = np.random.default_rng(7)
TOL = dict(rtol=1e-4, atol=1e-4)


def _arr(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32), dtype)


# ---------------------------------------------------------------------------
# backend resolution
# ---------------------------------------------------------------------------
def test_resolution_order_and_validation(monkeypatch):
    assert kb.resolve_backend("interpret") == "interpret"
    # legacy interpret= boolean keeps working
    assert kb.resolve_backend(None, True) == "interpret"
    if kb.on_tpu():
        assert kb.resolve_backend(None, False) == "pallas"
    else:
        # pallas is rejected at resolution off-TPU (clear error instead
        # of a Mosaic lowering failure deep inside jit)
        with pytest.raises(ValueError, match="requires a TPU"):
            kb.resolve_backend(None, False)
        with pytest.raises(ValueError, match="requires a TPU"):
            kb.resolve_backend("pallas")
        monkeypatch.setenv(kb.ENV_VAR, "pallas")
        with pytest.raises(ValueError, match="requires a TPU"):
            kb.default_backend()
    # explicit backend wins over the legacy boolean
    assert kb.resolve_backend("xla", True) == "xla"
    # env var sets the default; argument still wins
    monkeypatch.setenv(kb.ENV_VAR, "interpret")
    assert kb.default_backend() == "interpret"
    assert kb.resolve_backend("xla") == "xla"
    monkeypatch.setenv(kb.ENV_VAR, "bogus")
    with pytest.raises(ValueError, match="bogus"):
        kb.default_backend()
    with pytest.raises(ValueError, match="cuda"):
        kb.resolve_backend("cuda")
    monkeypatch.delenv(kb.ENV_VAR)
    # platform default: xla everywhere but TPU (cached probe)
    assert kb.default_backend() == ("pallas" if kb.on_tpu() else "xla")


def test_resolve_interpret_defaults(monkeypatch):
    assert kb.resolve_interpret(True) is True
    assert kb.resolve_interpret(False) is False
    monkeypatch.delenv(kb.ENV_VAR, raising=False)
    assert kb.resolve_interpret(None) is (not kb.on_tpu())
    # the env var reaches direct kernel-module calls too: interpret is
    # honored on any platform; xla has no meaning for a raw Pallas call
    # and keeps the platform default
    monkeypatch.setenv(kb.ENV_VAR, "interpret")
    assert kb.resolve_interpret(None) is True
    monkeypatch.setenv(kb.ENV_VAR, "xla")
    assert kb.resolve_interpret(None) is (not kb.on_tpu())
    monkeypatch.setenv(kb.ENV_VAR, "bogus")
    with pytest.raises(ValueError, match="bogus"):
        kb.resolve_interpret(None)


def test_engine_validates_backend():
    from repro.config import reduce_config
    from repro.configs import get_config
    from repro.serving import EngineConfig, ServingEngine
    cfg = reduce_config(get_config("llama3.2-1b"))
    with pytest.raises(ValueError, match="mxu"):
        ServingEngine(cfg, EngineConfig(kernel_backend="mxu"))


# ---------------------------------------------------------------------------
# decode: xla vs oracle vs interpret across head layouts + ragged lengths
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,hq,hkv,hd,page,pages", [
    (2, 8, 2, 64, 64, 4),       # GQA
    (3, 4, 4, 32, 32, 3),       # MHA
    (1, 8, 1, 128, 64, 2),      # MQA
])
def test_paged_decode_xla_equivalence(b, hq, hkv, hd, page, pages):
    n = b * pages + 2
    q = _arr((b, hq, hd))
    kp, vp = _arr((n, page, hkv, hd)), _arr((n, page, hkv, hd))
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    ln = jnp.asarray(RNG.integers(1, pages * page, size=b), jnp.int32)
    out = ops.paged_decode(q, kp, vp, bt, ln, backend="xla")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.paged_decode_attention_ref(
            q, kp, vp, bt, ln)), **TOL)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ops.paged_decode(
            q, kp, vp, bt, ln, backend="interpret")), **TOL)


@pytest.mark.parametrize("b,hq,dl,dr,page,pages", [
    (2, 4, 64, 16, 32, 3),
    (1, 8, 128, 32, 64, 2),
])
def test_mla_decode_xla_equivalence(b, hq, dl, dr, page, pages):
    n = b * pages + 1
    ql, qr = _arr((b, hq, dl)), _arr((b, hq, dr))
    lat = _arr((n, page, dl + dr))
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    ln = jnp.asarray(RNG.integers(1, pages * page, size=b), jnp.int32)
    out = ops.mla_decode(ql, qr, lat, bt, ln, d_latent=dl, backend="xla")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.mla_paged_decode_ref(
            ql, qr, lat, bt, ln, dl)), **TOL)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ops.mla_decode(
            ql, qr, lat, bt, ln, d_latent=dl, backend="interpret")), **TOL)


def test_paged_decode_xla_ignores_padded_table_entries():
    """Block-table padding (trailing entries left at the scratch page /
    stale ids past the valid length) must not leak into the output."""
    b, hq, hkv, hd, page, pages = 2, 4, 2, 32, 16, 4
    n = b * pages + 1
    q = _arr((b, hq, hd))
    kp, vp = _arr((n, page, hkv, hd)), _arr((n, page, hkv, hd))
    ln = jnp.asarray([17, 5], jnp.int32)     # 2 pages / 1 page valid
    bt = jnp.asarray([[1, 2, 0, 0], [3, 0, 0, 0]], jnp.int32)
    out = ops.paged_decode(q, kp, vp, bt, ln, backend="xla")
    # redirect the padded entries to poisoned pages: output unchanged
    kp2 = kp.at[4:].set(999.0)
    vp2 = vp.at[4:].set(999.0)
    bt2 = jnp.asarray([[1, 2, 4, 5], [3, 6, 7, 8]], jnp.int32)
    out2 = ops.paged_decode(q, kp2, vp2, bt2, ln, backend="xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), **TOL)
    # an out-of-range padding id clamps into the pool (mode="clip" —
    # the same semantics as the oracles' fancy indexing), never NaN-fills
    bt3 = jnp.asarray([[1, 2, 4, 99], [3, 6, 7, 99]], jnp.int32)
    out3 = ops.paged_decode(q, kp2, vp2, bt3, ln, backend="xla")
    assert not bool(jnp.any(jnp.isnan(out3)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(out3), **TOL)


def test_int8_decode_xla_matches_oracle():
    from repro.models.attention import quantize_kv
    b, hq, hkv, hd, page, pages = 2, 8, 2, 64, 64, 3
    n = b * pages + 1
    k, v = _arr((n, page, hkv, hd)), _arr((n, page, hkv, hd))
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    q = _arr((b, hq, hd))
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    ln = jnp.asarray([pages * page, 70], jnp.int32)
    out = ops.paged_decode_int8(q, kq, vq, ks, vs, bt, ln, backend="xla")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.paged_decode_attention_int8_ref(
            q, kq, vq, ks, vs, bt, ln)), **TOL)


def test_flash_causal_xla_matches_oracle():
    q, k, v = _arr((2, 64, 4, 32)), _arr((2, 64, 2, 32)), _arr((2, 64, 2, 32))
    out = ops.flash_causal(q, k, v, backend="xla")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.flash_prefill_ref(q, k, v)), **TOL)


# ---------------------------------------------------------------------------
# prefill: xla vs oracle vs interpret, incl. masked mid-prefill slots
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,c,hq,hkv,hd,page,pages,offs", [
    (2, 16, 4, 2, 32, 8, 5, (19, 0)),     # GQA, unaligned + zero offset
    (1, 32, 8, 8, 64, 32, 3, (64,)),      # MHA, page-aligned offset
    (2, 8, 4, 1, 16, 16, 4, (5, 48)),     # MQA
])
def test_paged_prefill_xla_equivalence(b, c, hq, hkv, hd, page, pages, offs):
    n = b * pages + 2
    q = _arr((b, c, hq, hd))
    kc, vc = _arr((b, c, hkv, hd)), _arr((b, c, hkv, hd))
    kp, vp = _arr((n, page, hkv, hd)), _arr((n, page, hkv, hd))
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    off = jnp.asarray(offs, jnp.int32)
    out = ops.paged_prefill(q, kc, vc, kp, vp, bt, off, backend="xla")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.paged_prefill_attention_ref(
            q, kc, vc, kp, vp, bt, off)), **TOL)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ops.paged_prefill(
            q, kc, vc, kp, vp, bt, off, backend="interpret")), **TOL)


@pytest.mark.parametrize("b,c,hq,dl,dr,page,pages,offs", [
    (2, 16, 4, 32, 8, 16, 4, (23, 0)),
    (1, 8, 8, 64, 16, 32, 2, (32,)),
])
def test_mla_prefill_xla_equivalence(b, c, hq, dl, dr, page, pages, offs):
    n = b * pages + 1
    ql, qr = _arr((b, c, hq, dl)), _arr((b, c, hq, dr))
    lc, lp = _arr((b, c, dl + dr)), _arr((n, page, dl + dr))
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    off = jnp.asarray(offs, jnp.int32)
    out = ops.mla_prefill(ql, qr, lc, lp, bt, off, d_latent=dl,
                          backend="xla")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.mla_paged_prefill_ref(
            ql, qr, lc, lp, bt, off, dl)), **TOL)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ops.mla_prefill(
            ql, qr, lc, lp, bt, off, d_latent=dl, backend="interpret")),
        **TOL)


def test_prefill_xla_ignores_pool_garbage_past_offset():
    """Mid-prefill masked slots: pool positions >= offset (stale pages,
    the page the chunk will land on) never reach chunk attention."""
    b, c, hq, hkv, hd, page, pages = 1, 8, 4, 2, 32, 8, 4
    n = pages + 1
    q = _arr((b, c, hq, hd))
    kc, vc = _arr((b, c, hkv, hd)), _arr((b, c, hkv, hd))
    kp, vp = _arr((n, page, hkv, hd)), _arr((n, page, hkv, hd))
    bt = jnp.arange(1, n, dtype=jnp.int32).reshape(1, pages)
    off = jnp.asarray([11], jnp.int32)       # mid-page offset
    out = ops.paged_prefill(q, kc, vc, kp, vp, bt, off, backend="xla")
    mask = (jnp.arange(page)[None, :, None, None] +
            page * jnp.arange(n)[:, None, None, None] - page) >= 11
    out2 = ops.paged_prefill(q, kc, vc,
                             jnp.where(mask, 999.0, kp),
                             jnp.where(mask, 999.0, vp),
                             bt, off, backend="xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), **TOL)


def test_prefill_xla_chunk_is_causal():
    b, c, hq, hkv, hd, page, pages = 1, 8, 4, 2, 32, 8, 2
    n = pages + 1
    q = _arr((b, c, hq, hd))
    kc, vc = _arr((b, c, hkv, hd)), _arr((b, c, hkv, hd))
    kp, vp = _arr((n, page, hkv, hd)), _arr((n, page, hkv, hd))
    bt = jnp.arange(1, n, dtype=jnp.int32).reshape(1, pages)
    off = jnp.asarray([16], jnp.int32)
    out = ops.paged_prefill(q, kc, vc, kp, vp, bt, off, backend="xla")
    out2 = ops.paged_prefill(q, kc.at[:, 5:].set(999.0),
                             vc.at[:, 5:].set(999.0), kp, vp, bt, off,
                             backend="xla")
    np.testing.assert_allclose(np.asarray(out[:, :5]),
                               np.asarray(out2[:, :5]), **TOL)


# ---------------------------------------------------------------------------
# segment prefill: xla vs oracle vs interpret
# ---------------------------------------------------------------------------
def _cpos(rows, c):
    return jnp.asarray([list(r) + [-1] * (c - len(r)) for r in rows],
                       jnp.int32)


@pytest.mark.parametrize("b,c,hq,hkv,hd,page,pages,rows", [
    # GQA: two runs around a resumed island + a ragged padded row
    (2, 8, 4, 2, 32, 8, 5, ([3, 4, 5, 6, 21, 22, 23, 24],
                            [0, 1, 2, 3, 4, 5])),
    # MHA: page-aligned runs around a whole resumed page
    (1, 16, 8, 8, 64, 32, 3, ([8, 9, 10, 11, 12, 13, 14, 15,
                               64, 65, 66, 67, 68, 69, 70, 71],)),
    # MQA: run crossing a page boundary + a far gap
    (2, 8, 4, 1, 16, 16, 4, ([5, 6, 7, 8, 9, 50, 51, 52],
                             [10, 11, 12, 13, 14, 15, 16, 17])),
])
def test_paged_prefill_seg_xla_equivalence(b, c, hq, hkv, hd, page,
                                           pages, rows):
    n = b * pages + 2
    q = _arr((b, c, hq, hd))
    kc, vc = _arr((b, c, hkv, hd)), _arr((b, c, hkv, hd))
    kp, vp = _arr((n, page, hkv, hd)), _arr((n, page, hkv, hd))
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    cpos = _cpos(rows, c)
    out = ops.paged_prefill_seg(q, kc, vc, kp, vp, bt, cpos,
                                backend="xla")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.paged_prefill_segments_ref(
            q, kc, vc, kp, vp, bt, cpos)), **TOL)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ops.paged_prefill_seg(
            q, kc, vc, kp, vp, bt, cpos, backend="interpret")), **TOL)


@pytest.mark.parametrize("b,c,hq,dl,dr,page,pages,rows", [
    (2, 8, 4, 32, 8, 16, 4, ([2, 3, 4, 5, 40, 41, 42, 43],
                             [0, 1, 2, 3, 4])),
    (1, 8, 8, 64, 16, 32, 2, ([16, 17, 18, 19, 48, 49, 50, 51],)),
])
def test_mla_prefill_seg_xla_equivalence(b, c, hq, dl, dr, page, pages,
                                         rows):
    n = b * pages + 1
    ql, qr = _arr((b, c, hq, dl)), _arr((b, c, hq, dr))
    lc, lp = _arr((b, c, dl + dr)), _arr((n, page, dl + dr))
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    cpos = _cpos(rows, c)
    out = ops.mla_prefill_seg(ql, qr, lc, lp, bt, cpos, d_latent=dl,
                              backend="xla")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.mla_paged_prefill_segments_ref(
            ql, qr, lc, lp, bt, cpos, dl)), **TOL)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ops.mla_prefill_seg(
            ql, qr, lc, lp, bt, cpos, d_latent=dl,
            backend="interpret")), **TOL)


def test_paged_prefill_seg_xla_degenerate_contiguous():
    """One contiguous segment (cpos = offset + arange) reproduces the
    scalar-offset dispatcher bit-for-bit on the xla backend."""
    b, c, hq, hkv, hd, page, pages = 2, 8, 4, 2, 32, 8, 5
    n = b * pages + 2
    q = _arr((b, c, hq, hd))
    kc, vc = _arr((b, c, hkv, hd)), _arr((b, c, hkv, hd))
    kp, vp = _arr((n, page, hkv, hd)), _arr((n, page, hkv, hd))
    bt = jnp.asarray(RNG.permutation(n)[:b * pages].reshape(b, pages),
                     jnp.int32)
    offs = (19, 0)
    cpos = _cpos([[o + i for i in range(c)] for o in offs], c)
    out_seg = ops.paged_prefill_seg(q, kc, vc, kp, vp, bt, cpos,
                                    backend="xla")
    out_off = ops.paged_prefill(q, kc, vc, kp, vp, bt,
                                jnp.asarray(offs, jnp.int32),
                                backend="xla")
    np.testing.assert_allclose(np.asarray(out_seg), np.asarray(out_off),
                               **TOL)


# ---------------------------------------------------------------------------
# engine-level: greedy replay A/B is token-identical across backends
# ---------------------------------------------------------------------------
def _greedy_engine_tokens(backend: str):
    from repro.config import reduce_config
    from repro.configs import get_config
    from repro.serving import EngineConfig, SamplingParams, ServingEngine
    cfg = reduce_config(get_config("llama3.2-1b"))
    eng = ServingEngine(cfg, EngineConfig(
        max_len=160, kv_budget_bytes=1e6, async_transfers=False,
        kernel_backend=backend))
    assert eng.kernel_backend == backend
    rng = np.random.default_rng(3)
    template = [int(t) for t in rng.integers(0, 200, size=40)]
    for i in range(4):
        user = [int(t) for t in rng.integers(0, 200, size=12)]
        # shared template: requests 1+ take the CoW prefix-share path,
        # so the A/B covers chunk prefill at nonzero offsets too
        eng.submit(template + user,
                   params=SamplingParams(max_new_tokens=8, temperature=0.0),
                   session_id=f"s{i}", block_type="system_prompt")
    eng.run(max_steps=500)
    eng.shutdown()
    done = sorted(eng.scheduler.done, key=lambda r: r.request_id)
    assert len(done) == 4 and all(len(r.generated) == 8 for r in done)
    return [list(r.generated) for r in done]


def test_replay_greedy_token_identical_across_backends():
    assert _greedy_engine_tokens("xla") == \
        _greedy_engine_tokens("interpret")
