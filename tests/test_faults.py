"""Fault-injected tier I/O (core/faults.py + hierarchy/engine wiring):
typed errors, bounded retries with deterministic backoff, crc32
integrity gating, the per-tier health state machine, stalled-transfer
expiry, drain-deadline shedding, and the chaos soak — every request
completes under injected faults with greedy tokens identical to the
fault-free control."""
import types

import numpy as np
import pytest

try:        # property tests skip individually when hypothesis is absent;
    #         the example-based tests below always run
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                 # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    settings = given

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from repro.core.faults import (DEGRADED, HEALTHY, PROBING, QUARANTINED,
                               FaultInjector, FaultProfile, HealthConfig,
                               RetryPolicy, TierHealthMonitor,
                               TierIntegrityError, TierIOError, payload_crc)
from repro.core.tiers import (PAPER_TIER_SPECS, AsyncTierTransferWorker,
                              RDMATier, TierHierarchy, TierManager,
                              TierSpec, TransferRequest)


def small_specs(cap=10 * 100.0):
    return tuple(
        TierSpec(s.tier_id, s.name, s.bandwidth, s.latency,
                 s.cost_per_gb_hour, cap * (s.tier_id + 1))
        for s in PAPER_TIER_SPECS)


def _payload(seed=0, n=8):
    return np.random.default_rng(seed).normal(size=n).astype(np.float32)


# ---------------------------------------------------------------------------
# injector-off inertness
# ---------------------------------------------------------------------------
def test_no_injector_is_inert():
    """Without an injector the fault layer must be completely absent:
    no crc recorded, run_io is a plain passthrough, and no fault
    counters exist in the hot path."""
    h = TierHierarchy(small_specs())
    p = _payload()
    h.write_tier(1, "b0", p, nbytes=float(p.nbytes))
    assert h.tiers[1]._crc == {}            # checksums gated on injector
    out, _ = h.read_tier(1, "b0")
    assert np.array_equal(out, p)
    assert h.counters.retries == 0 and h.counters.io_errors == 0
    assert h.fault_stats()["tier_health"][1] == HEALTHY
    assert "injected" not in h.fault_stats()


def test_disabled_injector_draws_nothing():
    inj = FaultInjector({1: FaultProfile(read_error_rate=1.0,
                                         corruption_rate=1.0)}, seed=0)
    inj.enabled = False
    assert inj.check_read(1, "b") == 1.0
    p = _payload()
    assert inj.maybe_corrupt(1, "b", p) is p
    assert not inj.should_stall(1, "b")
    assert all(v == 0 for v in inj.stats().values())


# ---------------------------------------------------------------------------
# transient errors, retries, escalation
# ---------------------------------------------------------------------------
def test_transient_read_error_retried_then_escalated():
    """rate=1.0: every attempt fails, so run_io burns the whole retry
    budget then escalates exactly one io_error."""
    pol = RetryPolicy(max_attempts=3, deadline_s=10.0)
    h = TierHierarchy(small_specs(),
                      fault_injector=FaultInjector(
                          {1: FaultProfile(read_error_rate=1.0)}, seed=0),
                      retry_policy=pol)
    p = _payload()
    h.write_tier(1, "b0", p, nbytes=float(p.nbytes))
    with pytest.raises(TierIOError):
        h.read_tier(1, "b0")
    assert h.counters.retries == pol.max_attempts - 1
    assert h.counters.io_errors == 1
    assert h.counters.retry_delay_s > 0.0
    # the stored payload is untouched — a later fault-free read works
    h.fault_injector.enabled = False
    out, _ = h.read_tier(1, "b0")
    assert np.array_equal(out, p)


def test_write_fault_mutates_nothing():
    h = TierHierarchy(small_specs(),
                      fault_injector=FaultInjector(
                          {2: FaultProfile(write_error_rate=1.0)}, seed=0),
                      retry_policy=RetryPolicy(max_attempts=2))
    with pytest.raises(TierIOError):
        h.write_tier(2, "b0", _payload(), nbytes=100.0)
    assert "b0" not in h.tiers[2]._sizes
    assert "b0" not in h.tiers[2]._crc


def test_unfaulted_tiers_draw_nothing():
    """Only tiers with a profile consume randomness: ops on clean tiers
    never advance the injector RNG, so a fault-free tier's behaviour is
    identical with and without the injector attached."""
    inj = FaultInjector({3: FaultProfile(read_error_rate=0.5)}, seed=42)
    state0 = inj._rng.bit_generator.state["state"]["state"]
    assert inj.check_read(1, "b") == 1.0       # tier 1: no profile
    assert inj.check_write(2, "b") == 1.0
    assert inj._rng.bit_generator.state["state"]["state"] == state0


# ---------------------------------------------------------------------------
# corruption + integrity gate
# ---------------------------------------------------------------------------
def test_forced_corruption_caught_before_return():
    h = TierHierarchy(small_specs(),
                      fault_injector=FaultInjector({}, seed=0))
    p = _payload()
    h.write_tier(1, "b0", p, nbytes=float(p.nbytes))
    h.fault_injector.force_corrupt("b0")
    with pytest.raises(TierIntegrityError):
        h.read_tier(1, "b0")
    assert h.counters.integrity_failures == 1
    assert h.tiers[1].stats.integrity_failures == 1
    assert h.fault_injector.stats()["injected_corruptions"] == 1
    # the flip hit a COPY: the stored bytes are intact, so the next
    # (unforced) read returns the true payload
    out, _ = h.read_tier(1, "b0")
    assert np.array_equal(out, p)


def test_integrity_error_not_retried():
    """Corruption escalates immediately — re-reading cannot make the
    already-returned copy safe, and retrying would hide the event."""
    pol = RetryPolicy(max_attempts=4)
    h = TierHierarchy(small_specs(),
                      fault_injector=FaultInjector(
                          {1: FaultProfile(corruption_rate=1.0)}, seed=0),
                      retry_policy=pol)
    p = _payload()
    h.write_tier(1, "b0", p, nbytes=float(p.nbytes))
    with pytest.raises(TierIntegrityError):
        h.read_tier(1, "b0")
    assert h.counters.retries == 0
    assert h.counters.integrity_failures == 1


def test_brownout_inflates_transfer_time():
    prof = FaultProfile(brownout_rate=1.0, brownout_latency_mult=10.0)
    h0 = TierHierarchy(small_specs())
    h1 = TierHierarchy(small_specs(),
                       fault_injector=FaultInjector({1: prof}, seed=0))
    p = _payload()
    t0w = h0.write_tier(1, "b0", p, nbytes=float(p.nbytes))
    t1w = h1.write_tier(1, "b0", p, nbytes=float(p.nbytes))
    assert t1w == pytest.approx(10.0 * t0w)
    _, t0r = h0.read_tier(1, "b0")
    _, t1r = h1.read_tier(1, "b0")
    assert t1r == pytest.approx(10.0 * t0r)
    assert h1.fault_injector.stats()["injected_brownouts"] == 2
    assert h1.fault_injector.read_brownouts_by_tier == {1: 1}


def test_rdma_flap_rehomes_and_fails_transiently():
    spec = PAPER_TIER_SPECS[4]
    tier = RDMATier(spec, nodes=("n0", "n1", "n2"))
    tier.fault_injector = FaultInjector(
        {4: FaultProfile(flap_rate=1.0)}, seed=0)
    tier.allocate("b0", 100.0)
    with pytest.raises(TierIOError) as ei:
        tier.read("b0")
    assert ei.value.kind == "flap"
    # the node rejoined immediately: ring membership is unchanged and
    # the block survived the re-home round trip
    assert sorted(tier.ring.nodes) == ["n0", "n1", "n2"]
    assert "b0" in tier._sizes
    tier.fault_injector.enabled = False
    tier.read("b0")                        # post-flap read succeeds


# ---------------------------------------------------------------------------
# RetryPolicy properties
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 2**31 - 1), attempts=st.integers(2, 8))
@settings(max_examples=50, deadline=None)
def test_retry_schedule_deterministic(seed, attempts):
    pol = RetryPolicy(max_attempts=attempts, seed=seed)
    assert pol.schedule() == pol.schedule()


@given(seed=st.integers(0, 2**31 - 1),
       base=st.floats(1e-5, 1e-2),
       deadline=st.floats(1e-4, 1.0))
@settings(max_examples=50, deadline=None)
def test_retry_total_delay_bounded_by_deadline(seed, base, deadline):
    pol = RetryPolicy(max_attempts=16, base_delay_s=base,
                      deadline_s=deadline, seed=seed)
    assert sum(pol.schedule()) <= deadline


@given(seed=st.integers(0, 2**31 - 1), attempts=st.integers(2, 8))
@settings(max_examples=50, deadline=None)
def test_retry_eventually_escalates(seed, attempts):
    """The schedule is finite: at most max_attempts-1 backoffs, so an op
    that keeps failing always escalates."""
    pol = RetryPolicy(max_attempts=attempts, deadline_s=1e9, seed=seed)
    sched = pol.schedule()
    assert len(sched) <= attempts - 1
    # delays grow (exponential backoff survives +/-25% jitter at 2x mult)
    for a, b in zip(sched, sched[1:]):
        assert b > a * 1.0


# ---------------------------------------------------------------------------
# health state machine
# ---------------------------------------------------------------------------
@given(n_fails=st.integers(0, 30))
@settings(max_examples=50, deadline=None)
def test_quarantine_only_after_threshold(n_fails):
    cfg = HealthConfig(degraded_after=3, quarantine_after=8)
    m = TierHealthMonitor(6, cfg)
    for _ in range(n_fails):
        m.record_failure(2)
    if n_fails >= cfg.quarantine_after:
        assert m.state(2) == QUARANTINED
    elif n_fails >= cfg.degraded_after:
        assert m.state(2) == DEGRADED
    else:
        assert m.state(2) == HEALTHY


@given(ops=st.lists(st.sampled_from(["fail", "ok"]), max_size=40))
@settings(max_examples=50, deadline=None)
def test_no_exit_from_quarantine_without_probe(ops):
    """Once quarantined, no sequence of recorded successes or failures
    changes the state — only probe_result(tid, True) does."""
    cfg = HealthConfig(quarantine_after=2)
    m = TierHealthMonitor(6, cfg)
    m.record_failure(1)
    m.record_failure(1)
    assert m.state(1) == QUARANTINED
    for op in ops:
        (m.record_failure if op == "fail" else m.record_success)(1)
    assert m.state(1) == QUARANTINED
    # the only exit: due probe -> PROBING -> successful probe_result
    assert m.due_probe(1, now=cfg.probe_interval + 1.0)
    assert m.state(1) == PROBING
    assert m.probe_result(1, True) == HEALTHY


def test_failed_probe_requarantines_with_fresh_timer():
    cfg = HealthConfig(quarantine_after=1, probe_interval=10.0)
    m = TierHealthMonitor(6, cfg)
    m.record_failure(3, now=0.0)
    assert not m.due_probe(3, now=5.0)          # interval not elapsed
    assert m.due_probe(3, now=11.0)
    assert m.probe_result(3, False, now=11.0) == QUARANTINED
    assert not m.due_probe(3, now=15.0)         # timer restarted at 11
    assert m.due_probe(3, now=22.0)


def test_degraded_recovers_after_consecutive_successes():
    cfg = HealthConfig(degraded_after=2, quarantine_after=99,
                       recover_successes=3)
    m = TierHealthMonitor(6, cfg)
    m.record_failure(2), m.record_failure(2)
    assert m.state(2) == DEGRADED
    m.record_success(2), m.record_success(2)
    m.record_failure(2)                          # resets the streak
    m.record_success(2), m.record_success(2)
    assert m.state(2) == DEGRADED
    m.record_success(2)
    assert m.state(2) == HEALTHY


# ---------------------------------------------------------------------------
# hierarchy: quarantine routes around, probe restores
# ---------------------------------------------------------------------------
def test_quarantine_routes_around_and_probe_restores():
    """A persistently failing tier gets quarantined (available=False —
    the same routing flag fail_tier uses), then a recovery probe after
    the fault clears restores it to the demotion graph."""
    hcfg = HealthConfig(degraded_after=1, quarantine_after=2,
                        probe_interval=5.0)
    h = TierHierarchy(small_specs(),
                      fault_injector=FaultInjector(
                          {2: FaultProfile(read_error_rate=1.0)}, seed=0),
                      retry_policy=RetryPolicy(max_attempts=2),
                      health_config=hcfg)
    p = _payload()
    h.write_tier(2, "b0", p, nbytes=float(p.nbytes))
    with pytest.raises(TierIOError):
        h.read_tier(2, "b0")
    assert h.health.state(2) == QUARANTINED      # 2 failed attempts
    assert not h.tiers[2].available
    assert h.counters.quarantines == 1
    # probe while the fault persists: stays quarantined, stays routed out
    h.tick(6.0)
    assert h.health.state(2) == QUARANTINED
    assert not h.tiers[2].available
    assert h.counters.probes == 1
    # fault clears -> next due probe restores routing
    h.fault_injector.profiles.pop(2)
    h.tick(6.0)
    assert h.health.state(2) == HEALTHY
    assert h.tiers[2].available
    assert h.counters.probe_recoveries == 1
    out, _ = h.read_tier(2, "b0")                # parked block reachable
    assert np.array_equal(out, p)


# ---------------------------------------------------------------------------
# async transfer worker: stalls, timeouts, drain escalation
# ---------------------------------------------------------------------------
def test_stalled_transfer_expires_as_failed_event():
    h = TierHierarchy(small_specs(),
                      fault_injector=FaultInjector({}, seed=0))
    h.fault_injector.force_stall("b0")
    p = _payload()
    h.write_tier(1, "b0", p, nbytes=float(p.nbytes))
    w = AsyncTierTransferWorker(h, default_timeout_s=0.05)
    w.submit(TransferRequest(kind="fetch", block_id="b0", src=1, dst=0,
                             payload=None, nbytes=float(p.nbytes)))
    evs = []
    deadline = 200
    while not evs and deadline:
        evs = w.poll()
        deadline -= 1
        import time
        time.sleep(0.005)
    assert evs, "stalled transfer never expired"
    assert not evs[0].ok and "timeout" in evs[0].error
    assert w.drain(timeout=1.0)
    st_ = w.stats()
    assert st_["timeouts"] == 1 and st_["stalled_total"] == 1
    assert st_["in_flight"] == 0
    w.close()


def test_drain_escalate_sheds_unexpired_stall():
    """drain(escalate=True) must not wait out a stall whose deadline is
    far away: at the drain deadline it force-fails the transfer."""
    h = TierHierarchy(small_specs(),
                      fault_injector=FaultInjector({}, seed=0))
    h.fault_injector.force_stall("b0")
    p = _payload()
    h.write_tier(1, "b0", p, nbytes=float(p.nbytes))
    w = AsyncTierTransferWorker(h, default_timeout_s=3600.0)
    w.submit(TransferRequest(kind="fetch", block_id="b0", src=1, dst=0,
                             payload=None, nbytes=float(p.nbytes)))
    import time
    t0 = time.monotonic()
    assert w.drain(timeout=0.2, escalate=True)
    assert time.monotonic() - t0 < 2.0
    evs = w.poll()
    assert len(evs) == 1 and not evs[0].ok
    assert w.stats()["in_flight"] == 0
    w.close()


# ---------------------------------------------------------------------------
# frontend: drain-deadline shed with balanced ledger
# ---------------------------------------------------------------------------
class _StuckScheduler:
    def __init__(self):
        self.waiting, self.preempted = [], []
        self.running, self.blocked, self.done = {}, {}, []

    def has_work(self):
        return bool(self.waiting or self.running or self.blocked)


class _StuckEngine:
    """Accepts submissions into a blocked state that no step() ever
    resolves — the permanently-stalled-fetch shape, minus the engine."""

    def __init__(self):
        from repro.serving.scheduler import Scheduler  # noqa: F401
        self.scheduler = _StuckScheduler()
        self.ecfg = types.SimpleNamespace(max_step_tokens=64)
        self.kv = types.SimpleNamespace(free_slots=lambda: [0, 1])
        self.cancelled = []
        self.was_shutdown = False

    def submit(self, prompt, **kw):
        from repro.serving.request import Request
        req = Request(prompt=list(prompt))
        self.scheduler.blocked[req.request_id] = req
        return req

    def step(self):
        return 0

    def cancel_request(self, req):
        from repro.serving.request import Phase
        if self.scheduler.blocked.pop(req.request_id, None) is None:
            return False
        req.phase = Phase.DONE
        self.cancelled.append(req.request_id)
        return True

    def shutdown(self):
        self.was_shutdown = True


def test_frontend_stop_sheds_stuck_requests():
    """stop(drain=True) with a request that can never finish: the drain
    deadline sheds it through engine cancellation instead of raising,
    and the ledger still balances (offered == shed + done)."""
    from repro.serving.frontend import ServingFrontend, VirtualClock
    eng = _StuckEngine()
    fe = ServingFrontend(eng, clock=VirtualClock(), step_time_s=0.01)
    h1 = fe.submit([1, 2, 3])
    h2 = fe.submit([4, 5, 6])
    fe.run_for(n_steps=3)                  # admitted, stuck in the engine
    assert fe.in_flight() == 2
    fe.stop(drain=True, timeout=0.5)
    assert h1.status == "shed" and h2.status == "shed"
    assert fe.in_flight() == 0
    assert fe.shed == 2 and fe.done == 0 and fe.offered == 2
    fe.check_ledger()
    assert sorted(eng.cancelled) == sorted(
        [h1.request.request_id, h2.request.request_id])
    assert eng.was_shutdown


def test_frontend_stop_sheds_queued_and_inbox_too():
    from repro.serving.frontend import ServingFrontend, VirtualClock
    eng = _StuckEngine()
    fe = ServingFrontend(eng, clock=VirtualClock(), step_time_s=0.01)
    fe.submit([1, 2])
    fe.run_for(n_steps=1)                  # -> engine (stuck)
    fe.submit([3, 4])                      # stays in the inbox
    fe.stop(drain=True, timeout=0.3)
    assert fe.shed == 2 and fe.offered == 2
    fe.check_ledger()


def test_engine_cancel_request_releases_resources():
    """ServingEngine.cancel_request on a live decode: slot freed, blocks
    released, request terminal and not counted done."""
    from repro.config import reduce_config
    from repro.configs import get_config
    from repro.serving import EngineConfig, SamplingParams, ServingEngine
    cfg = reduce_config(get_config("llama3.2-1b"))
    eng = ServingEngine(cfg, EngineConfig(max_len=128,
                                          kv_budget_bytes=2e6))
    r1 = eng.submit(list(range(1, 20)),
                    params=SamplingParams(max_new_tokens=32))
    r2 = eng.submit(list(range(21, 40)),
                    params=SamplingParams(max_new_tokens=4))
    eng.step()
    assert r1.request_id in eng.scheduler.running
    free_before = len(eng.kv.free_slots())
    assert eng.cancel_request(r1)
    assert not eng.cancel_request(r1)           # already gone
    assert r1.request_id not in eng.scheduler.running
    assert len(eng.kv.free_slots()) == free_before + 1
    from repro.serving.request import Phase
    assert r1.phase == Phase.DONE and r1 not in eng.scheduler.done
    eng.run(max_steps=200)                      # survivor completes
    assert r2.finished() and len(r2.generated) == 4
    eng.shutdown()


# ---------------------------------------------------------------------------
# engine/replay level: faults never hang a request
# ---------------------------------------------------------------------------
def _chaos_cfg(**kw):
    from repro.traces.serving_replay import ServingReplayConfig
    base = dict(workload="agentic", policy="bayesian", n_sessions=2,
                max_turns=3, max_steps=4000, async_transfers=False,
                hot_blocks=6, t1_blocks=8)
    return ServingReplayConfig(**{**base, **kw})


def test_dead_lower_tiers_become_recompute():
    """read_error_rate=1.0 on every lower tier: no fetch can succeed,
    so every previously-demoted block converts to a recompute — and
    every turn still completes."""
    from repro.traces.serving_replay import run_serving_replay
    prof = {t: FaultProfile(read_error_rate=1.0) for t in (1, 2, 3, 4, 5)}
    r = run_serving_replay(_chaos_cfg(fault_profiles=prof, fault_seed=1))
    assert r.requests_done == r.turns_submitted
    assert r.io_errors > 0
    assert r.fetch_recomputes > 0
    assert r.retries >= r.io_errors            # budget burned before each


def test_chaos_replay_zero_hung_and_corruptions_caught():
    from repro.traces.serving_replay import run_serving_replay
    prof = {t: FaultProfile(read_error_rate=0.2, write_error_rate=0.1,
                            corruption_rate=0.2) for t in (1, 2, 3, 4, 5)}
    r = run_serving_replay(_chaos_cfg(fault_profiles=prof, fault_seed=3))
    assert r.requests_done == r.turns_submitted
    assert r.retries >= 1
    corruptions = r.injected.get("injected_corruptions", 0)
    assert corruptions >= 1
    assert r.integrity_failures == corruptions


# ---------------------------------------------------------------------------
# chaos soak: token identity + accounting inertness
# ---------------------------------------------------------------------------
def _soak_tokens(backend, profiles, seed=11):
    """2 sessions x 3 turns submitted turn-by-turn through one engine;
    returns (generated tokens per turn, engine, replay-ish ledger)."""
    from repro.core import sizing
    from repro.serving.request import SamplingParams
    from repro.traces.generators import TraceConfig, workload_sessions
    from repro.traces.serving_replay import (_turn_spec, build_engine,
                                             replay_model_config)
    rcfg = _chaos_cfg(kernel_backend=backend, fault_profiles=profiles,
                      fault_seed=seed)
    cfg = replay_model_config(rcfg.block_tokens)
    bt = sizing.block_tokens(cfg)
    sessions = workload_sessions(
        rcfg.workload, TraceConfig(n_sessions=rcfg.n_sessions, seed=0))
    cache = {}
    specs = [[_turn_spec(t, bt, cfg.vocab_size, rcfg.max_new_cap, cache)
              for t in sess[:rcfg.max_turns]] for sess in sessions]
    eng = build_engine(rcfg, cfg, max_len=768)
    tokens, submitted, done = {}, 0, 0
    for k in range(rcfg.max_turns):
        for i, sess in enumerate(specs):
            if k >= len(sess):
                continue
            spec = sess[k]
            req = eng.submit(spec.prompt,
                             params=SamplingParams(max_new_tokens=spec.max_new),
                             session_id=spec.session_id,
                             block_types=spec.block_types, tool=spec.tool,
                             retain_blocks=k + 1 < len(sess))
            submitted += 1
            eng.run(max_steps=2000)
            assert req.finished(), f"session {i} turn {k} hung"
            done += 1
            tokens[(i, k)] = list(req.generated)
    eng.manager.sync_fault_stats()
    stats = eng.manager.metrics()
    eng.shutdown()
    assert submitted == done
    return tokens, stats


CHAOS_PROFILES = {t: FaultProfile(read_error_rate=1e-2,
                                  write_error_rate=1e-2,
                                  corruption_rate=1e-2)
                  for t in (1, 2, 3, 4, 5)}


@pytest.mark.parametrize("backend", [
    "xla",
    pytest.param("interpret", marks=pytest.mark.slow),
])
def test_chaos_soak_tokens_identical_to_fault_free(backend):
    """The whole point of the integrity/retry/recompute machinery:
    under a 1e-2 fault profile every request completes AND the greedy
    token streams are bit-identical to the fault-free control — faults
    cost latency, never correctness."""
    control, _ = _soak_tokens(backend, None)
    chaos, stats = _soak_tokens(backend, CHAOS_PROFILES)
    assert chaos == control
    inj = stats["faults"]["injected"]
    # the profile actually fired (else the soak proves nothing)
    assert (inj["injected_read_errors"] + inj["injected_write_errors"]
            + inj["injected_corruptions"]) > 0
    assert stats["integrity_failures"] == inj["injected_corruptions"]


def test_attached_but_all_zero_injector_matches_no_injector():
    """A wired-up injector whose profiles never fire must reproduce the
    no-injector accounting exactly (hit/reuse/latency/steps) — PR 9's
    numbers survive the fault plumbing bit-for-bit."""
    from repro.traces.serving_replay import run_serving_replay
    r_none = run_serving_replay(_chaos_cfg())
    r_zero = run_serving_replay(_chaos_cfg(
        fault_profiles={t: FaultProfile() for t in (1, 2, 3, 4, 5)}))
    for f in ("engine_hit_rate", "reuse_rate", "seen_blocks",
              "generated_tokens", "requests_done", "steps",
              "virtual_time_s", "ttft_p50", "ttft_p95", "ttft_p99",
              "tbt_p50", "tbt_p95", "promotions", "demotions",
              "hot_hits_t0", "hot_hits_t1"):
        assert getattr(r_zero, f) == getattr(r_none, f), f
    assert r_zero.retries == r_zero.io_errors == 0
    assert r_zero.integrity_failures == r_zero.fetch_recomputes == 0


# ---------------------------------------------------------------------------
# stats surfacing
# ---------------------------------------------------------------------------
def test_manager_metrics_surface_fault_counters():
    from repro.core.cache_manager import PredictiveCacheManager
    from repro.configs.paper_models import LLAMA3_70B
    mgr = PredictiveCacheManager(
        LLAMA3_70B, specs=small_specs(cap=1e9),
        fault_injector=FaultInjector(
            {1: FaultProfile(read_error_rate=1.0)}, seed=0),
        retry_policy=RetryPolicy(max_attempts=2))
    m = mgr.metrics()
    for k in ("retries", "io_errors", "integrity_failures",
              "fetch_recomputes", "tier_health", "faults"):
        assert k in m, k
    assert m["faults"]["tier_health"][0] == HEALTHY
    assert "injected" in m["faults"]


def test_engine_stats_surface_faults():
    from repro.traces.serving_replay import build_engine
    eng = build_engine(_chaos_cfg(
        fault_profiles={1: FaultProfile(read_error_rate=0.5)}))
    st_ = eng.stats()
    eng.shutdown()
    assert "faults" in st_
    assert st_["faults"]["tier_health"][1] == HEALTHY
    assert st_["faults"]["injected"]["injected_read_errors"] == 0


def test_fleet_manager_stats_health_worst_state_wins():
    from repro.config import reduce_config
    from repro.configs import get_config
    from repro.serving import EngineConfig
    from repro.serving.cluster import ReplicaCluster
    cfg = reduce_config(get_config("llama3.2-1b"))
    cluster = ReplicaCluster(cfg, EngineConfig(max_len=128,
                                               kv_budget_bytes=4e6),
                             n_replicas=2)
    engines = list(cluster.engines.values())
    engines[0].manager.hierarchy.health._state[3] = QUARANTINED
    engines[1].manager.hierarchy.health._state[3] = DEGRADED
    fleet = cluster.fleet_manager_stats()
    assert fleet.tier_health[3] == QUARANTINED     # worst state wins
    assert fleet.tier_health[0] == HEALTHY
    cluster.shutdown()
