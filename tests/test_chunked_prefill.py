"""Chunked prefill through the unified token-budget step loop: A/B token
identity vs monolithic prefill, budget compliance with a long prompt
admitted mid-stream (no head-of-line decode stall), same-step prefix
sharing, MLA, and mid-prefill preemption."""
import numpy as np
import pytest

from repro.config import FAMILY_DECODER, ModelConfig, reduce_config
from repro.configs import get_config
from repro.serving import EngineConfig, SamplingParams, ServingEngine
from repro.serving.request import Phase

MLA_CFG = ModelConfig(name="tiny-mla", family=FAMILY_DECODER, n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                      d_ff=128, vocab_size=256, d_latent=32, d_rope=8)


def test_chunked_vs_monolithic_identical_tokens():
    """Acceptance: the chunked path is token-identical to the monolithic
    prefill path on the same seed/trace (greedy sampling)."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    outs = {}
    for chunked in (False, True):
        eng = ServingEngine(cfg, EngineConfig(max_len=128,
                                              kv_budget_bytes=5e5,
                                              chunked_prefill=chunked,
                                              max_step_tokens=96,
                                              prefill_chunk_tokens=32))
        assert eng.chunked == chunked
        rng = np.random.default_rng(7)
        reqs = []
        for i in range(4):
            toks = [int(t) for t in rng.integers(0, 250, size=48)]
            reqs.append(eng.submit(toks,
                                   params=SamplingParams(max_new_tokens=5)))
        eng.run()
        outs[chunked] = [r.generated for r in reqs]
        if chunked:
            assert eng.prefill_chunks > 0
            assert eng.max_step_prefill_tokens <= 96
        eng.shutdown()
    assert outs[True] == outs[False]
    assert all(len(g) == 5 for g in outs[True])


def test_long_prompt_respects_budget_no_decode_stall():
    """Acceptance: a >=1k-token prompt admitted mid-stream into active
    decodes never pushes more than max_step_tokens prompt tokens through
    a single step, and running decodes keep producing a token every
    step."""
    budget = 192
    cfg = reduce_config(get_config("llama3.2-1b"))
    eng = ServingEngine(cfg, EngineConfig(max_len=1152,
                                          kv_budget_bytes=1.5e6,
                                          max_step_tokens=budget,
                                          prefill_chunk_tokens=64))
    rng = np.random.default_rng(3)
    for _ in range(2):
        eng.submit([int(t) for t in rng.integers(0, 250, size=24)],
                   params=SamplingParams(max_new_tokens=24))
    for _ in range(3):
        eng.step()
    long_req = eng.submit([int(t) for t in rng.integers(0, 250, size=1025)],
                          params=SamplingParams(max_new_tokens=4))
    prefill_steps = 0
    while eng.scheduler.has_work():
        decoding = [r for r in eng.scheduler.running.values()
                    if r.phase is Phase.DECODE]
        before = {r.request_id: len(r.generated) for r in decoding}
        eng.step()
        # the long prompt is chunked across steps, each within budget
        assert eng.last_step_prefill_tokens <= budget
        prefill_steps += eng.last_step_prefill_tokens > 0
        # no head-of-line stall: every request that was decoding when the
        # step began produced exactly one more token
        for r in decoding:
            assert len(r.generated) == before[r.request_id] + 1
    assert len(long_req.generated) == 4
    assert prefill_steps >= (1024 - 128) // budget  # genuinely spread out
    assert eng.max_step_prefill_tokens <= budget
    eng.shutdown()


def test_same_step_shared_prefix_still_hits():
    """Requests sharing a prompt prefix submitted in the same batch get
    prefix hits mid-prefill (the radix re-match at the chunk cursor)."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    eng = ServingEngine(cfg, EngineConfig(max_len=256,
                                          kv_budget_bytes=16e6))
    rng = np.random.default_rng(0)
    system = [int(t) for t in rng.integers(0, 200, size=128)]
    reqs = []
    for i in range(4):
        user = [int(t) for t in rng.integers(0, 200, size=24)]
        reqs.append(eng.submit(system + user,
                               params=SamplingParams(max_new_tokens=3)))
    eng.run()
    assert sum(r.prefix_hit_blocks for r in reqs) > 0
    assert eng.kv.allocator.stats.shares > 0
    eng.shutdown()


def test_mla_chunked_prefill_generates():
    eng = ServingEngine(MLA_CFG, EngineConfig(max_len=256,
                                              kv_budget_bytes=8e6,
                                              max_step_tokens=64,
                                              prefill_chunk_tokens=32))
    assert eng.chunked
    r = eng.submit(list(range(100)), params=SamplingParams(max_new_tokens=4))
    eng.run()
    assert len(r.generated) == 4
    assert eng.prefill_chunks >= 3        # 99 effective tokens, C=32
    eng.shutdown()


def test_mid_prefill_preemption_resumes_cursor():
    """Preempting a request whose chunk cursor is mid-prompt restores
    the partial KV and resumes prefill where it left off — final tokens
    match an uninterrupted run."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    eng = ServingEngine(cfg, EngineConfig(max_len=256,
                                          kv_budget_bytes=32e6,
                                          max_step_tokens=48,
                                          prefill_chunk_tokens=32))
    prompt = list(range(100, 280))
    ref = eng.submit(prompt, params=SamplingParams(max_new_tokens=6))
    eng.run()
    req = eng.submit(prompt, params=SamplingParams(max_new_tokens=6))
    eng.step()
    # the prefix hit plus one budget grant leaves the cursor mid-prompt
    assert req.phase is Phase.PREFILL
    assert 0 < req.prefill_pos < len(prompt) - 1
    eng.preempt(req)
    assert req.request_id in eng._preempted_payloads
    eng.run()
    assert req.generated == ref.generated
    eng.shutdown()


def test_dense_layout_falls_back_to_monolithic():
    cfg = reduce_config(get_config("llama3.2-1b"))
    eng = ServingEngine(cfg, EngineConfig(max_len=128,
                                          kv_budget_bytes=5e5,
                                          paged=False))
    assert not eng.chunked                # no paged pool to chunk into
    r = eng.submit(list(range(48)), params=SamplingParams(max_new_tokens=3))
    eng.run()
    assert len(r.generated) == 3 and eng.prefill_chunks == 0
    eng.shutdown()
