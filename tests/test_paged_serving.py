"""Paged serving path: numerical equivalence of paged vs dense decode
(f32, < 1e-4), engine-level A/B token equality, CoW prefix sharing, and
the async tier-transfer worker."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, FAMILY_DECODER, reduce_config
from repro.configs import get_config
from repro.core.tiers import (AsyncTierTransferWorker, TierHierarchy,
                              TPU_V5E_TIER_SPECS, TransferRequest)
from repro.models.model import build_model
from repro.serving import EngineConfig, SamplingParams, ServingEngine

GQA_CFG = ModelConfig(name="tiny-gqa", family=FAMILY_DECODER, n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=128, vocab_size=256)
MLA_CFG = ModelConfig(name="tiny-mla", family=FAMILY_DECODER, n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                      d_ff=128, vocab_size=256, d_latent=32, d_rope=8)


def _f32_params(model, seed=0):
    params = model.init_params(jax.random.PRNGKey(seed))
    return jax.tree.map(lambda a: a.astype(jnp.float32), params)


def _paged_state_from_prefill(cfg, state, page, max_len):
    """Dense prefill state -> page pool + block table (batch 1, f32)."""
    n_pages_needed = -(-max_len // page)
    n_pages = n_pages_needed + 2                 # page 0 = scratch
    table = np.arange(1, n_pages_needed + 1, dtype=np.int32)[None]
    mla = cfg.attention_variant == "mla"
    key = "latent" if mla else "k"
    L = state[key].shape[0]
    s = state[key].shape[2]
    out = {"block_tables": jnp.asarray(table),
           "lengths": state["lengths"]}
    for src_key, dst_key in ((("latent", "latent_pages"),) if mla else
                             (("k", "k_pages"), ("v", "v_pages"))):
        inner = state[src_key].shape[3:]
        pool = jnp.zeros((L, n_pages, page) + inner, jnp.float32)
        for pi in range(n_pages_needed):
            lo, hi = pi * page, min((pi + 1) * page, s)
            if lo >= s:
                break
            pool = pool.at[:, pi + 1, :hi - lo].set(
                state[src_key][:, 0, lo:hi])
        out[dst_key] = pool
    return out


def _grow(state, max_len):
    def pad(x):
        p = [(0, 0)] * x.ndim
        p[2] = (0, max_len - x.shape[2])
        return jnp.pad(x, p)
    out = dict(state)
    for k in ("k", "v", "latent"):
        if k in state:
            out[k] = pad(state[k])
    return out


@pytest.mark.parametrize("cfg", [GQA_CFG, MLA_CFG], ids=["gqa", "mla"])
def test_paged_decode_matches_dense_1e4(cfg):
    """Acceptance: paged decode logits match the dense path to < 1e-4
    (f32 end to end; page-table indirection is the only difference)."""
    page, max_len, steps = 64, 192, 6
    model = build_model(cfg)
    params = _f32_params(model)
    prompt = jnp.asarray([list(range(10, 106))], jnp.int32)   # 96 tokens
    logits, state = model.prefill(params, {"tokens": prompt})
    pstate = _paged_state_from_prefill(cfg, state, page, max_len)
    dstate = _grow(state, max_len)
    dense_step = jax.jit(model.decode_step)
    paged_step = jax.jit(model.decode_step_paged)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    max_err = 0.0
    for _ in range(steps):
        ld, dstate = dense_step(params, dstate, tok)
        lp, pstate = paged_step(params, pstate, tok)
        max_err = max(max_err, float(jnp.max(jnp.abs(ld - lp))))
        assert jnp.array_equal(jnp.argmax(ld, -1), jnp.argmax(lp, -1))
        tok = jnp.argmax(ld, -1).astype(jnp.int32)
    assert max_err < 1e-4, f"paged vs dense max abs diff {max_err}"


def test_engine_paged_vs_dense_identical_tokens():
    """A/B flag: the same workload generates identical tokens (greedy)
    through the paged and dense engines."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    outs = {}
    for paged in (False, True):
        eng = ServingEngine(cfg, EngineConfig(max_len=128,
                                              kv_budget_bytes=5e5,
                                              paged=paged))
        assert eng.paged == paged
        rng = np.random.default_rng(7)
        reqs = []
        for i in range(4):
            toks = [int(t) for t in rng.integers(0, 250, size=48)]
            reqs.append(eng.submit(toks,
                                   params=SamplingParams(max_new_tokens=5)))
        eng.run()
        outs[paged] = [r.generated for r in reqs]
        eng.shutdown()
    assert outs[True] == outs[False]
    assert all(len(g) == 5 for g in outs[True])


def test_paged_prefix_hit_shares_pages():
    """A radix-prefix hit maps physical pages (CoW) instead of copying,
    and the shared-prefix request decodes the same tokens."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    eng = ServingEngine(cfg, EngineConfig(max_len=256,
                                          kv_budget_bytes=32e6))
    prompt = list(range(30, 158)) + [5, 6, 7] * 6       # >1 full block
    r1 = eng.submit(prompt, params=SamplingParams(max_new_tokens=4))
    eng.run()
    shares_before = eng.kv.allocator.stats.shares
    r2 = eng.submit(prompt, params=SamplingParams(max_new_tokens=4))
    eng.run()
    assert r2.prefix_hit_blocks > 0
    assert eng.kv.allocator.stats.shares > shares_before
    assert r1.generated == r2.generated
    eng.shutdown()


def test_mla_engine_paged_generates():
    eng = ServingEngine(MLA_CFG, EngineConfig(max_len=256,
                                              kv_budget_bytes=8e6))
    assert eng.paged
    r = eng.submit(list(range(100)), params=SamplingParams(max_new_tokens=4))
    eng.run()
    assert len(r.generated) == 4
    eng.shutdown()


# ---------------------------------------------------------------------------
# async tier transfers
# ---------------------------------------------------------------------------
def test_async_worker_demote_fetch_roundtrip():
    hier = TierHierarchy(TPU_V5E_TIER_SPECS)
    w = AsyncTierTransferWorker(hier)
    payload = np.arange(16, dtype=np.float32)
    w.submit(TransferRequest("b0", 0, 1, kind="demote", payload=payload,
                             nbytes=float(payload.nbytes), tag="1"))
    assert w.drain(5.0)
    (ev,) = w.poll()
    assert ev.ok and ev.sim_time > 0
    assert hier[1].contains("b0")

    w.submit(TransferRequest("b0", 1, 0, kind="fetch", evict_src=True,
                             tag="1"))
    assert w.drain(5.0)
    (ev,) = w.poll()
    assert ev.ok
    np.testing.assert_array_equal(ev.payload, payload)
    assert not hier[1].contains("b0")

    # failure surfaces as an event, not an exception
    w.submit(TransferRequest("missing", 1, 0, kind="fetch"))
    assert w.drain(5.0)
    (ev,) = w.poll()
    assert not ev.ok and ev.error
    st = w.stats()
    assert st["completed"] == 3 and st["failed"] == 1
    assert st["in_flight"] == 0
    w.close()


def test_double_preemption_epochs_keep_latest_payload():
    """preempt -> restore-from-buffer -> preempt again: the stale first
    demote's completion event must not release the second epoch's
    staging buffer (ticket correlation), and the final restore decodes
    the same tokens as an uninterrupted run."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    eng = ServingEngine(cfg, EngineConfig(max_len=256,
                                          kv_budget_bytes=32e6))
    prompt = list(range(60, 188))
    ref = eng.submit(prompt, params=SamplingParams(max_new_tokens=8))
    eng.run()
    req = eng.submit(prompt, params=SamplingParams(max_new_tokens=8))
    eng.step()
    eng.preempt(req)                             # epoch 1: demote #1
    t1 = eng._demote_tickets[req.request_id]
    # restore from the staging buffer WITHOUT polling the worker first
    # (the demote #1 event stays queued — the stale-epoch case)
    (r,) = eng.scheduler.admissible(1)
    assert r is req
    eng._admit(req, eng.kv.acquire(req.request_id, req.prompt_len))
    eng.step()
    eng.preempt(req)                             # epoch 2: demote #2
    t2 = eng._demote_tickets[req.request_id]
    assert t2 != t1
    assert eng.worker.drain(5.0)
    eng._poll_transfers()                        # both events arrive
    # the buffer release was driven by the epoch-2 event, not the stale one
    assert eng._preempted_payloads[req.request_id][0] is None
    eng.run()
    assert req.generated == ref.generated
    eng.shutdown()


def test_async_preempt_demote_then_restore():
    """Preemption demotes off the step loop; once the write lands the
    staging buffer is dropped and restore becomes an async tier fetch —
    decode output is unchanged either way."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    eng = ServingEngine(cfg, EngineConfig(max_len=256,
                                          kv_budget_bytes=32e6))
    prompt = list(range(40, 168))
    ref = eng.submit(prompt, params=SamplingParams(max_new_tokens=8))
    eng.run()
    req = eng.submit(prompt, params=SamplingParams(max_new_tokens=8))
    eng.step()
    eng.preempt(req)
    assert req.request_id in eng._preempted_payloads
    assert eng.worker.drain(5.0)          # demotion completed off-loop
    eng._poll_transfers()
    assert eng._preempted_payloads[req.request_id][0] is None
    eng.run()                             # async fetch -> restore -> finish
    assert req.generated == ref.generated
    stats = eng.stats()
    assert stats["scheduler"]["async_restores"] >= 1
    assert stats["async_transfers"]["completed"] >= 2
    assert stats["async_transfers"]["failed"] == 0
    eng.shutdown()
