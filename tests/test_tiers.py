"""Tier hierarchy: capacity invariants, moves, failure, hash ring,
and the fleet-shared tier-4 namespace."""
import numpy as np
import pytest

try:        # property tests skip individually when hypothesis is absent;
    #         the example-based tests below always run
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                 # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    settings = given

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from repro.core.tiers import (PAPER_TIER_SPECS, CapacityError,
                              ConsistentHashRing, FleetKVStore, RDMATier,
                              SharedTierView, TierHierarchy, TierManager,
                              TierSpec)


def small_specs(cap=10 * 100.0):
    return tuple(
        TierSpec(s.tier_id, s.name, s.bandwidth, s.latency,
                 s.cost_per_gb_hour, cap * (s.tier_id + 1))
        for s in PAPER_TIER_SPECS)


def test_capacity_enforced():
    t = TierManager(TierSpec(0, "x", 1e9, 1e-6, 0.1, 100.0))
    t.allocate("a", 60)
    with pytest.raises(CapacityError):
        t.allocate("b", 60)
    t.evict("a")
    t.allocate("b", 60)


def test_paper_capacity_ladder():
    h = TierHierarchy()
    # Table IV cumulative capacities: 40 GB -> 200 -> 712 -> ~4.7T -> 38T+
    gb = 1024 ** 3
    assert h.capacity_through(0) / gb == pytest.approx(40)
    assert h.capacity_through(1) / gb == pytest.approx(200)
    assert h.capacity_through(2) / gb == pytest.approx(712)
    assert h.capacity_through(3) / (1024 ** 4) == pytest.approx(4.695, rel=.01)
    assert h.capacity_through(4) / (1024 ** 4) > 38


def test_move_and_locate():
    h = TierHierarchy(small_specs())
    h[0].write("blk", None, nbytes=50)
    assert h.locate("blk") == 0
    h.move("blk", 0, 3)
    assert h.locate("blk") == 3
    assert h[0].used == 0 and h[3].used == 50


def test_tier_failure_redistributes():
    h = TierHierarchy(small_specs())
    for i in range(5):
        h[2].write(f"b{i}", None, nbytes=50)
    lost = h.fail_tier(2)
    assert not h[2].available
    assert lost == []                       # everything re-homed
    for i in range(5):
        assert h.locate(f"b{i}") is not None
    h.restore_tier(2)
    assert h[2].available


# ---------------------------------------------------------------------------
# RDMA node failure: re-home, don't lose
# ---------------------------------------------------------------------------
def test_rdma_node_failure_rehomes_displaced_blocks():
    spec = TierSpec(4, "rdma", 50e9, 5e-6, .005, 1e9)
    t = RDMATier(spec, nodes=[f"n{i}" for i in range(4)])
    for i in range(64):
        t.allocate(f"b{i}", 100.0)
    victim = t.placement("b0")
    n_displaced = len(t._node_store[victim])
    lost = t.fail_node(victim)
    # with survivors on the ring nothing is lost: every displaced block
    # re-homes through the ring (one re-replication write each)
    assert lost == []
    assert t.rehomed_blocks == n_displaced
    for i in range(64):
        assert t.contains(f"b{i}")
        assert t.placement(f"b{i}") != victim


def test_rdma_last_node_failure_loses_blocks():
    spec = TierSpec(4, "rdma", 50e9, 5e-6, .005, 1e9)
    t = RDMATier(spec, nodes=["n0"])
    for i in range(8):
        t.allocate(f"b{i}", 100.0)
    lost = t.fail_node("n0")
    assert sorted(lost) == sorted(f"b{i}" for i in range(8))
    assert t.used == 0


# ---------------------------------------------------------------------------
# Consistent hash ring properties
# ---------------------------------------------------------------------------
@given(st.sets(st.text(min_size=1, max_size=8), min_size=2, max_size=12),
       st.lists(st.text(min_size=1, max_size=16), min_size=1, max_size=50))
@settings(max_examples=30, deadline=None)
def test_ring_remap_minimal(nodes, keys):
    """Consistent hashing: removing one node only remaps its own keys."""
    ring = ConsistentHashRing(sorted(nodes))
    before = {k: ring.lookup(k) for k in keys}
    victim = sorted(nodes)[0]
    ring.remove_node(victim)
    for k in keys:
        if before[k] != victim:
            assert ring.lookup(k) == before[k]


@given(st.sets(st.text(min_size=1, max_size=8), min_size=2, max_size=10),
       st.text(max_size=6))
@settings(max_examples=30, deadline=None)
def test_ring_lookup_deterministic_under_fixed_salt(nodes, salt):
    """Same node set (any insertion order) + same salted key -> same
    owner, across independently built rings and repeated lookups."""
    keys = [f"{salt}:k{i}" for i in range(40)]
    a = ConsistentHashRing(sorted(nodes))
    b = ConsistentHashRing(sorted(nodes, reverse=True))
    for k in keys:
        assert a.lookup(k) == b.lookup(k)
        assert a.lookup(k) == a.lookup(k)
        assert a.lookup(k) in nodes


@given(st.sets(st.text(min_size=1, max_size=8), min_size=2, max_size=10))
@settings(max_examples=20, deadline=None)
def test_ring_add_node_remaps_about_one_nth(nodes):
    """Joining a node steals ~1/n of the key space — and every remapped
    key lands ON the joiner (no survivor-to-survivor reshuffle)."""
    keys = [f"key{i}" for i in range(600)]
    ring = ConsistentHashRing(sorted(nodes))
    before = {k: ring.lookup(k) for k in keys}
    joiner = "zz-joiner"
    ring.add_node(joiner)
    remapped = [k for k in keys if ring.lookup(k) != before[k]]
    # no survivor reshuffle: a key either stays put or moves to the joiner
    assert all(ring.lookup(k) == joiner for k in remapped)
    # ~1/(n+1) expectation; generous slack for 64-vnode placement variance
    n_after = len(nodes) + 1
    assert len(remapped) / len(keys) <= 3.0 / n_after + 0.05


@given(st.sets(st.text(min_size=1, max_size=8), min_size=3, max_size=10))
@settings(max_examples=20, deadline=None)
def test_ring_remove_node_remaps_only_its_share(nodes):
    """Leaving remaps only the victim's keys (~1/n of the space), and
    survivors keep every key they already owned."""
    keys = [f"key{i}" for i in range(600)]
    ring = ConsistentHashRing(sorted(nodes))
    before = {k: ring.lookup(k) for k in keys}
    victim = sorted(nodes)[-1]
    owned = [k for k in keys if before[k] == victim]
    ring.remove_node(victim)
    for k in keys:
        if before[k] != victim:
            assert ring.lookup(k) == before[k]   # survivors undisturbed
    assert len(owned) / len(keys) <= 3.0 / len(nodes) + 0.05


def test_ring_balance():
    ring = ConsistentHashRing([f"n{i}" for i in range(8)], vnodes=128)
    from collections import Counter
    c = Counter(ring.lookup(f"key{i}") for i in range(4000))
    assert max(c.values()) / min(c.values()) < 2.5


# ---------------------------------------------------------------------------
# Fleet-shared tier 4: dedup, refcounts, no stranded references
# ---------------------------------------------------------------------------
def _fleet(cap=1000.0):
    spec = TierSpec(4, "rdma", 50e9, 5e-6, .005, cap)
    return FleetKVStore(spec, nodes=("n0", "n1"))


def _payload(seed=0):
    return np.full((4,), seed, dtype=np.float32)


def test_shared_block_occupies_fleet_bytes_once():
    """A block interned by two replicas lives in the fleet tier once:
    the second publish is a ref bump, not a second copy."""
    store = _fleet()
    va = SharedTierView(store, "replicaA", resolve_key=lambda b: "c:h1")
    vb = SharedTierView(store, "replicaB", resolve_key=lambda b: "c:h1")
    va.write("blkA", _payload(1), nbytes=100.0)
    used_after_first = store.tier.used
    vb.write("blkB", _payload(1), nbytes=100.0)
    assert store.tier.used == used_after_first == 100.0
    assert store.ref_count("c:h1") == 2
    assert store.publishes == 1 and store.dedup_publishes == 1
    # per-owner accounting stays owner-scoped
    assert va.used == 100.0 and vb.used == 100.0


def test_refcount_survives_one_replicas_teardown():
    """One replica's teardown (failover release_all -> view evictions)
    releases only ITS reference; the survivor still reads the block."""
    store = _fleet()
    va = SharedTierView(store, "replicaA", resolve_key=lambda b: "c:h1")
    vb = SharedTierView(store, "replicaB", resolve_key=lambda b: "c:h1")
    va.write("blkA", _payload(7), nbytes=100.0)
    vb.write("blkB", None, nbytes=100.0)
    va.evict("blkA")                       # replica A dies
    assert va.used == 0
    assert store.ref_count("c:h1") == 1
    payload, _ = store.fetch("c:h1")
    assert payload is not None and payload[0] == 7
    # survivor's own read path still works
    got, _ = vb.read("blkB")
    assert got is not None and got[0] == 7


def test_zero_ref_keys_stay_resident_until_pressure():
    """Fully released keys stay resident (cross-replica prefix cache)
    and are reclaimed lazily, oldest-first, under capacity pressure."""
    store = _fleet(cap=250.0)
    v = SharedTierView(store, "replicaA")
    v.write("b0", None, nbytes=100.0)
    v.write("b1", None, nbytes=100.0)
    v.evict("b0")
    assert store.contains_key("replicaA:b0")     # cached, zero-ref
    # needs room: the zero-ref key goes, the live-ref key stays
    v.write("b2", None, nbytes=100.0)
    assert not store.contains_key("replicaA:b0")
    assert store.contains_key("replicaA:b1")
    assert store.evicted_cold == 1


def test_eviction_never_strands_a_live_reference():
    """Capacity pressure must never reclaim a key another replica still
    references — writes fail before live refs are touched."""
    store = _fleet(cap=200.0)
    va = SharedTierView(store, "replicaA", resolve_key=lambda b: f"c:{b}")
    vb = SharedTierView(store, "replicaB", resolve_key=lambda b: f"c:{b}")
    va.write("h1", _payload(1), nbytes=100.0)
    vb.write("h1", _payload(1), nbytes=100.0)    # shared, refs=2
    va.write("h2", _payload(2), nbytes=100.0)    # full: 200/200, all live
    with pytest.raises(CapacityError):
        vb.write("h3", _payload(3), nbytes=100.0)
    # every live reference still resolves
    assert store.ref_count("c:h1") == 2
    assert store.ref_count("c:h2") == 1
    assert store.contains_key("c:h1") and store.contains_key("c:h2")
    assert store.evicted_cold == 0


def test_fleet_node_failure_rehomes_shared_blocks():
    store = _fleet()
    v = SharedTierView(store, "replicaA")
    for i in range(16):
        v.write(f"b{i}", None, nbytes=10.0)
    lost = store.fail_node("n0")
    assert lost == []
    assert store.stats()["rehomed_blocks"] > 0
    for i in range(16):
        assert store.contains_key(f"replicaA:b{i}")
