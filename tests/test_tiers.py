"""Tier hierarchy: capacity invariants, moves, failure, hash ring."""
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.tiers import (PAPER_TIER_SPECS, CapacityError,
                              ConsistentHashRing, RDMATier, TierHierarchy,
                              TierManager, TierSpec)


def small_specs(cap=10 * 100.0):
    return tuple(
        TierSpec(s.tier_id, s.name, s.bandwidth, s.latency,
                 s.cost_per_gb_hour, cap * (s.tier_id + 1))
        for s in PAPER_TIER_SPECS)


def test_capacity_enforced():
    t = TierManager(TierSpec(0, "x", 1e9, 1e-6, 0.1, 100.0))
    t.allocate("a", 60)
    with pytest.raises(CapacityError):
        t.allocate("b", 60)
    t.evict("a")
    t.allocate("b", 60)


def test_paper_capacity_ladder():
    h = TierHierarchy()
    # Table IV cumulative capacities: 40 GB -> 200 -> 712 -> ~4.7T -> 38T+
    gb = 1024 ** 3
    assert h.capacity_through(0) / gb == pytest.approx(40)
    assert h.capacity_through(1) / gb == pytest.approx(200)
    assert h.capacity_through(2) / gb == pytest.approx(712)
    assert h.capacity_through(3) / (1024 ** 4) == pytest.approx(4.695, rel=.01)
    assert h.capacity_through(4) / (1024 ** 4) > 38


def test_move_and_locate():
    h = TierHierarchy(small_specs())
    h[0].write("blk", None, nbytes=50)
    assert h.locate("blk") == 0
    h.move("blk", 0, 3)
    assert h.locate("blk") == 3
    assert h[0].used == 0 and h[3].used == 50


def test_tier_failure_redistributes():
    h = TierHierarchy(small_specs())
    for i in range(5):
        h[2].write(f"b{i}", None, nbytes=50)
    lost = h.fail_tier(2)
    assert not h[2].available
    assert lost == []                       # everything re-homed
    for i in range(5):
        assert h.locate(f"b{i}") is not None
    h.restore_tier(2)
    assert h[2].available


def test_rdma_node_failure_loses_only_its_blocks():
    spec = TierSpec(4, "rdma", 50e9, 5e-6, .005, 1e9)
    t = RDMATier(spec, nodes=[f"n{i}" for i in range(4)])
    for i in range(64):
        t.allocate(f"b{i}", 100.0)
    victim = t.placement("b0")
    lost = t.fail_node(victim)
    assert "b0" in lost
    assert all(t.placement(f"b{i}") != victim for i in range(64)
               if t.contains(f"b{i}"))


@given(st.sets(st.text(min_size=1, max_size=8), min_size=2, max_size=12),
       st.lists(st.text(min_size=1, max_size=16), min_size=1, max_size=50))
@settings(max_examples=30, deadline=None)
def test_ring_remap_minimal(nodes, keys):
    """Consistent hashing: removing one node only remaps its own keys."""
    ring = ConsistentHashRing(sorted(nodes))
    before = {k: ring.lookup(k) for k in keys}
    victim = sorted(nodes)[0]
    ring.remove_node(victim)
    for k in keys:
        if before[k] != victim:
            assert ring.lookup(k) == before[k]


def test_ring_balance():
    ring = ConsistentHashRing([f"n{i}" for i in range(8)], vnodes=128)
    from collections import Counter
    c = Counter(ring.lookup(f"key{i}") for i in range(4000))
    assert max(c.values()) / min(c.values()) < 2.5
