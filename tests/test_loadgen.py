"""Property tests (hypothesis) for the open-loop Poisson load
generator and the SLO admission pure functions.

Everything here is pure (no engine, no JAX): the Poisson process is a
function of ``(rate, seed)`` and ``admission_decision`` of an
``AdmissionSnapshot`` — so the properties hold with no timing races.
"""
import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.serving.frontend import (ADMIT, QUEUE, SHED, AdmissionSnapshot,
                                    SLOConfig, admission_decision,
                                    projected_ttft_s)
from repro.traces.loadgen import PoissonLoadGen

RATES = st.floats(min_value=0.1, max_value=500.0,
                  allow_nan=False, allow_infinity=False)
SEEDS = st.integers(min_value=0, max_value=2**32 - 1)


# ---------------------------------------------------------------------------
# Poisson process properties
# ---------------------------------------------------------------------------
@given(rate=RATES, seed=SEEDS)
@settings(max_examples=25, deadline=None)
def test_seeded_reproducibility(rate, seed):
    a = PoissonLoadGen(rate, seed=seed).arrival_times(n=50)
    b = PoissonLoadGen(rate, seed=seed).arrival_times(n=50)
    assert a == b


@given(rate=RATES, seed=SEEDS)
@settings(max_examples=25, deadline=None)
def test_monotone_timestamps(rate, seed):
    ts = PoissonLoadGen(rate, seed=seed).arrival_times(n=100)
    assert all(b >= a for a, b in zip(ts, ts[1:]))
    assert all(t > 0 for t in ts)


@given(rate=st.floats(min_value=1.0, max_value=100.0), seed=SEEDS)
@settings(max_examples=15, deadline=None)
def test_mean_interarrival_close_to_reciprocal_rate(rate, seed):
    n = 4000
    gaps = PoissonLoadGen(rate, seed=seed).interarrivals(n)
    mean = float(gaps.mean())
    # CLT tolerance: exponential sd == mean, so sample mean is within
    # ~5 sigma/sqrt(n) of 1/rate essentially always
    assert abs(mean - 1.0 / rate) < 5.0 / (rate * math.sqrt(n))


@given(rate=RATES, seed=SEEDS,
       duration=st.floats(min_value=0.01, max_value=10.0))
@settings(max_examples=25, deadline=None)
def test_duration_mode_bounds_all_arrivals(rate, seed, duration):
    ts = PoissonLoadGen(rate, seed=seed).arrival_times(duration_s=duration)
    assert all(0 < t < duration for t in ts)


def test_rejects_bad_arguments():
    with pytest.raises(ValueError):
        PoissonLoadGen(0.0)
    with pytest.raises(ValueError):
        PoissonLoadGen(10.0).arrival_times()
    with pytest.raises(ValueError):
        PoissonLoadGen(10.0).arrival_times(n=5, duration_s=1.0)


# ---------------------------------------------------------------------------
# SLO admission properties
# ---------------------------------------------------------------------------
SNAPS = st.builds(
    AdmissionSnapshot,
    pending_prefill_tokens=st.integers(min_value=0, max_value=10**6),
    queued_prefill_tokens=st.integers(min_value=0, max_value=10**6),
    queue_len=st.integers(min_value=0, max_value=10**4),
    live_decodes=st.integers(min_value=0, max_value=10**3),
    free_slots=st.integers(min_value=0, max_value=256),
    est_step_s=st.floats(min_value=1e-6, max_value=1.0))
SLOS = st.builds(
    SLOConfig,
    ttft_budget_s=st.one_of(st.just(float("inf")),
                            st.floats(min_value=1e-4, max_value=10.0)),
    action=st.sampled_from([SHED, QUEUE]),
    max_queue=st.integers(min_value=0, max_value=128))
PROMPTS = st.integers(min_value=1, max_value=10**4)
BUDGETS = st.integers(min_value=1, max_value=1024)


@given(prompt=PROMPTS, snap=SNAPS, slo=SLOS, mst=BUDGETS)
@settings(max_examples=200, deadline=None)
def test_decision_is_deterministic_and_closed(prompt, snap, slo, mst):
    d1 = admission_decision(prompt, snap, slo, mst)
    d2 = admission_decision(prompt, snap, slo, mst)
    assert d1 == d2
    assert d1 in (ADMIT, QUEUE, SHED)


@given(prompt=PROMPTS, snap=SNAPS, mst=BUDGETS)
@settings(max_examples=100, deadline=None)
def test_infinite_budget_always_admits(prompt, snap, mst):
    assert admission_decision(prompt, snap, SLOConfig(), mst) == ADMIT


@given(prompt=PROMPTS, slo=SLOS, mst=BUDGETS,
       step=st.floats(min_value=1e-6, max_value=1.0))
@settings(max_examples=100, deadline=None)
def test_never_sheds_below_rate_floor(prompt, slo, mst, step):
    """An idle system (no backlog, empty queue, no live decodes) always
    admits — shedding can never push throughput below the sequential
    service rate, whatever the budget."""
    idle = AdmissionSnapshot(pending_prefill_tokens=0,
                             queued_prefill_tokens=0, queue_len=0,
                             live_decodes=0, free_slots=1, est_step_s=step)
    assert admission_decision(prompt, idle, slo, mst) == ADMIT


@given(prompt=PROMPTS, snap=SNAPS, mst=BUDGETS,
       budget=st.floats(min_value=1e-4, max_value=10.0),
       max_queue=st.integers(min_value=0, max_value=64))
@settings(max_examples=200, deadline=None)
def test_queue_is_bounded(prompt, snap, mst, budget, max_queue):
    """QUEUE is only ever returned while the queue has room — the
    front-end queue length can never exceed ``max_queue``."""
    slo = SLOConfig(ttft_budget_s=budget, action=QUEUE,
                    max_queue=max_queue)
    if admission_decision(prompt, snap, slo, mst) == QUEUE:
        assert snap.queue_len < max_queue


@given(prompt=PROMPTS, snap=SNAPS, mst=BUDGETS,
       budget=st.floats(min_value=1e-4, max_value=10.0))
@settings(max_examples=200, deadline=None)
def test_admit_iff_projection_within_budget_under_load(prompt, snap, mst,
                                                       budget):
    """On a non-idle system the admit/deny boundary is exactly the
    projected-TTFT-vs-budget comparison (pure, no hidden state)."""
    slo = SLOConfig(ttft_budget_s=budget, action=SHED)
    idle = (snap.pending_prefill_tokens == 0 and snap.queue_len == 0
            and snap.live_decodes == 0)
    decision = admission_decision(prompt, snap, slo, mst)
    if idle:
        assert decision == ADMIT
    elif projected_ttft_s(prompt, snap, mst) <= budget:
        assert decision == ADMIT
    else:
        assert decision == SHED


@given(prompt=PROMPTS, snap=SNAPS, mst=BUDGETS)
@settings(max_examples=100, deadline=None)
def test_projection_monotone_in_backlog(prompt, snap, mst):
    """More backlog never projects a *smaller* TTFT."""
    heavier = AdmissionSnapshot(
        pending_prefill_tokens=snap.pending_prefill_tokens + 1000,
        queued_prefill_tokens=snap.queued_prefill_tokens,
        queue_len=snap.queue_len, live_decodes=snap.live_decodes,
        free_slots=snap.free_slots, est_step_s=snap.est_step_s)
    assert (projected_ttft_s(prompt, heavier, mst)
            >= projected_ttft_s(prompt, snap, mst))
