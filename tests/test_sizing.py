"""Architecture-aware sizing engine: exact paper-table reproduction +
property-based invariants."""
import math

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.config import ModelConfig, FAMILY_DECODER
from repro.configs.paper_models import (DEEPSEEK_V3, LLAMA3_70B,
                                        MIXTRAL_8X22B, QWEN2_5_72B)
from repro.core import sizing


# --- paper Table I (exact) -------------------------------------------------
@pytest.mark.parametrize("cfg,mha,actual", [
    (DEEPSEEK_V3, 65536, 1152),
    (LLAMA3_70B, 32768, 4096),
    (MIXTRAL_8X22B, 24576, 4096),
    (QWEN2_5_72B, 32768, 4096),
])
def test_table_i_exact(cfg, mha, actual):
    assert sizing.mha_equivalent_bytes(cfg) == mha
    assert sizing.per_token_layer_bytes(cfg) == actual


# --- paper Table III (exact) ------------------------------------------------
@pytest.mark.parametrize("cfg,sq,aa", [
    (DEEPSEEK_V3, 14, 104),
    (LLAMA3_70B, 22, 22),
    (MIXTRAL_8X22B, 42, 31),
    (QWEN2_5_72B, 22, 22),
])
def test_table_iii_exact(cfg, sq, aa):
    assert sizing.status_quo_max_batch(cfg, 30e9, 4096, tp=8) == sq
    assert sizing.max_batch(cfg, 30e9, 4096) == aa


def test_mla_57x_compression():
    r = sizing.sizing_report(DEEPSEEK_V3)
    assert 56.0 < r.compression < 58.0
    assert r.variant == "mla"


# --- properties -------------------------------------------------------------
@st.composite
def arch_configs(draw):
    hd = draw(st.sampled_from([32, 64, 128]))
    hq = draw(st.integers(1, 64))
    hkv = draw(st.integers(1, hq).filter(lambda k: hq % k == 0))
    return ModelConfig(
        name="t", family=FAMILY_DECODER,
        n_layers=draw(st.integers(1, 100)), d_model=hq * hd,
        n_heads=hq, n_kv_heads=hkv, head_dim=hd,
        d_ff=128, vocab_size=1000)


@given(arch_configs(), st.integers(1, 1 << 20))
@settings(max_examples=100, deadline=None)
def test_sizing_monotone_and_bounded(cfg, n):
    b = sizing.per_token_layer_bytes(cfg)
    assert 0 < b <= sizing.mha_equivalent_bytes(cfg)
    assert sizing.seq_bytes(cfg, n) == cfg.n_layers * b * n
    # arch-aware batch >= status-quo for any non-MHA variant at tp=1
    if cfg.attention_variant != "mha":
        assert sizing.max_batch(cfg, 1e9, 128) >= \
            sizing.status_quo_max_batch(cfg, 1e9, 128, tp=1)


@given(arch_configs())
@settings(max_examples=50, deadline=None)
def test_variant_inference(cfg):
    v = cfg.attention_variant
    if cfg.n_kv_heads == cfg.n_heads:
        assert v == "mha"
    elif cfg.n_kv_heads == 1:
        assert v == "mqa"
    else:
        assert v == "gqa"


@given(st.floats(0.25, 4.0), arch_configs())
@settings(max_examples=50, deadline=None)
def test_quantized_precision_scales_linearly(p, cfg):
    base = sizing.per_token_layer_bytes(cfg, p=2)
    assert sizing.per_token_layer_bytes(cfg, p=2 * p) == \
        pytest.approx(base * p)
