"""Tests and benches must see ONE device: the 512-device virtualization
belongs exclusively to launch/dryrun.py (assignment requirement)."""
import os


def pytest_configure(config):
    flags = os.environ.get("XLA_FLAGS", "")
    assert "host_platform_device_count" not in flags, (
        "XLA_FLAGS device-count virtualization must not leak into tests")
    config.addinivalue_line(
        "markers", "slow: multi-minute end-to-end runs (CPU interpret "
        "mode); deselect with -m 'not slow'")
