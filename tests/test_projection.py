"""Analytical projection engine: anchor reproduction + monotonicity."""
import pytest

from repro.core.projection import (ANCHOR_TPUT, ANCHOR_TTFT_P99,
                                   Projector)


@pytest.fixture(scope="module")
def proj():
    return Projector()


def test_gpu_only_anchors(proj):
    r = proj.project(1, name="gpu-only")
    assert r.tput_tok_s_gpu == pytest.approx(ANCHOR_TPUT)
    assert r.ttft_p99 == pytest.approx(ANCHOR_TTFT_P99, rel=0.15)


def test_tput_monotone_in_tiers(proj):
    tputs = [proj.project(n).tput_tok_s_gpu for n in range(1, 7)]
    assert all(b >= a - 1e-6 for a, b in zip(tputs, tputs[1:]))


def test_full_system_in_paper_band(proj):
    r = proj.project(6)
    # paper: 1.7-2.9x throughput improvement; 4,150 tok/s/GPU
    gain = r.tput_tok_s_gpu / ANCHOR_TPUT
    assert 1.7 <= gain <= 3.1
    assert 0.3 <= r.cost_per_mtok <= 0.7         # paper: $0.43


def test_predictive_beats_reactive(proj):
    pred = proj.project(6, predictive=True)
    reac = proj.project(6, predictive=False)
    assert pred.tput_tok_s_gpu > reac.tput_tok_s_gpu
    assert pred.ttft_p99 < reac.ttft_p99


def test_higher_hit_rate_helps(proj):
    hi = proj.project(6, hit_rate=0.9)
    lo = proj.project(6, hit_rate=0.5)
    assert hi.tput_tok_s_gpu > lo.tput_tok_s_gpu
