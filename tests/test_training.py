"""Training substrate: loss decreases, checkpoint/restart bit-exactness,
resume equivalence (fault tolerance), gradient compression, ZeRO-1."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import reduce_config
from repro.configs import get_config
from repro.models import build_model
from repro.training import checkpoint as ckpt_mod
from repro.training import data as data_mod
from repro.training import optimizer as opt_mod
from repro.training import train as train_mod
from repro.training.optimizer import AdamWConfig


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    data = data_mod.SyntheticLM(data_mod.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=4))
    return cfg, model, params, data


_STEP_FNS = {}


def _run(model, params, data, steps, n_micro=1, compress=False,
         start=0, opt_state=None):
    # memoize the jitted step per (model, n_micro, compress): every
    # fresh jax.jit(make_train_step(...)) wrapper re-traces, and the
    # compile dominated this module's wall clock
    key = (id(model), n_micro, compress)
    step_fn = _STEP_FNS.get(key)
    if step_fn is None:
        step_fn = jax.jit(train_mod.make_train_step(
            model, adamw=AdamWConfig(lr=1e-3, total_steps=100,
                                     warmup_steps=2),
            n_micro=n_micro, grad_compress=compress))
        _STEP_FNS[key] = step_fn
    opt_state = opt_mod.init_state(params) if opt_state is None \
        else opt_state
    losses = []
    for s in range(start, start + steps):
        raw = data.batch(s)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
    return params, opt_state, losses


def test_loss_decreases(setup):
    cfg, model, params, data = setup
    _, _, losses = _run(model, params, data, 12)
    assert losses[-1] < losses[0]


def test_microbatching_matches_full_batch(setup):
    cfg, model, params, data = setup
    p1, _, l1 = _run(model, params, data, 3, n_micro=1)
    p2, _, l2 = _run(model, params, data, 3, n_micro=2)
    # grad accumulation == full batch (up to bf16 accumulation noise)
    np.testing.assert_allclose(l1, l2, rtol=2e-2, atol=2e-2)


def test_grad_compression_close_to_exact(setup):
    cfg, model, params, data = setup
    _, _, l1 = _run(model, params, data, 5, compress=False)
    _, _, l2 = _run(model, params, data, 5, compress=True)
    np.testing.assert_allclose(l1, l2, rtol=0.1, atol=0.1)


def test_checkpoint_roundtrip_bitexact(setup, tmp_path):
    cfg, model, params, data = setup
    p1, o1, _ = _run(model, params, data, 2)
    mgr = ckpt_mod.CheckpointManager(str(tmp_path))
    mgr.save(2, (p1, o1))
    (p2, o2), manifest = mgr.restore((p1, o1))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        assert jnp.array_equal(a, b)
    for a, b in zip(jax.tree.leaves(o1), jax.tree.leaves(o2)):
        assert jnp.array_equal(a, b)


def test_delta_checkpoint_skips_unchanged(setup, tmp_path):
    cfg, model, params, data = setup
    mgr = ckpt_mod.CheckpointManager(str(tmp_path))
    o = opt_mod.init_state(params)
    m1 = mgr.save(1, (params, o))
    m2 = mgr.save(2, (params, o))       # identical state
    assert m2["delta"]["new_bytes"] == 0
    assert m2["delta"]["reused_bytes"] > 0


def test_crash_resume_equivalence(setup, tmp_path):
    """Train 6 straight == train 3, 'crash', restore, train 3 more."""
    cfg, model, params, data = setup
    pa, oa, _ = _run(model, params, data, 6)
    p1, o1, _ = _run(model, params, data, 3)
    mgr = ckpt_mod.CheckpointManager(str(tmp_path))
    mgr.save(3, (p1, o1))
    (p1r, o1r), _ = mgr.restore((p1, o1))
    pb, ob, _ = _run(model, p1r, data, 3, start=3, opt_state=o1r)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        assert jnp.array_equal(a, b), "resume diverged from straight run"


def test_data_pipeline_deterministic_and_seekable(setup):
    cfg, model, params, data = setup
    b1 = data.batch(7)
    b2 = data.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    it = data.iterate(start_step=7)
    np.testing.assert_array_equal(next(it)["tokens"], b1["tokens"])


def test_lr_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(opt_mod.lr_schedule(cfg, jnp.asarray(s)))
           for s in (0, 5, 10, 50, 99)]
    assert lrs[0] < lrs[1] < lrs[2]
    assert lrs[2] >= lrs[3] >= lrs[4]
    assert lrs[4] >= 0.09 * cfg.lr
