"""Serving engine end-to-end: determinism, prefix reuse, preemption,
cluster failover, sizing-driven admission."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import reduce_config
from repro.configs import get_config
# the pre-promotion import location must keep working (launch/serve.py
# re-exports from serving/cluster.py)
from repro.launch.serve import ReplicaCluster
from repro.serving import EngineConfig, SamplingParams, ServingEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = reduce_config(get_config("llama3.2-1b"))
    eng = ServingEngine(cfg, EngineConfig(max_len=256,
                                          kv_budget_bytes=32e6))
    return cfg, eng


def test_engine_matches_reference_decode(engine_setup):
    cfg, eng = engine_setup
    prompt = list(range(100, 228)) + [1, 2, 3, 4] * 4
    req = eng.submit(prompt, params=SamplingParams(max_new_tokens=6))
    eng.run()
    m, params = eng.model, eng.params
    logits, state = jax.jit(m.prefill)(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)})
    def grow(x, n):
        pad = [(0, 0)] * x.ndim
        pad[2] = (0, n - x.shape[2])
        return jnp.pad(x, pad)
    state = {"k": grow(state["k"], 256), "v": grow(state["v"], 256),
             "lengths": state["lengths"]}
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    expected = []
    for _ in range(6):
        expected.append(int(tok[0]))
        lg, state = jax.jit(m.decode_step)(params, state, tok)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
    assert req.generated == expected


def test_prefix_reuse_preserves_output(engine_setup):
    cfg, eng = engine_setup
    prompt = list(range(300, 428)) + [9, 8, 7] * 6
    r1 = eng.submit(prompt, params=SamplingParams(max_new_tokens=5))
    eng.run()
    r2 = eng.submit(prompt, params=SamplingParams(max_new_tokens=5))
    eng.run()
    assert r2.prefix_hit_blocks > 0
    assert r1.generated == r2.generated


def test_preemption_restore_roundtrip(engine_setup):
    cfg, eng = engine_setup
    prompt = list(range(500, 628))
    ref = eng.submit(prompt, params=SamplingParams(max_new_tokens=8))
    eng.run()
    req = eng.submit(prompt, params=SamplingParams(max_new_tokens=8))
    eng.step()                       # prefill + first token
    eng.preempt(req)
    assert req.request_id in eng._preempted_payloads
    eng.run()                        # re-admits and finishes
    assert req.generated == ref.generated


def test_mla_engine_generates():
    from repro.config import ModelConfig, FAMILY_DECODER
    cfg = ModelConfig(name="mla-serve", family=FAMILY_DECODER,
                      n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      head_dim=16, d_ff=128, vocab_size=256,
                      d_latent=32, d_rope=8)
    eng = ServingEngine(cfg, EngineConfig(max_len=256,
                                          kv_budget_bytes=8e6))
    r = eng.submit(list(range(100)), params=SamplingParams(max_new_tokens=4))
    eng.run()
    assert len(r.generated) == 4


def test_sizing_drives_slot_count():
    cfg = reduce_config(get_config("llama3.2-1b"))
    budget = 4e6
    a = ServingEngine(cfg, EngineConfig(max_len=256,
                                        kv_budget_bytes=budget))
    b = ServingEngine(cfg, EngineConfig(max_len=256,
                                        kv_budget_bytes=budget,
                                        status_quo_sizing=True))
    # arch-aware sizing admits more concurrent requests (kv=2 < heads=4)
    assert a.scheduler.n_slots >= b.scheduler.n_slots


def test_cluster_failover_completes_all():
    cfg = reduce_config(get_config("llama3.2-1b"))
    cluster = ReplicaCluster(cfg, EngineConfig(max_len=128,
                                               kv_budget_bytes=16e6),
                             n_replicas=2)
    rng = np.random.default_rng(0)
    for i in range(6):
        cluster.submit([int(t) for t in rng.integers(0, 250, size=48)],
                       session_id=f"s{i}",
                       params=SamplingParams(max_new_tokens=3))
    for e in cluster.engines.values():
        if e.scheduler.has_work():
            e.step()
    victim = sorted(cluster.engines)[0]
    cluster.fail_replica(victim)
    stats = cluster.run()
    assert stats["done"] == 6
    assert stats["redispatched"] >= 1
