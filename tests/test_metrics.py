"""Observability registry (paper §IV)."""
from repro.configs.paper_models import LLAMA3_70B
from repro.core.cache_manager import PredictiveCacheManager
from repro.core.metrics import Registry, publish_manager
from repro.traces.replay import replay_tier_specs


def test_registry_expose_format():
    r = Registry()
    r.gauge("kv_tier_used_bytes", 123.0, {"tier": "gpu_hbm"},
            help="bytes resident")
    r.inc("requests_total", 2)
    text = r.expose()
    assert "# TYPE kv_tier_used_bytes gauge" in text
    assert 'kv_tier_used_bytes{tier="gpu_hbm"} 123.0' in text
    assert "requests_total 2.0" in text
    assert r.get("requests_total") == 2.0


def test_publish_manager_covers_paper_metrics():
    mgr = PredictiveCacheManager(
        LLAMA3_70B, specs=replay_tier_specs(LLAMA3_70B, hot_blocks=8,
                                            t1_blocks=8))
    bid, _ = mgr.register_block(list(range(128)),
                                block_type="system_prompt")
    mgr.access(bid, transition="same_tool_repeat")
    reg = Registry()
    publish_manager(reg, mgr)
    text = reg.expose()
    for metric in ("kv_cache_hit_rate_hot", "kv_tier_used_bytes",
                   "kv_cache_cost_dollars", "kv_bayes_posterior_mean"):
        assert metric in text
