"""End-to-end behaviour of the paper's system (formerly a placeholder):
the full predictive multi-tier stack on a live engine + trace replay."""
import numpy as np
import pytest

from repro.config import reduce_config
from repro.configs import get_config
from repro.configs.paper_models import LLAMA3_70B
from repro.serving import EngineConfig, SamplingParams, ServingEngine
from repro.traces import GENERATORS, TraceConfig
from repro.traces.replay import REPLAY_HOT_BLOCKS, replay


def test_bayesian_hit_rates_in_paper_band():
    """Paper abstract: 70-84% hit rates on conversation/agentic logs."""
    for wl, gen in GENERATORS.items():
        trace = gen(TraceConfig(n_sessions=60, seed=0))
        r = replay(trace, LLAMA3_70B, policy="bayesian", workload=wl,
                   hot_blocks=REPLAY_HOT_BLOCKS[wl])
        assert 0.6 <= r.hit_rate <= 0.95, (wl, r.hit_rate)


def test_bayesian_beats_lru_all_workloads():
    for wl, gen in GENERATORS.items():
        trace = gen(TraceConfig(n_sessions=40, seed=1))
        lru = replay(trace, LLAMA3_70B, policy="lru", workload=wl,
                     hot_blocks=REPLAY_HOT_BLOCKS[wl])
        bay = replay(trace, LLAMA3_70B, policy="bayesian", workload=wl,
                     hot_blocks=REPLAY_HOT_BLOCKS[wl])
        assert bay.hit_rate > lru.hit_rate, wl


def test_end_to_end_serving_with_full_stack():
    """Live engine: multi-tier + dedup + prefix reuse + agentic hooks."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    eng = ServingEngine(cfg, EngineConfig(max_len=256,
                                          kv_budget_bytes=16e6))
    rng = np.random.default_rng(0)
    system = [int(t) for t in rng.integers(0, 200, size=128)]
    reqs = []
    for i in range(8):
        user = [int(t) for t in rng.integers(0, 200, size=24)]
        reqs.append(eng.submit(
            system + user, params=SamplingParams(max_new_tokens=4),
            session_id=f"s{i % 2}", block_type="system_prompt",
            tool=f"tool{i % 3}"))
    stats = eng.run()
    assert stats["scheduler"]["done"] == 8
    assert stats["scheduler"]["prefix_hit_blocks"] > 0
    assert stats["cache"]["dedup"]["dedup_hits"] > 0
    # agentic predictor learned transitions
    probs = eng.manager.agentic.transition_probs("tool0")
    assert probs and abs(sum(probs.values()) - 1) < 1e-6
