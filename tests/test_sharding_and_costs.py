"""Sharding rules, ZeRO-1 specs, and the HLO cost parser."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shlib
from repro.launch import hlocost
from repro.models.common import PSpec


def test_logical_to_pspec():
    rules = {"vocab": "model", "embed": None, "batch": ("pod", "data")}
    ps = shlib.logical_to_pspec(("vocab", "embed"), rules)
    assert ps == P("model")
    ps = shlib.logical_to_pspec(("batch", None, "vocab"), rules)
    assert ps == P(("pod", "data"), None, "model")


def _make_mesh():
    """1x1 mesh across jax versions (AxisType landed after 0.4.37)."""
    kw = {}
    if hasattr(jax.sharding, "AxisType"):
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * 2
    return jax.make_mesh((1, 1), ("data", "model"), **kw)


def test_evenly_shardable_drops_indivisible():
    mesh = _make_mesh()
    # 1-device mesh: everything trivially divisible
    ps = shlib._evenly_shardable(P("model"), (10,), mesh)
    assert ps == P("model")


def test_zero1_shards_largest_free_dim():
    mesh = _make_mesh()
    ps = shlib.zero1_spec(P(None, "model"), (8, 16), mesh, axis="data")
    assert ps == P("data", "model")


SYNTH_HLO = """\
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={{0,1,2,3}}, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> (s32[], f32[8,8]) {
  %a = f32[8,8] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %a)
  ROOT %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
}
"""


def test_hlocost_trip_count_multiplies():
    s = hlocost.analyze(SYNTH_HLO)
    # dot: 2*8*8*8 = 1024 flops, x5 trips
    assert s.flops == pytest.approx(1024 * 5, rel=0.01)
    # all-reduce wire at TPU-native width (f32 charged 2B):
    # 2*(g-1)/g * 128B = 192B, x5 trips
    assert s.wire_bytes == pytest.approx(192 * 5)
    assert 5 in s.trip_counts.values()


def test_hlocost_backend_config_trip():
    hlo = SYNTH_HLO.replace(
        "condition=%cond, body=%body",
        'condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}')
    s = hlocost.analyze(hlo)
    assert s.flops == pytest.approx(1024 * 7, rel=0.01)


def test_batch_shardings_replicate_small_batch():
    mesh = _make_mesh()
    tree = {"tokens": jax.ShapeDtypeStruct((1, 8), jnp.int32)}
    sh = shlib.batch_shardings(tree, mesh)
    # batch=1 on size-1 axes: sharded-over-1 == replicated, both legal
    assert sh["tokens"].spec in (P(), P("data"), P(("data",)))
