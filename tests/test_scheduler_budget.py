"""Scheduler invariants under the per-step token budget (property-based)
and the per-phase straggler-deadline fix."""
import time

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.config import FAMILY_DECODER, ModelConfig
from repro.serving.request import Phase, Request, SamplingParams
from repro.serving.scheduler import Scheduler, SchedulerConfig

CFG = ModelConfig(name="tiny-gqa", family=FAMILY_DECODER, n_layers=2,
                  d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                  d_ff=128, vocab_size=256)


def _scheduler(budget: int, max_slots: int = 4) -> Scheduler:
    return Scheduler(CFG, SchedulerConfig(
        kv_budget_bytes=1e9, max_len=256, max_slots=max_slots,
        max_step_tokens=budget))


def _drive(sch: Scheduler, reqs, budget: int, max_new: int):
    """Simulate the engine's budget-selected step loop on scheduler state
    alone (prefill grants advance cursors; decodes append tokens).
    Returns (#steps, per-step records) once every request finished."""
    steps, records = 0, []
    while sch.has_work():
        steps += 1
        assert steps < 10_000, "scheduler loop did not converge"
        free = sch.n_slots - len(sch.running)
        for r in sch.admissible(free):
            r.prefill_tokens = list(r.prompt[:-1])
            r.prefill_pos = 0
            sch.start_prefill(r, slot=0)
            if r.prefill_left == 0:
                sch.begin_decode(r)
        decode, grants = sch.plan_step()
        records.append((len(decode),
                        all(r.phase is Phase.DECODE for r in decode),
                        [n for _, n in grants]))
        for r, n in grants:
            r.prefill_pos += n
            if r.prefill_left == 0:
                sch.begin_decode(r)
        for r in decode:
            r.generated.append(0)
            if len(r.generated) >= max_new:
                sch.finish(r)
    return steps, records


@settings(deadline=None, max_examples=30)
@given(prompt_lens=st.lists(st.integers(2, 200), min_size=1, max_size=8),
       budget=st.integers(8, 128),
       max_new=st.integers(1, 6),
       max_slots=st.integers(1, 6))
def test_budget_invariants(prompt_lens, budget, max_new, max_slots):
    sch = _scheduler(budget, max_slots)
    reqs = [Request(prompt=list(range(n)),
                    params=SamplingParams(max_new_tokens=max_new))
            for n in prompt_lens]
    order = [r.request_id for r in reqs]
    for r in reqs:
        sch.submit(r)
    steps, records = _drive(sch, reqs, budget, max_new)

    for n_decode, decode_phase_ok, grant_sizes in records:
        # decode is never starved: only DECODE-phase requests decode,
        # and every one of them decodes each step it is selected
        assert decode_phase_ok
        # per-step prompt tokens respect the remaining budget
        prefill = sum(grant_sizes)
        assert prefill <= max(0, budget - n_decode)
        assert prefill + n_decode <= max(budget, n_decode)
        # grants are positive
        assert all(n > 0 for n in grant_sizes)
    # every submitted request eventually finishes
    assert sorted(r.request_id for r in sch.done) == sorted(order)
    # FIFO completion among equal-length workloads: admission followed
    # submission order (no reordering in the waiting queue)
    assert not sch.waiting and not sch.running and not sch.preempted


@settings(deadline=None, max_examples=20)
@given(budget=st.integers(4, 64), n=st.integers(2, 6))
def test_prefill_grants_follow_admission_order(budget, n):
    """Chunk grants flow to the earliest-admitted PREFILL request first;
    later requests only get budget once earlier cursors are done."""
    sch = _scheduler(budget, max_slots=n)
    reqs = [Request(prompt=list(range(150)),
                    params=SamplingParams(max_new_tokens=1))
            for _ in range(n)]
    for i, r in enumerate(reqs):
        sch.submit(r)
    for i, r in enumerate(sch.admissible(n)):
        r.prefill_tokens = list(r.prompt[:-1])
        sch.start_prefill(r, slot=i)
    _, grants = sch.plan_step()
    granted_ids = [r.request_id for r, _ in grants]
    admitted_ids = [r.request_id for r in sch.running.values()]
    assert granted_ids == admitted_ids[:len(granted_ids)]
    # all but the last grant saturate the request's remaining prompt
    for r, g in grants[:-1]:
        assert g == len(r.prefill_tokens)


def test_preempted_requests_readmit_first():
    sch = _scheduler(64, max_slots=2)
    a, b = (Request(prompt=[1, 2, 3]) for _ in range(2))
    sch.submit(a)
    sch.start(a, 0)
    sch.preempt(a)
    sch.submit(b)
    out = sch.admissible(2)
    assert [r.request_id for r in out] == [a.request_id, b.request_id]


def test_straggler_deadline_is_per_phase():
    """A preempted-then-readmitted request must NOT instantly re-trip the
    deadline (the old arrival-based check livelocked)."""
    sch = _scheduler(64)
    sch.sched.deadline_s = 10.0
    r = Request(prompt=[1, 2, 3, 4])
    sch.submit(r)
    sch.start(r, 0)
    # age the request past the deadline in its current phase
    r.phase_start = time.monotonic() - 11.0
    r.arrival = time.monotonic() - 100.0
    assert sch.check_stragglers() == [r]
    sch.preempt(r)
    (again,) = sch.admissible(1)
    assert again is r
    sch.start(again, 0)
    # re-admission reset the phase clock: no instant re-preemption even
    # though arrival is ancient
    assert sch.check_stragglers() == []
