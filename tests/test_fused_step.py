"""Fused jitted step loop (PR 7): token-identity vs the unfused A/B
path (greedy, across backends, mid-prefill admission, CoW-shared
prefixes, dense layout), the batched sampler's per-row semantics, the
steady-state recompile gate, and device-state reuse accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import reduce_config
from repro.configs import get_config
from repro.serving import EngineConfig, SamplingParams, ServingEngine
from repro.serving import sampler as sampler_mod
from repro.serving.request import Phase


def _cfg():
    return reduce_config(get_config("llama3.2-1b"))


def _run_ab(make_engine, submit, max_steps=2000):
    """Run the same workload fused and unfused; return generated tokens
    per request per mode plus the engines' stats."""
    outs, stats = {}, {}
    for fused in (True, False):
        eng = make_engine(fused)
        assert eng.fused == fused
        reqs = submit(eng)
        eng.run(max_steps=max_steps)
        eng.shutdown()
        outs[fused] = [list(r.generated) for r in reqs]
        stats[fused] = eng.stats()
    return outs, stats


# ---------------------------------------------------------------------------
# A/B token identity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_fused_vs_unfused_identical_tokens(backend):
    """Acceptance: greedy decode through the fused closure is
    token-identical to the per-request-sampling path, per backend."""
    cfg = _cfg()

    def make(fused):
        return ServingEngine(cfg, EngineConfig(
            max_len=128, kv_budget_bytes=5e5, fused_step=fused,
            kernel_backend=backend, page_tokens=32,
            prefill_chunk_tokens=32, max_step_tokens=96))

    def submit(eng):
        rng = np.random.default_rng(11)
        n_new = 4 if backend == "interpret" else 8
        return [eng.submit([int(t) for t in rng.integers(0, 250, size=40)],
                           params=SamplingParams(max_new_tokens=n_new))
                for _ in range(3)]

    outs, stats = _run_ab(make, submit)
    assert outs[True] == outs[False]
    assert all(g for g in outs[True])
    assert stats[True]["fused"] and not stats[False]["fused"]


def test_fused_identical_with_midstream_prefill():
    """A long prompt admitted while other requests are decoding (mixed
    prefill+decode steps force state rebuilds) stays token-identical."""
    cfg = _cfg()

    def make(fused):
        return ServingEngine(cfg, EngineConfig(
            max_len=640, kv_budget_bytes=2e6, fused_step=fused,
            page_tokens=32, prefill_chunk_tokens=64, max_step_tokens=128))

    def submit(eng):
        rng = np.random.default_rng(5)
        reqs = [eng.submit([int(t) for t in rng.integers(0, 250, size=24)],
                           params=SamplingParams(max_new_tokens=20))
                for _ in range(2)]
        for _ in range(3):        # established decodes
            eng.step()
        assert any(r.phase is Phase.DECODE for r in reqs)
        reqs.append(eng.submit(
            [int(t) for t in rng.integers(0, 250, size=500)],
            params=SamplingParams(max_new_tokens=6)))
        return reqs

    outs, _ = _run_ab(make, submit)
    assert outs[True] == outs[False]


def test_fused_identical_with_cow_shared_prefix():
    """Requests sharing a CoW-mapped prefix (same pool pages in several
    block tables; private-page copies on the decode boundary) decode the
    same tokens fused and unfused, and sharing actually engaged."""
    cfg = _cfg()

    def make(fused):
        return ServingEngine(cfg, EngineConfig(
            max_len=512, kv_budget_bytes=4e6, fused_step=fused,
            page_tokens=32, prefill_chunk_tokens=64, max_step_tokens=256))

    def submit(eng):
        # seed the radix index: a retained first turn makes its prefix
        # blocks pool-resident and shareable
        bt = eng.manager.block_tokens
        shared = [(7 * i + 3) % 250 for i in range(2 * bt)]
        seed = eng.submit(shared, retain_blocks=True,
                          params=SamplingParams(max_new_tokens=2))
        eng.run(max_steps=500)
        assert seed.phase is Phase.DONE
        rng = np.random.default_rng(9)
        reqs = []
        for i in range(3):
            tail = [int(t) for t in rng.integers(0, 250, size=12)]
            reqs.append(eng.submit(shared + tail,
                                   params=SamplingParams(max_new_tokens=6)))
        return reqs

    outs, stats = _run_ab(make, submit)
    assert outs[True] == outs[False]
    assert stats[True]["cow_share_hits"] > 0
    assert stats[False]["cow_share_hits"] == stats[True]["cow_share_hits"]


def test_fused_dense_layout_identical():
    """The dense (paged=False) fallback fuses decode+sampling too."""
    cfg = _cfg()

    def make(fused):
        return ServingEngine(cfg, EngineConfig(
            max_len=96, kv_budget_bytes=5e5, fused_step=fused,
            paged=False))

    def submit(eng):
        assert not eng.paged
        rng = np.random.default_rng(2)
        return [eng.submit([int(t) for t in rng.integers(0, 250, size=20)],
                           params=SamplingParams(max_new_tokens=6))
                for _ in range(3)]

    outs, _ = _run_ab(make, submit)
    assert outs[True] == outs[False]


# ---------------------------------------------------------------------------
# batched sampler semantics
# ---------------------------------------------------------------------------
def test_sample_batched_matches_per_row_semantics():
    """Deterministic rows (greedy / top_k=1 / tiny top_p) must equal the
    per-row ``sample`` results exactly; filters are per-row."""
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    key = jax.random.PRNGKey(0)
    temps = jnp.asarray([0.0, 1.0, 0.7, 1.3], jnp.float32)
    top_ks = jnp.asarray([0, 1, 0, 5], jnp.int32)
    top_ps = jnp.asarray([1.0, 1.0, 1e-6, 1.0], jnp.float32)
    toks = np.asarray(sampler_mod.sample_batched(
        logits, key, temps, top_ks, top_ps))
    argmax = np.asarray(jnp.argmax(logits, axis=-1))
    assert toks[0] == argmax[0]            # greedy row
    assert toks[1] == argmax[1]            # top_k=1 collapses to argmax
    assert toks[2] == argmax[2]            # top_p -> 0 collapses to argmax
    # top_k=5 row: sampled token must be inside the top-5 support
    top5 = set(np.asarray(jnp.argsort(logits[3])[-5:]).tolist())
    assert int(toks[3]) in top5


def test_sample_batched_jit_stable():
    """One compiled variant regardless of the per-row param values."""
    f = jax.jit(sampler_mod.sample_batched)
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.standard_normal((3, 32)), jnp.float32)
    key = jax.random.PRNGKey(1)
    for t in ((0.0, 0.0, 0.0), (1.0, 0.5, 0.0), (2.0, 0.0, 0.9)):
        f(logits, key, jnp.asarray(t, jnp.float32),
          jnp.zeros((3,), jnp.int32), jnp.ones((3,), jnp.float32))
    assert f._cache_size() == 1


# ---------------------------------------------------------------------------
# recompilation + device-state reuse gates
# ---------------------------------------------------------------------------
def test_zero_recompiles_and_state_reuse_in_steady_decode():
    """Steady-state decode must not grow any jit cache (zero recompiles)
    and must mostly reuse the cached device state instead of rebuilding
    block tables."""
    cfg = _cfg()
    eng = ServingEngine(cfg, EngineConfig(
        max_len=256, kv_budget_bytes=2e6, fused_step=True,
        page_tokens=32, prefill_chunk_tokens=32, max_step_tokens=96))
    rng = np.random.default_rng(4)
    reqs = [eng.submit([int(t) for t in rng.integers(0, 250, size=40)],
                       params=SamplingParams(max_new_tokens=60))
            for _ in range(4)]
    # warm up until everyone decodes (prefill + first fused compile)
    for _ in range(200):
        eng.step()
        if all(r.phase is Phase.DECODE for r in reqs):
            break
    eng.step()
    baseline = eng.recompiles()
    assert baseline["fused_decode"] == 1
    reuses0, rebuilds0 = eng.kv.state_reuses, eng.kv.state_rebuilds
    for _ in range(20):
        eng.step()
    after = eng.recompiles()
    assert after == baseline, f"recompiled in steady state: {baseline} -> {after}"
    reuse_delta = eng.kv.state_reuses - reuses0
    rebuild_delta = eng.kv.state_rebuilds - rebuilds0
    # page-boundary crossings force occasional rebuilds; steady decode
    # must still be reuse-dominated
    assert reuse_delta > rebuild_delta, (reuse_delta, rebuild_delta)
    assert eng.stats()["decode_state_reuses"] == eng.kv.state_reuses
    eng.shutdown()


def test_state_cache_invalidated_on_mutation():
    """Any host-side table mutation (here: a release) must force a
    rebuild — the cached device state is never served stale."""
    cfg = _cfg()
    eng = ServingEngine(cfg, EngineConfig(
        max_len=128, kv_budget_bytes=1e6, fused_step=True,
        page_tokens=32, prefill_chunk_tokens=32, max_step_tokens=96))
    rng = np.random.default_rng(6)
    reqs = [eng.submit([int(t) for t in rng.integers(0, 250, size=33)],
                       params=SamplingParams(max_new_tokens=8 + 8 * i))
            for i in range(3)]
    eng.run(max_steps=500)
    eng.shutdown()
    assert all(r.phase is Phase.DONE for r in reqs)
    assert all(len(r.generated) == 8 + 8 * i for i, r in enumerate(reqs))
    # the staggered finishes changed the decode set twice: each change
    # must have produced at least one rebuild
    assert eng.kv.state_rebuilds >= 3
