"""Trace→engine serving replay (traces/serving_replay.py): adapter
determinism, per-turn submission order, session continuation, and the
engine-level policy separation the paper's Table V predicts."""
import numpy as np
import pytest

from repro.core import sizing
from repro.traces.generators import TraceConfig, workload_sessions
from repro.traces.serving_replay import (ServingReplayConfig, _turn_spec,
                                         build_engine, replay_model_config,
                                         run_serving_replay)

TINY = dict(n_sessions=3, max_turns=3)


def _tiny(workload="agentic", policy="bayesian", **kw):
    return ServingReplayConfig(workload=workload, policy=policy,
                               **{**TINY, **kw})


# ---------------------------------------------------------------------------
# turn-spec construction
# ---------------------------------------------------------------------------
def test_turn_spec_structure():
    cfg = replay_model_config()
    bt = sizing.block_tokens(cfg)
    sessions = workload_sessions("agentic", TraceConfig(n_sessions=2, seed=0))
    cache = {}
    spec = _turn_spec(sessions[0][0], bt, cfg.vocab_size, 4, cache)
    # one engine block per trace block, in event order
    assert len(spec.prompt) == len(spec.block_types) * bt
    # output blocks ride at the prompt tail (agentic: sys, tool, think)
    assert spec.block_types[0] == "system_prompt"
    assert spec.block_types[-1] == "intermediate_reasoning"
    # the final (partial-after-effective) block is excluded from accounting
    assert len(spec.acct_cids) == len(spec.block_types) - 1
    # identical content ids materialize to identical tokens (dedup
    # target), independent of the cache instance
    spec2 = _turn_spec(sessions[0][0], bt, cfg.vocab_size, 4, {})
    assert spec2.prompt == spec.prompt
    assert spec2.acct_cids == spec.acct_cids


def test_adapter_determinism_fixed_seed():
    """Two runs under the same seed produce identical submission streams
    and identical results (virtual clock, sampling, and trace content
    are all seeded; inline transfers pin the one source of thread-timing
    variance)."""
    logs, results = [], []
    for _ in range(2):
        log = []
        r = run_serving_replay(_tiny(async_transfers=False), turn_log=log)
        # request ids are process-global (itertools.count): compare the
        # stream relative to the run's first id
        base = min(e["request_id"] for e in log)
        logs.append([{**e, "request_id": e["request_id"] - base}
                     for e in log])
        results.append(r)
    assert logs[0] == logs[1]
    a, b = results
    assert a.engine_hit_rate == b.engine_hit_rate
    assert a.generated_tokens == b.generated_tokens
    assert a.ttft_p50 == b.ttft_p50
    assert a.virtual_time_s == b.virtual_time_s


def test_per_turn_submission_order():
    """Within a session, turn k+1 is submitted only after turn k
    finished: the log's turn indices are contiguous and submit times
    non-decreasing per session."""
    log = []
    r = run_serving_replay(_tiny(workload="sharegpt"), turn_log=log)
    assert r.requests_done == len(log)
    per_session = {}
    for ent in log:
        per_session.setdefault(ent["session"], []).append(ent)
    assert per_session
    for sid, ents in per_session.items():
        assert [e["turn"] for e in ents] == list(range(len(ents)))
        sub = [e["submit_v"] for e in ents]
        assert sub == sorted(sub)
        # request ids are allocated at submit: monotone within a session
        rids = [e["request_id"] for e in ents]
        assert rids == sorted(rids)


# ---------------------------------------------------------------------------
# session continuation (retain_blocks) through the live engine
# ---------------------------------------------------------------------------
def test_session_continuation_prefix_reuse():
    """A second turn that resubmits the first turn's prefix gets served
    from the cache because the finished request retained its blocks."""
    rcfg = ServingReplayConfig(workload="agentic", n_sessions=2,
                               max_turns=2)
    eng = build_engine(rcfg, max_len=256)
    bt = eng.manager.block_tokens
    rng = np.random.default_rng(0)
    prefix = [int(t) for t in rng.integers(0, 200, size=3 * bt)]
    turn2_suffix = [int(t) for t in rng.integers(0, 200, size=bt)]
    from repro.serving.request import SamplingParams
    r1 = eng.submit(prefix, params=SamplingParams(max_new_tokens=2),
                    session_id="s0", retain_blocks=True)
    eng.run()
    assert r1.generated
    r2 = eng.submit(prefix + turn2_suffix,
                    params=SamplingParams(max_new_tokens=2),
                    session_id="s0")
    eng.run()
    assert r2.prefix_hit_blocks >= 2      # prefix served, not recomputed
    assert r2.hot_hit_blocks >= 2         # ... from the hot tiers
    st = eng.scheduler.session_stats()
    assert st["s0"]["turns"] == 2
    assert st["s0"]["prefix_hit_blocks"] >= 2
    eng.shutdown()


def test_retain_blocks_false_releases():
    """Without retention, low-reuse blocks may be dropped at finish —
    the manager's release path is still exercised (seed behaviour)."""
    rcfg = ServingReplayConfig(workload="agentic", n_sessions=1,
                               max_turns=1)
    eng = build_engine(rcfg, max_len=256)
    rng = np.random.default_rng(1)
    from repro.serving.request import SamplingParams
    prompt = [int(t) for t in rng.integers(0, 200,
                                           size=2 * eng.manager.block_tokens)]
    req = eng.submit(prompt, params=SamplingParams(max_new_tokens=2))
    eng.run()
    assert req.retain_blocks is False
    eng.shutdown()


# ---------------------------------------------------------------------------
# hit accounting
# ---------------------------------------------------------------------------
def test_hit_rates_bounded_and_split_consistent():
    r = run_serving_replay(_tiny(workload="lmsys"))
    assert 0.0 <= r.engine_hit_rate <= 1.0
    assert r.engine_hit_rate <= r.reuse_rate <= 1.0
    assert r.manager_replay_hit_rate <= r.manager_hit_rate + 1e-9
    # the hot-hit split partitions the manager's hot hits
    assert r.hot_hits_t0 + r.hot_hits_t1 >= r.cow_share_hits
    assert r.requests_done > 0 and r.generated_tokens > 0
    assert r.ttft_p50 > 0.0 and r.virtual_time_s > 0.0


def test_reregistration_counts_as_cold_miss():
    from repro.configs.paper_models import LLAMA3_70B
    from repro.core.cache_manager import PredictiveCacheManager
    from repro.traces.replay import replay_tier_specs
    mgr = PredictiveCacheManager(
        LLAMA3_70B, specs=replay_tier_specs(LLAMA3_70B, hot_blocks=2,
                                            t1_blocks=2),
        enable_multi_tier=False)
    bt = mgr.block_tokens
    first, _ = mgr.register_block(list(range(bt)))
    # flood so the first block is evicted from every tier
    for i in range(12):
        mgr.register_block([i + 1] * bt)
    assert first not in mgr.metas
    before = mgr.stats.reregistrations
    again, dup = mgr.register_block(list(range(bt)))
    assert not dup                       # content known, block dropped
    assert mgr.stats.reregistrations == before + 1
    assert mgr.stats.replay_hit_rate <= mgr.stats.hit_rate + 1e-9


# ---------------------------------------------------------------------------
# the paper's claim, end-to-end: Bayesian beats LRU under pressure
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_segment_reuse_lifts_sharegpt_hit_rate():
    """Segment-granular reuse, end to end: ShareGPT's history
    truncation shifts surviving turn blocks left by whole blocks, so
    the radix prefix loses everything past the first shifted block.
    The content-segment index recovers those blocks at their new
    positions — the engine-level hit rate must lift by >= 5 points on
    the same seeded trace (measured: 48.7% -> 72.0%)."""
    kw = dict(workload="sharegpt", n_sessions=12, max_turns=6)
    off = run_serving_replay(ServingReplayConfig(segment_reuse=False, **kw))
    on = run_serving_replay(ServingReplayConfig(segment_reuse=True, **kw))
    assert on.seen_blocks == off.seen_blocks       # same trace
    assert on.engine_hit_rate >= off.engine_hit_rate + 0.05
    # the lift is really segment-resumed content, not accounting drift
    assert on.segment_hit_blocks > 0
    assert on.segment_share_hits + on.segment_inject_hits > 0
    assert on.segment_lookups > 0
    assert off.segment_hit_blocks == 0
    # reuse_rate counts segment hits too and stays a valid rate
    assert on.engine_hit_rate <= on.reuse_rate <= 1.0


@pytest.mark.slow
def test_segment_reuse_off_reproduces_radix_baselines():
    """``segment_reuse=False`` must keep the monolithic-radix path
    bit-for-bit: the PR-8 baseline hit rates reproduce exactly on the
    seeded LMSYS and agentic traces (the A/B's control arm)."""
    kw = dict(n_sessions=12, max_turns=6, segment_reuse=False)
    lmsys = run_serving_replay(ServingReplayConfig(workload="lmsys", **kw))
    agentic = run_serving_replay(ServingReplayConfig(workload="agentic",
                                                    **kw))
    assert round(100 * lmsys.engine_hit_rate, 1) == 85.2
    assert round(100 * agentic.engine_hit_rate, 1) == 83.9
    assert lmsys.segment_hit_blocks == agentic.segment_hit_blocks == 0


@pytest.mark.slow
def test_engine_bayesian_beats_lru_on_agentic():
    """Table V at the serving layer: under replay tier pressure, the
    Bayesian policy keeps reusable tool/system context hot while LRU
    keeps recent single-use reasoning blocks — the engine-level tier-0/1
    hit rate must separate measurably on the agentic trace."""
    kw = dict(workload="agentic", n_sessions=8, max_turns=5,
              hot_blocks=40, t1_blocks=56)
    bay = run_serving_replay(ServingReplayConfig(policy="bayesian", **kw))
    lru = run_serving_replay(ServingReplayConfig(policy="lru", **kw))
    assert bay.seen_blocks == lru.seen_blocks      # same trace
    assert bay.engine_hit_rate >= lru.engine_hit_rate + 0.05
    # hit rate couples into virtual latency via lower-tier fetch stalls
    assert bay.promotions <= lru.promotions
