"""Beta-posterior predictor: math, convergence, blending, properties."""
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.bayesian import (BLOCK_TYPES, TRANSITION_TYPES,
                                 BayesianReusePredictor, BetaPosterior)


def test_sixteen_pairs():
    p = BayesianReusePredictor()
    assert len(p._post) == len(BLOCK_TYPES) * len(TRANSITION_TYPES) == 16


def test_posterior_mean_updates():
    post = BetaPosterior()
    assert post.mean == 0.5
    post.update(True)
    assert post.mean == pytest.approx(2 / 3)
    post.update(False)
    assert post.mean == pytest.approx(0.5)


def test_convergence_within_500_observations():
    """Paper SVE: (system_prompt, same_tool_repeat) converges to
    alpha/(alpha+beta) > 0.97 within 500 observations."""
    p = BayesianReusePredictor()
    for i in range(500):
        p.observe("system_prompt", "same_tool_repeat", i % 100 != 0)
    assert p.posterior_mean("system_prompt", "same_tool_repeat") > 0.97


def test_confidence_saturates():
    p = BayesianReusePredictor(confidence_k=20)
    assert p.confidence("user_context", "reasoning_step") == 0.0
    for _ in range(1000):
        p.observe("user_context", "reasoning_step", True)
    assert p.confidence("user_context", "reasoning_step") > 0.95


def test_blending_prefers_empirical_when_young():
    p = BayesianReusePredictor(confidence_k=50, window=8)
    # 4 recent misses on a fresh pair: empirical (0) should dominate
    for _ in range(4):
        p.observe("tool_context", "agent_handoff", False)
    blended = p.reuse_probability("tool_context", "agent_handoff")
    posterior = p.posterior_mean("tool_context", "agent_handoff")
    assert blended < posterior


def test_prior_overwhelmed_within_100_obs():
    """Paper Table IX: biased priors converge within 100 observations."""
    biased = BayesianReusePredictor(prior_alpha=9.0, prior_beta=1.0)
    flat = BayesianReusePredictor()
    for _ in range(100):
        biased.observe("user_context", "tool_switch", False)
        flat.observe("user_context", "tool_switch", False)
    a = biased.posterior_mean("user_context", "tool_switch")
    b = flat.posterior_mean("user_context", "tool_switch")
    assert abs(a - b) < 0.08


@given(st.lists(st.booleans(), min_size=1, max_size=300))
@settings(max_examples=50, deadline=None)
def test_posterior_in_unit_interval_and_consistent(events):
    p = BayesianReusePredictor()
    for e in events:
        p.observe("user_context", "reasoning_step", e)
    m = p.posterior_mean("user_context", "reasoning_step")
    assert 0.0 < m < 1.0
    expected = (1 + sum(events)) / (2 + len(events))
    assert m == pytest.approx(expected)
    assert 0.0 <= p.reuse_probability("user_context",
                                      "reasoning_step") <= 1.0


def test_state_roundtrip():
    p = BayesianReusePredictor()
    for i in range(50):
        p.observe("system_prompt", "tool_switch", i % 2 == 0)
    q = BayesianReusePredictor()
    q.load_state_dict(p.state_dict())
    assert q.posterior_mean("system_prompt", "tool_switch") == \
        p.posterior_mean("system_prompt", "tool_switch")


def test_thompson_sampler_concentrates():
    from repro.core.bayesian import ThompsonSampler
    p = BayesianReusePredictor()
    for _ in range(500):
        p.observe("system_prompt", "same_tool_repeat", True)
    ts = ThompsonSampler(p, seed=1)
    draws = [ts.sample_reuse("system_prompt", "same_tool_repeat")
             for _ in range(100)]
    assert min(draws) > 0.9            # posterior concentrated near 1
    fresh = [ts.sample_reuse("tool_context", "agent_handoff")
             for _ in range(100)]
    assert max(fresh) - min(fresh) > 0.5   # prior stays exploratory
