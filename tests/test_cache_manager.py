"""PredictiveCacheManager: invariants + policy separation."""
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.paper_models import LLAMA3_70B
from repro.core import sizing
from repro.core.cache_manager import PredictiveCacheManager
from repro.traces.replay import replay_tier_specs


def make_mgr(policy="bayesian", hot=8):
    return PredictiveCacheManager(
        LLAMA3_70B, specs=replay_tier_specs(LLAMA3_70B, hot_blocks=hot,
                                            t1_blocks=hot),
        policy=policy)


def test_register_dedups_identical_content():
    m = make_mgr()
    a, dup_a = m.register_block(list(range(128)))
    b, dup_b = m.register_block(list(range(128)))
    assert not dup_a and dup_b and a == b


def test_capacity_never_exceeded_under_churn():
    m = make_mgr(hot=4)
    for i in range(64):
        m.register_block([i] * 128, block_type="user_context")
        m.tick()
        for t in m.hierarchy.tiers:
            assert t.used <= t.spec.capacity + 1e-6


def test_hot_hit_accounting():
    m = make_mgr()
    bid, _ = m.register_block(list(range(128)),
                              block_type="system_prompt")
    r = m.access(bid, transition="same_tool_repeat")
    assert r.hit and r.tier == 0
    assert m.stats.hit_rate == 1.0


def test_demotion_cascade_keeps_all_tiers_ordered():
    """Filling beyond hot capacity demotes but never freezes tier 1."""
    m = make_mgr(hot=4)
    ids = []
    for i in range(40):
        bid, _ = m.register_block([i] * 128)
        ids.append(bid)
        m.tick()
    # earliest blocks should have cascaded below tier 1
    locs = [m.hierarchy.locate(b) for b in ids if b in m.metas]
    assert any(l is not None and l >= 2 for l in locs)
    assert m.stats.demotions > 0


def test_lower_tier_access_promotes_and_counts_miss():
    m = make_mgr(hot=4)
    first, _ = m.register_block([0] * 128)
    for i in range(1, 30):
        m.register_block([i] * 128)
        m.tick()
    loc = m.hierarchy.locate(first)
    assert loc is not None and loc > 1
    r = m.access(first)
    assert not r.hit and r.fetch_time > 0
    assert m.hierarchy.locate(first) == 0       # promoted


def test_release_respects_refcounts():
    m = make_mgr()
    a, _ = m.register_block(list(range(128)))
    b, _ = m.register_block(list(range(128)))   # same content
    assert a == b
    m.metas[a].reuse_prob = 0.0
    m.release_sequence([a])                     # one ref released
    assert a in m.metas
    m.release_sequence([a])                     # second release frees
    assert a not in m.metas


def test_bayesian_beats_lru_on_structured_reuse():
    """System prompts reused at long gaps: predictive wins (paper core)."""
    def run(policy):
        m = make_mgr(policy=policy, hot=6)
        sys_id, _ = m.register_block([7] * 128,
                                     block_type="system_prompt")
        hits = 0
        for round_ in range(30):
            # churn: 8 one-shot scratch blocks between sys accesses
            for j in range(8):
                m.register_block([round_ * 100 + j] * 128,
                                 block_type="intermediate_reasoning")
                m.tick()
            r = m.access(sys_id, transition="same_tool_repeat")
            hits += int(r.hit)
            m.tick()
        return hits
    assert run("bayesian") >= run("lru")
