"""Wall-clock serving front-end (``serving/frontend.py``) under a
deterministic virtual clock, plus a real-threaded soak (``slow``).

The deterministic tests drive the pump inline (``run_for`` /
``serve_schedule``) with a ``VirtualClock`` and a pinned per-step cost,
so every latency metric is an exact function of the schedule — no
timing races, byte-identical across runs.
"""
import threading

import pytest

from repro.serving.frontend import (ADMIT, QUEUE, SHED, AdmissionSnapshot,
                                    ServingFrontend, SLOConfig, VirtualClock,
                                    admission_decision, projected_ttft_s)
from repro.serving.request import SamplingParams
from repro.traces.loadgen import offered_summary, trace_load
from repro.traces.serving_replay import ServingReplayConfig, build_engine


def _frontend(*, budget=float("inf"), action="shed", max_queue=64,
              max_step_tokens=256, step_time_s=5e-3, workload="lmsys"):
    rcfg = ServingReplayConfig(workload=workload, n_sessions=4, seed=0,
                               async_transfers=False,
                               max_step_tokens=max_step_tokens)
    return ServingFrontend(
        build_engine(rcfg), clock=VirtualClock(), step_time_s=step_time_s,
        slo=SLOConfig(ttft_budget_s=budget, action=action,
                      max_queue=max_queue))


def _params(n=4):
    return SamplingParams(max_new_tokens=n)


# ---------------------------------------------------------------------------
# streaming callbacks + ledger
# ---------------------------------------------------------------------------
def test_stream_callbacks_once_per_token_in_order():
    fe = _frontend()
    events = []
    done_calls = []
    h = fe.submit([1, 2, 3, 4], params=_params(5), session_id="s0",
                  on_token=lambda t, i: events.append((i, t)),
                  on_done=lambda hh: done_calls.append(hh))
    fe.run_for(n_steps=40)
    assert h.status == "done"
    assert len(h.tokens) == 5
    # indices are 0..n-1 in order, one callback per token
    assert [i for i, _ in events] == list(range(5))
    assert [t for _, t in events] == h.tokens
    assert done_calls == [h]
    assert h.ttft is not None and h.ttft > 0
    # TBT gaps are exact multiples of the pinned step cost
    assert all(abs(g - 5e-3) < 1e-12 for g in h.tbts)
    fe.check_ledger()
    fe.stop()


def test_concurrent_submission_no_leak_no_double_completion():
    """Submissions racing in from many threads all reach a terminal
    state exactly once (on_done counted per handle)."""
    fe = _frontend()
    counts = {}
    lock = threading.Lock()

    def on_done(h):
        with lock:
            counts[id(h)] = counts.get(id(h), 0) + 1

    handles = []

    def submitter(k):
        for j in range(4):
            h = fe.submit([k * 16 + j + 1] * 6, params=_params(3),
                          session_id=f"s{k}", on_done=on_done)
            with lock:
                handles.append(h)

    threads = [threading.Thread(target=submitter, args=(k,))
               for k in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert fe.stats()["offered"] == 24
    fe.run_for(n_steps=400)
    fe.check_ledger()
    st = fe.stats()
    assert st["done"] == 24 and st["shed"] == 0 and st["in_flight"] == 0
    assert all(c == 1 for c in counts.values()) and len(counts) == 24
    assert all(len(h.tokens) == 3 for h in handles)
    fe.stop()


def test_stop_drains_in_flight_then_rejects_submissions():
    fe = _frontend()
    hs = [fe.submit([i + 1] * 8, params=_params(6), session_id=f"s{i}")
          for i in range(5)]
    fe.run_for(n_steps=2)                       # partially complete
    assert fe.in_flight() > 0
    fe.stop(drain=True)                         # inline drain
    assert all(h.status == "done" for h in hs)
    assert fe.in_flight() == 0
    fe.check_ledger()
    with pytest.raises(RuntimeError):
        fe.submit([1, 2, 3])


def test_run_for_duration_bound_on_virtual_clock():
    fe = _frontend(step_time_s=1e-2)
    fe.submit([1] * 4, params=_params(50))
    t0 = fe.clock.monotonic()
    fe.run_for(duration_s=0.1)
    assert fe.clock.monotonic() - t0 >= 0.1
    assert fe.clock.monotonic() - t0 < 0.1 + 2e-2
    fe.stop()


# ---------------------------------------------------------------------------
# open-loop schedule + determinism
# ---------------------------------------------------------------------------
def _run_schedule(budget=float("inf"), action="shed", rate=100.0, n=30):
    fe = _frontend(budget=budget, action=action, max_step_tokens=32)
    arrivals = trace_load("lmsys", rate, n_requests=n, seed=5,
                          n_sessions=4, max_turns=2)
    fe.serve_schedule(arrivals)
    fe.check_ledger()
    st = fe.stats()
    fe.stop()
    return st, arrivals


def test_serve_schedule_accounting_sums_to_offered():
    st, arrivals = _run_schedule()
    assert st["offered"] == len(arrivals)
    assert st["offered"] == st["done"] + st["shed"]
    assert st["goodput"] <= st["done"]
    assert st["in_flight"] == 0


def test_virtual_clock_metrics_byte_identical_across_runs():
    a, _ = _run_schedule(budget=0.15)
    b, _ = _run_schedule(budget=0.15)
    assert repr(a) == repr(b)


def test_admission_holds_p99_while_uncontrolled_breaches():
    budget = 0.15
    free, _ = _run_schedule(budget=float("inf"))
    held, _ = _run_schedule(budget=budget)
    assert free["ttft_p99"] > budget          # open loop overload breaches
    assert held["ttft_p99"] <= budget         # admission sheds to hold SLO
    assert held["shed"] > 0
    assert held["goodput"] == held["done"]    # everything served met SLO


def test_queue_mode_bounded_queue():
    st, _ = _run_schedule(budget=0.1, action="queue")
    assert st["queued_peak"] <= SLOConfig().max_queue
    assert st["offered"] == st["done"] + st["shed"]


def test_loadgen_schedule_deterministic_and_monotone():
    a = trace_load("lmsys", 50.0, n_requests=25, seed=9)
    b = trace_load("lmsys", 50.0, n_requests=25, seed=9)
    assert a == b
    ts = [x.t for x in a]
    assert ts == sorted(ts)
    # per-session turn indices strictly increase in schedule order
    seen = {}
    for x in a:
        assert x.turn == seen.get(x.session_id, -1) + 1
        seen[x.session_id] = x.turn
    s = offered_summary(a)
    assert s["requests"] == 25 and s["offered_qps"] > 0


# ---------------------------------------------------------------------------
# admission decisions are pure functions of observable state
# ---------------------------------------------------------------------------
def _snap(pending=0, queued=0, qlen=0, live=0, free=8, step=5e-3):
    return AdmissionSnapshot(pending_prefill_tokens=pending,
                             queued_prefill_tokens=queued, queue_len=qlen,
                             live_decodes=live, free_slots=free,
                             est_step_s=step)


def test_admission_infinite_budget_always_admits():
    slo = SLOConfig()
    snap = _snap(pending=10**6, qlen=10**3, live=10**3)
    assert admission_decision(10**4, snap, slo, 32) == ADMIT


def test_admission_idle_system_never_sheds():
    slo = SLOConfig(ttft_budget_s=1e-9)        # absurdly tight budget
    assert admission_decision(10**6, _snap(), slo, 32) == ADMIT


def test_admission_sheds_or_queues_on_projected_breach():
    slo_shed = SLOConfig(ttft_budget_s=0.05, action="shed")
    slo_q = SLOConfig(ttft_budget_s=0.05, action="queue", max_queue=2)
    loaded = _snap(pending=4096, live=4)
    assert projected_ttft_s(64, loaded, 32) > 0.05
    assert admission_decision(64, loaded, slo_shed, 32) == SHED
    assert admission_decision(64, loaded, slo_q, 32) == QUEUE
    full_q = _snap(pending=4096, live=4, qlen=2)
    assert admission_decision(64, full_q, slo_q, 32) == SHED


def test_admission_admits_within_budget():
    slo = SLOConfig(ttft_budget_s=1.0, action="shed")
    light = _snap(pending=32, live=1)
    assert projected_ttft_s(16, light, 32) <= 1.0
    assert admission_decision(16, light, slo, 32) == ADMIT


# ---------------------------------------------------------------------------
# real-threaded soak (true concurrency)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_soak_real_threads_no_drops_no_leaks_stable_recompiles():
    """Background pump thread + real-clock submitter at moderate QPS:
    zero dropped callbacks, zero leaked requests, recompile counts
    stable after warm-up."""
    import time as _time

    rcfg = ServingReplayConfig(workload="lmsys", n_sessions=6, seed=0,
                               async_transfers=False)
    engine = build_engine(rcfg)
    fe = ServingFrontend(engine)

    # warm-up inline: trigger every compilation path before measuring
    warm = [fe.submit([i + 1] * 12, params=_params(4), session_id=f"w{i}")
            for i in range(3)]
    while fe.in_flight() > 0:
        fe.pump_once()
    assert all(h.status == "done" for h in warm)
    recompiles_after_warmup = engine.recompiles()

    fe.start()
    arrivals = trace_load("lmsys", 12.0, duration_s=3.0, seed=11,
                          n_sessions=6, max_turns=2)
    t0 = _time.monotonic()
    handles = []
    for a in arrivals:
        dt = (t0 + a.t) - _time.monotonic()
        if dt > 0:
            _time.sleep(dt)
        h = fe.submit(list(a.prompt),
                      params=SamplingParams(max_new_tokens=a.max_new),
                      session_id=a.session_id, arrival_t=t0 + a.t,
                      block_types=list(a.block_types), tool=a.tool,
                      retain_blocks=not a.last_turn)
        handles.append(h)
    fe.stop(drain=True, timeout=120.0)
    fe.check_ledger()
    st = fe.stats()
    assert st["offered"] == len(arrivals) + len(warm)
    assert st["done"] == len(arrivals) + len(warm) and st["shed"] == 0
    # zero dropped callbacks: every generated token was delivered
    for h in handles:
        assert h.status == "done"
        assert h.tokens == list(h.request.generated)
    assert engine.recompiles() == recompiles_after_warmup
