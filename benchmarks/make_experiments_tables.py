"""Render the EXPERIMENTS.md dry-run/roofline tables from the sweep JSON.

    PYTHONPATH=src python benchmarks/make_experiments_tables.py
"""
import json
import sys

PEAK = 197e12
HBM = 819e9
ICI = 50e9

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_t(seconds: float) -> str:
    if seconds >= 1.0:
        return f"{seconds:.2f} s"
    if seconds >= 1e-3:
        return f"{seconds * 1e3:.1f} ms"
    return f"{seconds * 1e6:.0f} µs"


def main(path="benchmarks/results/dryrun_baseline.json",
         mesh="16x16"):
    d = json.load(open(path))
    rows = [r for r in d["results"] if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], ORDER.index(r["shape"])))
    print("| arch | shape | compute | memory | collective | bottleneck |"
          " useful/HLO flops | peak mem/chip | compile |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        tc, tm, tl = r["t_compute"], r["t_memory"], r["t_collective"]
        print(f"| {r['arch']} | {r['shape']} | {fmt_t(tc)} | {fmt_t(tm)} |"
              f" {fmt_t(tl)} | {r['bottleneck']} |"
              f" {100 * r['useful_flops_frac']:.0f}% |"
              f" {r['mem']['peak_hint'] / 1e9:.1f} GB |"
              f" {r['compile_s']:.0f} s |")
    # summary stats
    n_mem = sum(1 for r in rows if r["bottleneck"] == "memory")
    n_coll = sum(1 for r in rows if r["bottleneck"] == "collective")
    n_comp = sum(1 for r in rows if r["bottleneck"] == "compute")
    print(f"\ncells={len(rows)} memory-bound={n_mem} "
          f"collective-bound={n_coll} compute-bound={n_comp}",
          file=sys.stderr)


if __name__ == "__main__":
    main(*sys.argv[1:])
