"""Benchmark harness — one function per paper table (+ kernel/system
micro-benchmarks).  Prints ``name,value,paper_value`` CSV rows so every
reproduced number sits next to the paper's.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--table N]
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

PAPER = {
    # Table I: (mha_bytes, actual_bytes, ratio)
    "table1": {"deepseek-v3": (65536, 1152, 57), "llama-3-70b": (32768, 4096, 8),
               "mixtral-8x22b": (24576, 4096, 6), "qwen-2.5-72b": (32768, 4096, 8)},
    # Table III: (status_quo_batch, arch_aware_batch)
    "table3": {"deepseek-v3": (14, 104), "llama-3-70b": (22, 22),
               "mixtral-8x22b": (42, 31), "qwen-2.5-72b": (22, 22)},
    # Table IV: (capacity_label, ttft_p99_s, tput)
    "table4": [("GPU-only", 40, 4.2, 1450), ("+ CPU DRAM", 200, 2.8, 2100),
               ("+ CXL 3.0", 712, 1.8, 2850), ("+ NVMe (GDS)", 4813, 1.5, 3200),
               ("+ RDMA Pool", 38912, 1.1, 3950),
               ("Full system", 38912, 1.1, 4150)],
    # Table V: workload -> (lru, ema, bayes)
    "table5": {"sharegpt": (59.5, 59.5, 69.8), "lmsys": (77.8, 77.8, 84.2),
               "agentic": (66.5, 66.5, 80.5)},
    # Table VI: model -> (raw MB/1k tok, deduped MB, savings %)
    "table6": {"llama-3-70b": (327.7, 251.7, 23.2),
               "deepseek-v3": (70.3, 49.5, 29.6),
               "mixtral-8x22b": (229.4, 205.5, 10.4)},
    # Table VII: system -> (ttft_p50, ttft_p99, tbt_p99_ms, tput, cost)
    "table7": {"vLLM 0.19": (1.2, 4.2, 48, 1450, 0.82),
               "SGLang 0.5.9": (0.9, 3.1, 42, 1850, 0.68),
               "TensorRT-LLM": (0.8, 2.8, 35, 2100, 0.61),
               "FlexGen": (3.2, 12.1, 180, 650, 1.85),
               "Ours (projected)": (0.4, 1.1, 32, 4150, 0.43)},
    # Table VIII: component -> degradation % (L3-70B column)
    "table8": {"arch-aware sizing": -73.8, "bayesian prediction": -28.6,
               "multi-tier placement": -31.2, "head-granular eviction": -8.9,
               "deduplication": -4.2, "rope prefetching": -5.1},
}


def _row(name: str, value, paper=None) -> None:
    pv = "" if paper is None else paper
    print(f"{name},{value},{pv}")


# ---------------------------------------------------------------------------
def table_i() -> None:
    """KV bytes/token/layer: MHA-equivalent vs architecture-aware."""
    from repro.configs.paper_models import PAPER_MODELS
    from repro.core import sizing
    print("# Table I — per-token-per-layer KV bytes (ours vs paper)")
    for name, cfg in PAPER_MODELS.items():
        r = sizing.sizing_report(cfg)
        exp = PAPER["table1"][name]
        _row(f"table1.{name}.mha_bytes", int(r.mha_equivalent), exp[0])
        _row(f"table1.{name}.actual_bytes", int(r.per_token_layer), exp[1])
        _row(f"table1.{name}.ratio", round(r.compression, 1), exp[2])


def table_iii() -> None:
    """Max batch size, status-quo vs arch-aware sizing."""
    from repro.configs.paper_models import PAPER_MODELS
    from repro.core import sizing
    print("# Table III — max batch @30GB KV, n_max=4096, 8-way TP")
    for name, cfg in PAPER_MODELS.items():
        exp = PAPER["table3"][name]
        sq = sizing.status_quo_max_batch(cfg, 30e9, 4096, tp=8)
        aa = sizing.max_batch(cfg, 30e9, 4096)
        _row(f"table3.{name}.status_quo", sq, exp[0])
        _row(f"table3.{name}.arch_aware", aa, exp[1])
        _row(f"table3.{name}.tput_gain", round(aa / max(sq, 1), 1),
             round(exp[1] / exp[0], 1))


def table_v(fast: bool = False) -> Dict[str, float]:
    """Trace-replay hit rates: LRU / EMA / Bayesian x 3 workloads."""
    from repro.traces.replay import run_table_v
    print("# Table V — cache hit rates via trace replay (mean±std)")
    seeds = (0, 1) if fast else (0, 1, 2, 3, 4)
    n_sessions = 60 if fast else 100
    rows = run_table_v(n_sessions=n_sessions, seeds=seeds)
    out = {}
    for r in rows:
        exp = PAPER["table5"][r["workload"]]
        idx = {"lru": 0, "ema": 1, "bayesian": 2}[r["policy"]]
        _row(f"table5.{r['workload']}.{r['policy']}",
             f"{100 * r['hit_mean']:.1f}±{100 * r['hit_std']:.1f}",
             exp[idx])
        out[f"{r['workload']}.{r['policy']}"] = r["hit_mean"]
    return out


def table_vi() -> None:
    """Checkpoint dedup savings per 1,000 cached tokens.

    Scenario: checkpoint the live KV of all concurrent sessions to Tier 5
    (warm-start persistence).  Blocks shared across sessions (system
    prompts / templates / tool contexts) are stored once — the delta
    manifest references them by hash.  Raw sizes are exact per-model
    (eq. 3 x n_layers); the dedup ratio comes from the workload snapshot
    (paper band: 10-30%, varying with the shared-prompt share).
    """
    from collections import defaultdict
    from repro.configs.paper_models import PAPER_MODELS
    from repro.core import sizing
    from repro.core.dedup import ContentStore, content_hash, delta_checkpoint
    from repro.traces import GENERATORS, TraceConfig
    print("# Table VI — KV checkpoint dedup (per 1,000 tokens)")
    # model -> workload snapshot (paper reports one workload mix; we pair
    # each model with a plausible deployment mix and report the band)
    pairing = {"llama-3-70b": "lmsys", "deepseek-v3": "agentic",
               "mixtral-8x22b": "sharegpt"}
    for name, wl in pairing.items():
        cfg = PAPER_MODELS[name]
        per_tok = sizing.per_token_layer_bytes(cfg) * cfg.n_layers
        raw_mb = per_tok * 1000 / 1e6
        trace = GENERATORS[wl](TraceConfig(n_sessions=64, seed=0,
                                           concurrency=32))
        # snapshot: every session's distinct context blocks
        per_session = defaultdict(list)
        for ev in trace:
            if ev.content_id not in per_session[ev.session]:
                per_session[ev.session].append(ev.content_id)
        store = ContentStore()
        blocks = []
        for sid, ids in per_session.items():
            for cid in ids:
                blocks.append((content_hash(cid, salt=name), per_tok * 128))
        manifest = delta_checkpoint(blocks, store)
        savings = manifest.savings
        dedup_mb = raw_mb * (1 - savings)
        exp = PAPER["table6"][name]
        _row(f"table6.{name}.raw_mb", round(raw_mb, 1), exp[0])
        _row(f"table6.{name}.dedup_mb", round(dedup_mb, 1), exp[1])
        _row(f"table6.{name}.savings_pct", round(100 * savings, 1), exp[2])


def table_iv_vii_viii(hit_rates: Dict[str, float]) -> None:
    """Analytical projections: tier increments, end-to-end, ablations."""
    from repro.core.projection import Projector, WorkloadModel
    hit = hit_rates.get("lmsys.bayesian", 0.842)
    lru = hit_rates.get("lmsys.lru", 0.778)
    proj = Projector(wl=WorkloadModel(hit_rate_hot=hit))
    print("# Table IV — projected incremental tier impact (Llama-3-70B,"
          " LMSYS, 128K ctx)")
    for i, r in enumerate(proj.table_iv()):
        exp = PAPER["table4"][i]
        cap_gb = min(r.capacity_bytes, proj.capacity(5)) / 1024 ** 3
        _row(f"table4.{r.config}.capacity_gb", round(cap_gb), exp[1])
        _row(f"table4.{r.config}.ttft_p99_s", round(r.ttft_p99, 1), exp[2])
        _row(f"table4.{r.config}.tput", round(r.tput_tok_s_gpu), exp[3])

    print("# Table VII — projected end-to-end (ours vs published baselines)")
    ours = proj.project(6, name="ours")
    for sysname, exp in PAPER["table7"].items():
        if sysname.startswith("Ours"):
            _row("table7.ours.ttft_p50", round(ours.ttft_p50, 2), exp[0])
            _row("table7.ours.ttft_p99", round(ours.ttft_p99, 2), exp[1])
            _row("table7.ours.tput", round(ours.tput_tok_s_gpu), exp[3])
            _row("table7.ours.cost_mtok", round(ours.cost_per_mtok, 2),
                 exp[4])
    # FlexGen model: CPU+disk tiers only, reactive policy, LRU-grade
    # hits, and a non-paged allocator (0.45x the PagedAttention anchor's
    # achievable batch)
    flexgen = proj.project(4, name="flexgen-style", predictive=False,
                           hit_rate=lru, batch_factor=0.45)
    _row("table7.reactive_offload.tput", round(flexgen.tput_tok_s_gpu),
         PAPER["table7"]["FlexGen"][3])
    _row("table7.speedup_vs_reactive",
         round(ours.tput_tok_s_gpu / flexgen.tput_tok_s_gpu, 1), 6.4)

    print("# Table VIII — projected ablations (throughput delta %)")
    rows = proj.table_viii(lambda pol: hit_rates.get(f"lmsys.{pol}", lru))
    for r in rows:
        exp = PAPER["table8"].get(r["component"])
        _row(f"table8.{r['component']}.delta_pct",
             round(r["delta_pct"], 1), exp)


def table_ix(fast: bool = False) -> None:
    """Hyperparameter sensitivity via LMSYS replay."""
    from repro.configs.paper_models import LLAMA3_70B
    from repro.traces import TraceConfig, lmsys_trace
    from repro.traces.replay import REPLAY_HOT_BLOCKS, replay
    print("# Table IX — parameter sensitivity (LMSYS replay)")
    trace = lmsys_trace(TraceConfig(n_sessions=60 if fast else 100, seed=0))
    hot = REPLAY_HOT_BLOCKS["lmsys"]

    def run(**predictor_kwargs):
        r = replay(trace, LLAMA3_70B, policy="bayesian", workload="lmsys",
                   hot_blocks=hot,
                   predictor_kwargs=predictor_kwargs or None)
        return r.hit_rate

    base = run()
    # eviction recency-bias sweep (the policy's predicted-reuse horizon —
    # our analogue of the paper's EMA decay: how strongly recency is
    # discounted against predicted reuse)
    d_rates = []
    for h in (25.0, 50.0, 100.0, 200.0, 400.0):
        r = replay(trace, LLAMA3_70B, policy="bayesian", workload="lmsys",
                   hot_blocks=hot, policy_kwargs={"horizon": h})
        d_rates.append(r.hit_rate)
    _row("table9.ema_decay(recency_bias).variation_pct",
         round(100 * (max(d_rates) - min(d_rates)) / base, 2), "<5")
    p_rates = [run(prior_alpha=a, prior_beta=a) for a in (0.5, 1.0, 4.0)]
    _row("table9.beta_prior.variation_pct",
         round(100 * (max(p_rates) - min(p_rates)) / base, 2), "<2")
    c_rates = [run(confidence_k=k) for k in (5.0, 20.0, 80.0)]
    _row("table9.confidence_k.variation_pct",
         round(100 * (max(c_rates) - min(c_rates)) / base, 2), "<3")


def replay_benchmark(fast: bool = False, backend: str = None) -> None:
    """Table V at the serving layer: the ShareGPT / LMSYS / agentic
    session traces replayed end-to-end through the live ``ServingEngine``
    (paged pool, CoW prefix sharing, chunked prefill, async tier
    transfers) under a virtual clock — see
    ``src/repro/traces/serving_replay.py``.

    ``hit_pct`` is the engine-level tier-0/1 hit rate over previously-
    seen prompt blocks (the Table V definition measured at the engine);
    ``reuse_pct`` additionally counts blocks served from tiers 2+
    (compute still skipped, fetch paid).  TTFT/TBT/throughput are virtual
    -clock percentiles, where lower-tier fetches stall at paper-scale
    block sizes — the serving-layer coupling between hit rate and
    latency that block-level replay cannot show.
    """
    from repro.kernels.backend import resolve_backend
    from repro.traces.serving_replay import run_replay_serving_table
    print("# Table V (serving) — live-engine trace replay"
          + (" [fast]" if fast else "")
          + f" [kernel backend: {resolve_backend(backend)}]")
    rows = run_replay_serving_table(
        n_sessions=6 if fast else 12, max_turns=4 if fast else 6,
        kernel_backend=backend)
    for r in rows:
        exp = PAPER["table5"][r.workload]
        idx = {"lru": 0, "ema": 1, "bayesian": 2}[r.policy]
        key = f"replay.{r.workload}.{r.policy}"
        _row(f"{key}.hit_pct", round(100 * r.engine_hit_rate, 1), exp[idx])
        _row(f"{key}.reuse_pct", round(100 * r.reuse_rate, 1))
        _row(f"{key}.hits_t0_pool", r.hot_hits_t0)
        _row(f"{key}.hits_t1_dram", r.hot_hits_t1)
        _row(f"{key}.cow_share_hits", r.cow_share_hits)
        _row(f"{key}.inject_hits", r.inject_hits)
        _row(f"{key}.promotions", r.promotions)
        _row(f"{key}.ttft_p50_ms", round(1e3 * r.ttft_p50, 1))
        _row(f"{key}.ttft_p95_ms", round(1e3 * r.ttft_p95, 1))
        _row(f"{key}.tbt_p50_ms", round(1e3 * r.tbt_p50, 1))
        _row(f"{key}.tbt_p95_ms", round(1e3 * r.tbt_p95, 1))
        _row(f"{key}.virtual_tok_per_s", round(r.throughput_tok_s, 1))
        _row(f"{key}.requests", r.requests_done)
        _row(f"{key}.wall_s", round(r.wall_s, 1))


def cluster_benchmark(fast: bool = False, backend: str = None) -> None:
    """Fleet-level trace replay: the LMSYS trace through a multi-replica
    ``ReplicaCluster`` (``serving/cluster.py``), sweeping ``n_replicas x
    routing_policy`` under the shared virtual clock, plus one failover
    cell (2 replicas, kill one mid-replay).

    The headline question: does consistent-hash session affinity
    (``affine``) recover the single-engine hit rate that session-blind
    ``round_robin`` routing fragments across replica-private caches?
    The 1-replica affine cell should match ``--table replay``'s lmsys
    bayesian cell within noise (same harness, fleet of one); at n>=2
    affine must beat round-robin on fleet hit rate.  See
    ``docs/SERVING.md`` for the full column glossary.
    """
    from repro.kernels.backend import resolve_backend
    from repro.traces.serving_replay import (ClusterReplayConfig,
                                             run_cluster_replay,
                                             run_cluster_table)
    print("# Cluster — multi-replica LMSYS replay, n_replicas x routing"
          + (" [fast]" if fast else "")
          + f" [kernel backend: {resolve_backend(backend)}]")
    n_sessions = 6 if fast else 12
    max_turns = 4 if fast else 6
    exp = PAPER["table5"]["lmsys"][2]      # Table V lmsys bayesian
    rows = run_cluster_table(n_replicas=(1, 2) if fast else (1, 2, 4),
                             n_sessions=n_sessions, max_turns=max_turns,
                             kernel_backend=backend)
    for r in rows:
        key = f"cluster.lmsys.n{r.n_replicas}.{r.routing}"
        _row(f"{key}.fleet_hit_pct", round(100 * r.fleet_hit_rate, 1), exp)
        _row(f"{key}.fleet_reuse_pct", round(100 * r.fleet_reuse_rate, 1))
        for p in r.per_replica:
            _row(f"{key}.{p.name}.hit_pct", round(100 * p.hit_rate, 1))
            _row(f"{key}.{p.name}.requests", p.requests_done)
        _row(f"{key}.redispatched", r.redispatched, 0)
        _row(f"{key}.reprefill_tokens", r.reprefill_tokens, 0)
        _row(f"{key}.ttft_p50_ms", round(1e3 * r.ttft_p50, 1))
        _row(f"{key}.ttft_p95_ms", round(1e3 * r.ttft_p95, 1))
        _row(f"{key}.tbt_p95_ms", round(1e3 * r.tbt_p95, 1))
        _row(f"{key}.virtual_tok_per_s", round(r.throughput_tok_s, 1))
        _row(f"{key}.wall_s", round(r.wall_s, 1))
    # shared tier-4 cells: same sweep with the fleet-shared namespace —
    # the incl_shared column is the fleet hit counting cross-replica
    # tier-4 imports (a fabric fetch instead of a re-prefill); the
    # recovered points vs the replica-private cells above come at the
    # cost of shared_fetch stalls (fetched blocks)
    shared_rows = run_cluster_table(
        n_replicas=(1, 2) if fast else (1, 2, 4),
        n_sessions=n_sessions, max_turns=max_turns,
        kernel_backend=backend, shared_tier=True)
    for r in shared_rows:
        key = f"cluster.lmsys.shared.n{r.n_replicas}.{r.routing}"
        _row(f"{key}.fleet_hit_pct", round(100 * r.fleet_hit_rate, 1))
        _row(f"{key}.fleet_hit_incl_shared_pct",
             round(100 * r.fleet_hit_rate_incl_shared, 1), exp)
        _row(f"{key}.shared_fetch_blocks", r.shared_hit_blocks)
        _row(f"{key}.ttft_p95_ms", round(1e3 * r.ttft_p95, 1))
        _row(f"{key}.virtual_tok_per_s", round(r.throughput_tok_s, 1))
        _row(f"{key}.wall_s", round(r.wall_s, 1))
    # prefix-aware routing cell: probe every replica's radix tree and
    # route to the longest live prefix (shared tier on)
    pr = run_cluster_replay(ClusterReplayConfig(
        workload="lmsys", policy="bayesian", n_sessions=n_sessions,
        max_turns=max_turns, n_replicas=2, routing="prefix",
        kernel_backend=backend, shared_tier=True))
    key = "cluster.lmsys.shared.n2.prefix"
    _row(f"{key}.fleet_hit_pct", round(100 * pr.fleet_hit_rate, 1))
    _row(f"{key}.fleet_hit_incl_shared_pct",
         round(100 * pr.fleet_hit_rate_incl_shared, 1), exp)
    _row(f"{key}.shared_fetch_blocks", pr.shared_hit_blocks)
    _row(f"{key}.ttft_p95_ms", round(1e3 * pr.ttft_p95, 1))
    _row(f"{key}.wall_s", round(pr.wall_s, 1))
    # failover cell: 2 affine replicas, one killed mid-replay — the
    # graceful-degradation recomputation tax
    f = run_cluster_replay(ClusterReplayConfig(
        workload="lmsys", policy="bayesian", n_sessions=n_sessions,
        max_turns=max_turns, n_replicas=2, routing="affine",
        fail_replica_after_turns=max(2, n_sessions // 2),
        kernel_backend=backend))
    key = "cluster.lmsys.failover.n2.affine"
    _row(f"{key}.fleet_hit_pct", round(100 * f.fleet_hit_rate, 1))
    _row(f"{key}.redispatched", f.redispatched)
    _row(f"{key}.reprefill_tokens", f.reprefill_tokens)
    _row(f"{key}.failed_replicas", len(f.failed_replicas), 1)
    _row(f"{key}.ttft_p95_ms", round(1e3 * f.ttft_p95, 1))
    _row(f"{key}.requests", f.requests_done)
    _row(f"{key}.wall_s", round(f.wall_s, 1))
    # scale-out cells: third replica joins mid-replay, with and without
    # the warm-up push — the post-join TTFT spike the warm-up removes
    for warm in (False, True):
        j = run_cluster_replay(ClusterReplayConfig(
            workload="lmsys", policy="bayesian", n_sessions=n_sessions,
            max_turns=max_turns, n_replicas=2, routing="affine",
            add_replica_after_turns=max(2, n_sessions // 2),
            shared_tier=True, warmup_on_add=warm,
            kernel_backend=backend))
        key = ("cluster.lmsys.join.n2to3.warmup" if warm
               else "cluster.lmsys.join.n2to3.cold")
        _row(f"{key}.postjoin_ttft_p95_ms",
             round(1e3 * j.postjoin_ttft_p95, 1),
             "<=1.2x steady" if warm else None)
        _row(f"{key}.steady_ttft_p95_ms", round(1e3 * j.steady_ttft_p95, 1))
        _row(f"{key}.warmed_sessions", j.warmed_sessions)
        _row(f"{key}.warmed_blocks", j.warmed_blocks)
        _row(f"{key}.fleet_hit_incl_shared_pct",
             round(100 * j.fleet_hit_rate_incl_shared, 1))
        _row(f"{key}.wall_s", round(j.wall_s, 1))


def segments_benchmark(fast: bool = False, backend: str = None) -> None:
    """Segment-reuse A/B (``--table segments``): every workload replayed
    through the live engine twice on the same seeded trace — content-
    segment index on (mid-prompt blocks resumable beyond the contiguous
    radix prefix) vs the monolithic-radix baseline
    (``EngineConfig(segment_reuse=False)``).

    The headline cell is ShareGPT: its sessions truncate conversation
    history (oldest turns dropped), shifting the surviving turn blocks
    left by whole blocks — a radix tree loses everything past the first
    shifted block, while the content-segment index recovers the blocks
    at their new positions (position-independent reuse; resumed KV
    carries the RoPE/context of its original position — see
    docs/EVALUATION.md §7).  ``delta_pts`` is the engine hit-rate lift
    in percentage points; ``lookup_us_per_call`` is the measured
    segment-index probe cost the lift pays for.
    """
    from repro.kernels.backend import resolve_backend
    from repro.traces.serving_replay import (ServingReplayConfig,
                                             run_serving_replay)
    print("# Segments — segment-index vs monolithic-radix A/B"
          + (" [fast]" if fast else "")
          + f" [kernel backend: {resolve_backend(backend)}]")
    n_sessions = 6 if fast else 12
    max_turns = 4 if fast else 6
    for wl in ("sharegpt", "lmsys", "agentic"):
        rows = {}
        for seg in (False, True):
            rows[seg] = run_serving_replay(ServingReplayConfig(
                workload=wl, n_sessions=n_sessions, max_turns=max_turns,
                kernel_backend=backend, segment_reuse=seg))
        off, on = rows[False], rows[True]
        key = f"segments.{wl}"
        _row(f"{key}.hit_pct_radix", round(100 * off.engine_hit_rate, 1))
        _row(f"{key}.hit_pct_segments", round(100 * on.engine_hit_rate, 1))
        _row(f"{key}.delta_pts",
             round(100 * (on.engine_hit_rate - off.engine_hit_rate), 1),
             ">=5" if wl == "sharegpt" else None)
        _row(f"{key}.reuse_pct_radix", round(100 * off.reuse_rate, 1))
        _row(f"{key}.reuse_pct_segments", round(100 * on.reuse_rate, 1))
        _row(f"{key}.segment_hit_blocks", on.segment_hit_blocks)
        _row(f"{key}.segment_share_hits", on.segment_share_hits)
        _row(f"{key}.segment_inject_hits", on.segment_inject_hits)
        _row(f"{key}.segment_lookups", on.segment_lookups)
        us = (1e6 * on.segment_lookup_s / on.segment_lookups
              if on.segment_lookups else 0.0)
        _row(f"{key}.lookup_us_per_call", round(us, 1))
        _row(f"{key}.ttft_p95_ms_radix", round(1e3 * off.ttft_p95, 1))
        _row(f"{key}.ttft_p95_ms_segments", round(1e3 * on.ttft_p95, 1))
        _row(f"{key}.wall_s",
             round(off.wall_s + on.wall_s, 1))


def chaos_benchmark(fast: bool = False, backend: str = None) -> None:
    """Fault-injected serving replay (``--table chaos``): the LMSYS trace
    through the live engine under seeded tier-fault profiles
    (``core/faults.py``), sweeping fault severity:

      * ``control`` — no injector attached.  The fault path is inert, so
        this row must reproduce ``--table segments``'s lmsys
        segment-reuse cell exactly (same config, same seed).
      * ``pressure_control`` — fault-free baseline for every faulted
        row.  The default replay capacities never fill the paper-scale
        tiers 2-5 (no traffic, so no fault exposure); the faulted cells
        cap tiers 0-3 at 16 blocks each, cascading real demote/promote
        traffic into NVMe and the RDMA pool.
      * ``transient_1e-3`` / ``transient_1e-2`` — per-op transient
        read/write error rates on tiers 2-5, plus a 10x-lower payload
        corruption rate.  Transient errors are absorbed by bounded
        retries (``retries``); exhausted budgets escalate
        (``io_errors``) and the fetch converts to a recompute; corrupt
        payloads are caught by the crc gate (``integrity_failures``).
      * ``nvme_brownout`` — 25% of tier-3 ops land in a 10x latency
        brownout (inflation shows in TTFT p99 via the stall model, no
        errors).  ``rdma_flap`` — tier-4 ring nodes flap under
        in-flight ops, failing them transiently.

    Acceptance invariants asserted per row: zero hung requests
    (``turns_submitted == requests_done``) and every injected corruption
    caught by its crc32 check before any payload reaches a decode
    (``integrity_failures == injected_corruptions``).
    """
    from repro.core.faults import FaultProfile
    from repro.kernels.backend import resolve_backend
    from repro.traces.serving_replay import (ServingReplayConfig,
                                             run_serving_replay)
    print("# Chaos — fault-injected lmsys replay, severity sweep"
          + (" [fast]" if fast else "")
          + f" [kernel backend: {resolve_backend(backend)}]")
    n_sessions = 6 if fast else 12
    max_turns = 4 if fast else 6
    pressure = dict(hot_blocks=16, t1_blocks=16, t2_blocks=16,
                    t3_blocks=16)
    cells = [("control", None, {}),
             ("pressure_control", None, pressure)]
    for rate in (1e-3, 1e-2):
        cells.append((f"transient_{rate:g}",
                      {t: FaultProfile(read_error_rate=rate,
                                       write_error_rate=rate,
                                       corruption_rate=rate / 10)
                       for t in (2, 3, 4, 5)}, pressure))
    cells.append(("nvme_brownout",
                  {3: FaultProfile(brownout_rate=0.25,
                                   brownout_latency_mult=10.0)}, pressure))
    cells.append(("rdma_flap", {4: FaultProfile(flap_rate=0.05)},
                  pressure))
    baselines: Dict[str, object] = {}
    for name, profiles, extra in cells:
        r = run_serving_replay(ServingReplayConfig(
            workload="lmsys", policy="bayesian", n_sessions=n_sessions,
            max_turns=max_turns, kernel_backend=backend,
            fault_profiles=profiles, fault_seed=7, **extra))
        cfg_key = repr(sorted(extra.items()))
        if profiles is None:
            baselines[cfg_key] = r
        base = baselines.get(cfg_key)
        key = f"chaos.lmsys.{name}"
        hung = r.turns_submitted - r.requests_done
        corruptions = r.injected.get("injected_corruptions", 0)
        _row(f"{key}.hit_pct", round(100 * r.engine_hit_rate, 1))
        _row(f"{key}.ttft_p99_ms", round(1e3 * r.ttft_p99, 1))
        if profiles is not None and base is not None and base.ttft_p99 > 0:
            _row(f"{key}.ttft_p99_inflation_x",
                 round(r.ttft_p99 / base.ttft_p99, 2))
        _row(f"{key}.retries", r.retries)
        _row(f"{key}.io_errors", r.io_errors)
        _row(f"{key}.injected_corruptions", corruptions)
        _row(f"{key}.integrity_failures", r.integrity_failures, corruptions)
        _row(f"{key}.fetch_recomputes", r.fetch_recomputes)
        _row(f"{key}.retry_delay_ms", round(1e3 * r.retry_delay_s, 2))
        _row(f"{key}.unhealthy_tiers",
             sum(1 for s in r.tier_health.values() if s != "healthy"), 0)
        _row(f"{key}.hung_requests", hung, 0)
        _row(f"{key}.requests", r.requests_done)
        _row(f"{key}.wall_s", round(r.wall_s, 1))
        assert hung == 0, f"chaos {name}: {hung} hung requests"
        assert r.integrity_failures == corruptions, (
            f"chaos {name}: {corruptions} corruptions injected, "
            f"{r.integrity_failures} caught")


def micro_benchmarks() -> None:
    """System micro-benchmarks backing the paper's latency claims."""
    from repro.core.bayesian import BayesianReusePredictor
    from repro.core.dedup import RadixTree
    print("# Micro — component latencies")
    tree = RadixTree(128)
    rng = np.random.default_rng(0)
    seqs = [list(rng.integers(0, 1000, size=512)) for _ in range(200)]
    for i, s in enumerate(seqs):
        tree.insert(s, [f"b{i}-{j}" for j in range(4)])
    t0 = time.perf_counter()
    n = 0
    for s in seqs:
        tree.match(s)
        n += 4
    us = (time.perf_counter() - t0) / n * 1e6
    _row("micro.radix_lookup_us_per_block", round(us, 2), "<1")
    pred = BayesianReusePredictor()
    t0 = time.perf_counter()
    for i in range(20000):
        pred.observe("system_prompt", "same_tool_repeat", i % 3 != 0)
    us = (time.perf_counter() - t0) / 20000 * 1e6
    _row("micro.bayes_update_us", round(us, 2), "O(1)")
    t0 = time.perf_counter()
    for _ in range(20000):
        pred.reuse_probability("system_prompt", "same_tool_repeat")
    us = (time.perf_counter() - t0) / 20000 * 1e6
    _row("micro.bayes_query_us", round(us, 2), "O(1)")


def serving_benchmark(paged: bool, fast: bool = False,
                      backend: str = None) -> None:
    """Live-engine throughput through the paged block-table KV path
    (``--paged``, default) or the dense slot fallback (``--no-paged``),
    under the selected kernel backend (``--backend``; default: compiled
    xla off-TPU).

    The paged rows also report the async tier-transfer worker's stats:
    transfers complete off the step loop, so ``step_blocked_on_transfer``
    is structurally 0 — preemption demotions and RoPE prefetch
    promotions run on the worker thread while decode proceeds.
    """
    from repro.config import reduce_config
    from repro.configs import get_config
    from repro.serving import EngineConfig, SamplingParams, ServingEngine
    mode = "paged" if paged else "dense"
    cfg = reduce_config(get_config("llama3.2-1b"))
    eng = ServingEngine(cfg, EngineConfig(max_len=128,
                                          kv_budget_bytes=1e6,
                                          paged=paged,
                                          kernel_backend=backend))
    # the dense path never calls the paged ops (plain XLA attention),
    # so the backend knob only applies to the paged rows
    be_label = eng.kernel_backend if paged else "n/a (dense path)"
    print(f"# Serving — {mode} engine A/B (reduced llama3.2-1b, "
          f"kernel backend: {be_label})")
    rng = np.random.default_rng(0)
    templates = [[int(t) for t in rng.integers(0, 200, size=64)]
                 for _ in range(3)]
    n_req = 8 if fast else 16
    for i in range(n_req):
        user = [int(t) for t in rng.integers(0, 200, size=16)]
        eng.submit(templates[i % 3] + user,
                   params=SamplingParams(max_new_tokens=8),
                   session_id=f"s{i}", block_type="system_prompt")
    eng.step()                       # exclude jit compile from the timing
    warm_tokens = sum(len(r.generated) for r in eng.scheduler.done) + \
        sum(len(r.generated) for r in eng.scheduler.running.values())
    # separate timing windows: steps that ran prefill work (chunk grants,
    # or monolithic prefill at admission) vs pure-decode steps.  PR 2's
    # single window mixed interpret-mode chunk prefills into the decode
    # tok/s denominator, which read as a paged-decode regression on CPU.
    t_prefill = t_decode = 0.0
    prefill_window_tokens = decode_window_tokens = 0
    t0 = time.perf_counter()
    while eng.scheduler.has_work() and eng.steps < 10_000:
        running_before = set(eng.scheduler.running)
        done_before = len(eng.scheduler.done)
        ts = time.perf_counter()
        produced = eng.step()
        dt_step = time.perf_counter() - ts
        # admissions ran (monolithic) prefill this step: a request newly
        # in running — or admitted and finished within the step
        now_ids = set(eng.scheduler.running) | {
            r.request_id for r in eng.scheduler.done[done_before:]}
        admitted = bool(now_ids - running_before)
        if eng.last_step_prefill_tokens > 0 or admitted:
            t_prefill += dt_step
            prefill_window_tokens += eng.last_step_prefill_tokens
        else:
            t_decode += dt_step
            decode_window_tokens += produced
        if produced == 0 and not eng.scheduler.running \
                and eng.scheduler.blocked:
            eng.idle_transfer_waits += 1
            time.sleep(1e-3)
    dt = time.perf_counter() - t0
    stats = eng.stats()
    sch = stats["scheduler"]
    _row(f"serving.{mode}.kernel_backend", be_label)
    _row(f"serving.{mode}.done", sch["done"])
    _row(f"serving.{mode}.steps", stats["steps"])
    _row(f"serving.{mode}.tok_per_s",
         round((sch["generated_tokens"] - warm_tokens) / dt, 1))
    _row(f"serving.{mode}.prefill_window_s", round(t_prefill, 3))
    _row(f"serving.{mode}.decode_window_s", round(t_decode, 3))
    if t_decode > 0:
        # decode-phase throughput over pure-decode steps only — the
        # apples-to-apples paged-vs-dense decode comparison
        _row(f"serving.{mode}.decode_tok_per_s",
             round(decode_window_tokens / t_decode, 1))
    if t_prefill > 0:
        _row(f"serving.{mode}.prefill_tok_per_s",
             round(prefill_window_tokens / t_prefill, 1))
    _row(f"serving.{mode}.prefix_hit_blocks", sch["prefix_hit_blocks"])
    if stats.get("allocator"):
        al = stats["allocator"]
        _row(f"serving.{mode}.pages_peak", al["peak_in_use"])
        _row(f"serving.{mode}.cow_shares", al["shares"])
        _row(f"serving.{mode}.cow_copies", al["cow_copies"])
    aw = stats.get("async_transfers")
    if aw:
        _row(f"serving.{mode}.async_completed", aw["completed"])
        _row(f"serving.{mode}.async_max_inflight", aw["max_inflight"])
        _row(f"serving.{mode}.async_sim_time_s",
             round(aw["sim_time_total"], 6))
        # measured: run() iterations that had nothing to decode because
        # every live request was waiting on a KV fetch
        _row(f"serving.{mode}.idle_transfer_waits",
             stats["idle_transfer_waits"], 0)
    eng.shutdown()


def ttft_benchmark(chunked: bool, fast: bool = False,
                   backend: str = None) -> None:
    """TTFT under mixed load: short decode streams with long prompts
    arriving mid-stream, chunked vs monolithic prefill (``--chunked`` /
    ``--no-chunked`` A/B).

    The paper's headline serving claim is TTFT tail latency (Table VII
    projects 0.4s p50 / 1.1s p99 for the full system, a 1.4-2.1x
    reduction over baselines); the chunked rows show the structural
    mechanism — no single step prefills more than ``max_step_tokens``
    prompt tokens, so the worst-case step time (the inter-token stall
    running decodes see when a long prompt lands) stays bounded, where
    the monolithic path runs the whole prompt inline in one step.
    """
    from repro.config import reduce_config
    from repro.configs import get_config
    from repro.serving import EngineConfig, SamplingParams, ServingEngine
    mode = "chunked" if chunked else "monolithic"
    cfg = reduce_config(get_config("llama3.2-1b"))
    eng = ServingEngine(cfg, EngineConfig(
        max_len=640, kv_budget_bytes=2.5e6, max_step_tokens=96,
        prefill_chunk_tokens=32, chunked_prefill=chunked,
        kernel_backend=backend))
    print(f"# TTFT A/B — {mode} prefill, short decodes + mid-stream "
          f"long prompts (reduced llama3.2-1b, kernel backend: "
          f"{eng.kernel_backend})")
    rng = np.random.default_rng(0)

    def _prompt(n):
        return [int(t) for t in rng.integers(0, 250, size=n)]

    # warm the jit caches (prefill / chunk / decode) off the clock
    eng.submit(_prompt(40), params=SamplingParams(max_new_tokens=2))
    eng.submit(_prompt(500), params=SamplingParams(max_new_tokens=2))
    eng.run()
    eng.scheduler.done.clear()

    n_short = 4 if fast else 8
    n_long = 1 if fast else 2
    shorts = [eng.submit(_prompt(24),
                         params=SamplingParams(max_new_tokens=24))
              for _ in range(n_short)]
    for _ in range(3):
        eng.step()
    longs = [eng.submit(_prompt(480),
                        params=SamplingParams(max_new_tokens=8))
             for _ in range(n_long)]
    # tokens produced during the untimed ramp-up steps don't count
    warm_tokens = sum(len(r.generated) for r in shorts)
    t0 = time.perf_counter()
    step_max = 0.0
    while eng.scheduler.has_work():
        ts = time.perf_counter()
        eng.step()
        step_max = max(step_max, time.perf_counter() - ts)
    dt = time.perf_counter() - t0

    def pct(vals, p):
        vals = sorted(vals)
        return vals[min(len(vals) - 1, int(p * len(vals)))]

    short_ttfts = [r.ttft for r in shorts]
    long_ttfts = [r.ttft for r in longs]
    gen = sum(len(r.generated) for r in shorts + longs) - warm_tokens
    exp = PAPER["table7"]["Ours (projected)"]
    _row(f"ttft.{mode}.short_p50_ms", round(1e3 * pct(short_ttfts, .5), 1))
    _row(f"ttft.{mode}.short_p99_ms", round(1e3 * pct(short_ttfts, .99), 1))
    _row(f"ttft.{mode}.long_p50_ms", round(1e3 * pct(long_ttfts, .5), 1))
    _row(f"ttft.{mode}.paper_ttft_p50_s", "", exp[0])
    _row(f"ttft.{mode}.paper_ttft_p99_s", "", exp[1])
    _row(f"ttft.{mode}.tok_per_s", round(gen / dt, 1))
    _row(f"ttft.{mode}.max_step_ms", round(1e3 * step_max, 1))
    if chunked:
        _row(f"ttft.{mode}.max_step_prompt_tokens",
             eng.max_step_prefill_tokens, "<=96")
    eng.shutdown()


def steploop_benchmark(fast: bool = False, backend: str = None) -> None:
    """Step-loop dispatch-vs-compute microbench (``--table steploop``).

    Fused vs unfused rows per batch; the acceptance gate on the fused
    largest-batch row is host_overhead < kernel_time (the ROADMAP
    "host-overhead war" target: batch 16 on CPU-xla).  ``--fast``
    (CI smoke) shrinks batch and step counts to fit the smoke budget —
    the gate row is only meaningful at full scale.
    """
    from benchmarks.steploop_bench import run_steploop_table
    batches = (8,) if fast else (4, 16)
    steps = 10 if fast else 30
    print(f"# Step loop — dispatch vs compute per stage "
          f"(reduced llama3.2-1b){' [fast]' if fast else ''}")
    run_steploop_table(batches=batches, backend=backend, steps=steps)


def slo_benchmark(fast: bool = False, backend: str = None) -> None:
    """Latency-under-load curve (``--table slo``): offered-QPS sweep
    through the wall-clock ``ServingFrontend`` under open-loop Poisson
    load, with and without SLO admission control.

    The sweep runs the *real* front-end pump (admission, SLO queue,
    streaming delivery) over the live engine under a virtual clock with
    the per-step cost pinned to the fused-step latency measured by
    ``--table steploop`` (≈5 ms at batch 16 on CPU-xla) — so the table
    is deterministic and the rates are meaningful fractions of true
    engine capacity.  ``max_step_tokens`` is capped to put the knee of
    the curve inside the sweep.  At the top (overloaded) rate an
    uncontrolled A/B (infinite budget) shows p99 TTFT breaching the
    budget that the admission controller holds.
    """
    from repro.serving.frontend import ServingFrontend, SLOConfig, VirtualClock
    from repro.traces.loadgen import offered_summary, trace_load
    from repro.traces.serving_replay import ServingReplayConfig, build_engine

    step_s = 5e-3                   # --table steploop fused batch-16 CPU-xla
    budget_s = 0.150
    rates = (8.0, 32.0, 64.0) if fast else (8.0, 16.0, 32.0, 64.0)
    n_req = 40 if fast else 120
    workload = "lmsys"
    print(f"# SLO sweep — open-loop {workload} load through "
          f"ServingFrontend (ttft budget {budget_s * 1e3:.0f} ms, "
          f"virtual step {step_s * 1e3:.0f} ms)"
          f"{' [fast]' if fast else ''}")

    def run_rate(rate: float, budget: float) -> dict:
        rcfg = ServingReplayConfig(workload=workload, n_sessions=8,
                                   seed=0, async_transfers=False,
                                   kernel_backend=backend,
                                   max_step_tokens=32)
        fe = ServingFrontend(
            build_engine(rcfg), clock=VirtualClock(), step_time_s=step_s,
            slo=SLOConfig(ttft_budget_s=budget, action="shed"))
        arrivals = trace_load(workload, rate, n_requests=n_req, seed=7,
                              n_sessions=8, max_turns=3)
        fe.serve_schedule(arrivals)
        fe.check_ledger()
        st = fe.stats()
        st["offered_qps"] = offered_summary(arrivals)["offered_qps"]
        fe.stop()
        return st

    for rate in rates:
        st = run_rate(rate, budget_s)
        key = f"slo.qps{rate:g}"
        _row(f"{key}.offered_qps", round(st["offered_qps"], 1))
        _row(f"{key}.offered", st["offered"])
        _row(f"{key}.done", st["done"])
        _row(f"{key}.shed", st["shed"])
        _row(f"{key}.goodput", st["goodput"])
        _row(f"{key}.ttft_p50_ms", round(1e3 * st["ttft_p50"], 1))
        _row(f"{key}.ttft_p99_ms", round(1e3 * st["ttft_p99"], 1))
        _row(f"{key}.tbt_p50_ms", round(1e3 * st["tbt_p50"], 1))
        _row(f"{key}.tbt_p99_ms", round(1e3 * st["tbt_p99"], 1))
    # uncontrolled A/B at the top (shed-inducing) rate
    st = run_rate(rates[-1], float("inf"))
    key = f"slo.qps{rates[-1]:g}.uncontrolled"
    _row(f"{key}.done", st["done"])
    _row(f"{key}.shed", st["shed"])
    _row(f"{key}.ttft_p50_ms", round(1e3 * st["ttft_p50"], 1))
    _row(f"{key}.ttft_p99_ms", round(1e3 * st["ttft_p99"], 1))
    _row("slo.budget_ms", round(1e3 * budget_s, 1))


def kernel_benchmarks(backend: str = None, fast: bool = False) -> None:
    """Per-op kernel-backend microbenchmark (``--table kernels``).

    Times every paged op under each available backend — ``xla``
    (compiled jnp gathers, the off-TPU serving default) vs ``interpret``
    (the Pallas interpreter, the old off-TPU path) and ``pallas`` when
    running on a TPU — across decode/prefill x GQA/MQA/MLA shapes, so a
    backend regression is measurable in isolation from the engine.
    Also prints the xla-vs-oracle allclose gate per op (full sweeps in
    ``tests/test_xla_backend.py``).
    """
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kernels.backend import on_tpu

    backends = [backend] if backend else (
        ["xla", "interpret"] + (["pallas"] if on_tpu() else []))
    print(f"# Kernels — per-op latency by backend ({'/'.join(backends)}) "
          "+ xla-vs-oracle allclose")
    rng = np.random.default_rng(0)

    def arr(shape):
        return jnp.asarray(rng.normal(size=shape), jnp.float32)

    def table(b, pages, n):
        return jnp.asarray(
            rng.permutation(n)[:b * pages].reshape(b, pages), jnp.int32)

    # decode: one query per request over 4 pages x 64 tokens
    b, page, pages, hd = 4, 64, 4, 64
    n = b * pages + 2
    cases = []
    for name, hq, hkv in (("decode_gqa", 8, 2), ("decode_mha", 4, 4),
                          ("decode_mqa", 8, 1)):
        q, kp, vp = arr((b, hq, hd)), arr((n, page, hkv, hd)), \
            arr((n, page, hkv, hd))
        bt = table(b, pages, n)
        ln = jnp.asarray(rng.integers(page, pages * page, size=b), jnp.int32)
        cases.append((name,
                      lambda be, q=q, kp=kp, vp=vp, bt=bt, ln=ln:
                      ops.paged_decode(q, kp, vp, bt, ln, backend=be),
                      lambda q=q, kp=kp, vp=vp, bt=bt, ln=ln:
                      ops.paged_decode_ref(q, kp, vp, bt, ln)))
    dl, dr = 64, 16
    ql, qr = arr((b, 8, dl)), arr((b, 8, dr))
    lat = arr((n, page, dl + dr))
    bt = table(b, pages, n)
    ln = jnp.asarray(rng.integers(page, pages * page, size=b), jnp.int32)
    cases.append(("decode_mla",
                  lambda be: ops.mla_decode(ql, qr, lat, bt, ln,
                                            d_latent=dl, backend=be),
                  lambda: ops.mla_decode_ref(ql, qr, lat, bt, ln, dl)))
    # prefill: a 64-token chunk over 3 resident pages
    c, ppages = 64, 3
    np_ = 2 * ppages + 2
    pq, pkc, pvc = arr((2, c, 8, hd)), arr((2, c, 2, hd)), arr((2, c, 2, hd))
    pkp, pvp = arr((np_, page, 2, hd)), arr((np_, page, 2, hd))
    pbt = table(2, ppages, np_)
    off = jnp.asarray([page * 2 + 11, 0], jnp.int32)
    cases.append(("prefill_gqa",
                  lambda be: ops.paged_prefill(pq, pkc, pvc, pkp, pvp,
                                               pbt, off, backend=be),
                  lambda: ops.paged_prefill_ref(pq, pkc, pvc, pkp, pvp,
                                                pbt, off)))
    mql, mqr = arr((2, c, 8, dl)), arr((2, c, 8, dr))
    mlc, mlp = arr((2, c, dl + dr)), arr((np_, page, dl + dr))
    mbt = table(2, ppages, np_)
    cases.append(("prefill_mla",
                  lambda be: ops.mla_prefill(mql, mqr, mlc, mlp, mbt, off,
                                             d_latent=dl, backend=be),
                  lambda: ops.mla_prefill_ref(mql, mqr, mlc, mlp, mbt,
                                              off, dl)))

    for name, run, oracle in cases:
        err = float(jnp.max(jnp.abs(run("xla") - oracle())))
        _row(f"kernels.{name}.xla_vs_oracle_max_err", f"{err:.2e}", "<1e-4")
        lat_us = {}
        for be in backends:
            jax.block_until_ready(run(be))      # compile / first call
            iters = (3 if be == "interpret" else 20) * (1 if fast else 2)
            t0 = time.perf_counter()
            for _ in range(iters):
                out = run(be)
            jax.block_until_ready(out)
            lat_us[be] = (time.perf_counter() - t0) / iters * 1e6
            _row(f"kernels.{name}.{be}.us", round(lat_us[be], 1))
        if "xla" in lat_us and "interpret" in lat_us:
            _row(f"kernels.{name}.interpret_over_xla",
                 round(lat_us["interpret"] / lat_us["xla"], 1), ">1")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--table", default=None,
                    help="run one: 1,3,4,5,6,7,8,9,micro,kernels,serving,"
                         "ttft,replay,cluster,segments,chaos,steploop,slo")
    ap.add_argument("--paged", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="serving benchmark: paged block-table KV path "
                         "(--no-paged = dense slot A/B fallback)")
    ap.add_argument("--chunked", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="TTFT benchmark: chunked token-budget prefill "
                         "(--no-chunked = monolithic prefill A/B)")
    ap.add_argument("--backend", default=None,
                    choices=("pallas", "interpret", "xla"),
                    help="kernel backend for the engine-driving tables "
                         "(serving/ttft/replay/cluster) and the kernels "
                         "microbench; default resolves via "
                         "REPRO_KERNEL_BACKEND, else pallas on TPU / "
                         "xla elsewhere")
    args = ap.parse_args()
    t0 = time.time()
    sel = args.table
    hit_rates: Dict[str, float] = {}
    if sel in (None, "1"):
        table_i()
    if sel in (None, "3"):
        table_iii()
    if sel in (None, "5", "4", "7", "8"):
        hit_rates = table_v(fast=args.fast)
    if sel in (None, "6"):
        table_vi()
    if sel in (None, "4", "7", "8"):
        table_iv_vii_viii(hit_rates)
    if sel in (None, "9"):
        table_ix(fast=args.fast)
    if sel in (None, "micro"):
        micro_benchmarks()
    if sel in (None, "kernels"):
        kernel_benchmarks(backend=args.backend, fast=args.fast)
    if sel == "serving":
        # explicit A/B: both modes back to back
        serving_benchmark(paged=True, fast=args.fast, backend=args.backend)
        serving_benchmark(paged=False, fast=args.fast, backend=args.backend)
    elif sel is None:
        serving_benchmark(paged=args.paged, fast=args.fast,
                          backend=args.backend)
    if sel == "ttft":
        # explicit A/B: both prefill modes back to back
        ttft_benchmark(chunked=True, fast=args.fast, backend=args.backend)
        ttft_benchmark(chunked=False, fast=args.fast, backend=args.backend)
    elif sel is None:
        ttft_benchmark(chunked=args.chunked, fast=args.fast,
                       backend=args.backend)
    if sel == "replay":
        replay_benchmark(fast=args.fast, backend=args.backend)
    if sel == "cluster":
        cluster_benchmark(fast=args.fast, backend=args.backend)
    if sel == "segments":
        segments_benchmark(fast=args.fast, backend=args.backend)
    if sel == "chaos":
        chaos_benchmark(fast=args.fast, backend=args.backend)
    if sel == "steploop":
        steploop_benchmark(fast=args.fast, backend=args.backend)
    if sel == "slo":
        slo_benchmark(fast=args.fast, backend=args.backend)
    print(f"# done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
