"""Dispatch-vs-compute microbench for the serving step loop.

Modeled on jax's ``benchmarks/api_benchmark.py`` idiom: each step-loop
stage is timed twice — **dispatch-only** (issue the call, don't wait;
the host-side Python + dispatch cost the step loop pays even when the
device is busy) and **blocked** (``jax.block_until_ready``; the full
per-call latency including the kernel).  The gap between a full
``engine.step()`` and the blocked decode-closure latency is the
step-loop *host overhead*: scheduler planning, block-table bookkeeping,
sampling dispatches, prefetch planning, manager ticks.

The ROADMAP target this harness gates: host overhead < kernel time at
batch 16 on CPU-xla, fused step loop (``--table steploop`` in
``benchmarks/run.py``).

Stages:
    step          full ``ServingEngine.step()`` in steady-state decode
    decode        the jitted decode(+sample) closure, chained through
                  its donated KV state (dispatch vs blocked)
    state_build   a full ``PagedKVCache.decode_state`` rebuild (table
                  mask + host->device upload; the fused loop amortizes
                  this away via the cached device state)
    sample        the sampling stage: per-request ``sample`` dispatches
                  + per-token device syncs (unfused) vs one batched
                  ``sample_batched`` call + one sync (fused)
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class StepLoopResult:
    batch: int
    fused: bool
    backend: str
    steps: int                # measured engine steps
    step_ms: float            # mean full engine.step() wall
    kernel_ms: float          # blocked decode-closure latency per call
    dispatch_ms: float        # decode-closure dispatch-only per call
    state_build_ms: float     # full decode_state rebuild
    sample_ms: float          # sampling stage (style matches `fused`)
    state_reuses: int
    state_rebuilds: int
    recompiles: dict

    @property
    def host_ms(self) -> float:
        """Step wall minus the blocked decode closure: everything the
        host does around the kernel."""
        return max(0.0, self.step_ms - self.kernel_ms)

    @property
    def ratio(self) -> float:
        """host_ms / kernel_ms — the acceptance gate wants < 1.0."""
        return self.host_ms / self.kernel_ms if self.kernel_ms > 0 else 0.0


def build_steady_engine(batch: int, fused: bool, backend: str = None,
                        prompt_len: int = 64, max_len: int = 256):
    """An engine with ``batch`` requests all in steady-state decode
    (prefill complete, nobody near finishing)."""
    from repro.config import reduce_config
    from repro.configs import get_config
    from repro.core import sizing
    from repro.serving import EngineConfig, SamplingParams, ServingEngine
    from repro.serving.request import Phase

    cfg = reduce_config(get_config("llama3.2-1b"))
    # budget sized to exactly `batch` decode slots so the closure's
    # batch dimension IS the benchmarked batch
    budget = batch * sizing.seq_bytes(cfg, max_len) + 1.0
    eng = ServingEngine(cfg, EngineConfig(
        max_len=max_len, kv_budget_bytes=budget, fused_step=fused,
        kernel_backend=backend, page_tokens=32, prefill_chunk_tokens=64,
        max_step_tokens=max(batch + 64, 128)))
    if eng.scheduler.n_slots < batch:
        raise RuntimeError(f"sized {eng.scheduler.n_slots} slots < {batch}")
    rng = np.random.default_rng(0)
    max_new = max_len - prompt_len - 1   # never finishes mid-bench
    reqs = []
    for _ in range(batch):
        prompt = [int(t) for t in rng.integers(2, 200, size=prompt_len)]
        reqs.append(eng.submit(
            prompt, params=SamplingParams(max_new_tokens=max_new)))
    # drive prefill to completion: all requests decoding
    for _ in range(10_000):
        eng.step()
        if all(r.phase is Phase.DECODE for r in reqs):
            break
    else:
        raise RuntimeError("requests never reached steady-state decode")
    return eng, reqs


def _time_loop(fn, iters: int) -> float:
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) * 1e3 / iters


def _bench_decode_closure(eng, decode_reqs, iters: int):
    """Chain the decode closure through its donated state: issue all
    calls back to back (dispatch-only time), then block on the last
    output (per-call latency ~ kernel time).  The final state is
    absorbed back so the engine stays usable."""
    slots = [r.slot for r in decode_reqs]
    sa = eng.scheduler.step_arrays(decode_reqs, eng.kv.n_slots)
    tokens = jnp.asarray(sa["tokens"])
    if eng.fused:
        active = jnp.asarray(sa["active"])
        temps = jnp.asarray(sa["temperature"])
        tks = jnp.asarray(sa["top_k"])
        tps = jnp.asarray(sa["top_p"])
        key = jax.random.PRNGKey(0)
        state = eng.kv.decode_state(slots, reuse=True)
        # warmup call outside the timed window (donation: chain state)
        toks, state = eng._fused_decode(eng.params, state, tokens, active,
                                        key, temps, tks, tps)
        jax.block_until_ready(toks)
        t0 = time.perf_counter()
        for _ in range(iters):
            toks, state = eng._fused_decode(eng.params, state, tokens,
                                            active, key, temps, tks, tps)
        t_dispatch = time.perf_counter() - t0
        jax.block_until_ready(toks)
        t_blocked = time.perf_counter() - t0
        eng.kv.absorb(state, decode_slots=slots)
    else:
        state = eng.kv.decode_state(slots)
        logits, state = eng._decode(eng.params, state, tokens)
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for _ in range(iters):
            logits, state = eng._decode(eng.params, state, tokens)
        t_dispatch = time.perf_counter() - t0
        jax.block_until_ready(logits)
        t_blocked = time.perf_counter() - t0
        eng.kv.absorb(state)
    # NOTE: the closure writes the same token position `iters` times —
    # harmless (same pages, lengths re-absorbed below via set_length)
    for r in decode_reqs:
        eng.kv.set_length(r.slot, eng.kv.slots[r.slot].length)
    return (t_dispatch * 1e3 / iters, t_blocked * 1e3 / iters)


def _bench_sampling(eng, decode_reqs, iters: int) -> float:
    """The sampling stage in the style the engine mode actually uses."""
    from repro.serving import sampler as sampler_mod
    n_slots = eng.kv.n_slots
    vocab = eng.cfg.vocab_size
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.standard_normal((n_slots, vocab)),
                         jnp.float32)
    key = jax.random.PRNGKey(0)
    if eng.fused:
        sa = eng.scheduler.step_arrays(decode_reqs, n_slots)
        temps = jnp.asarray(sa["temperature"])
        tks = jnp.asarray(sa["top_k"])
        tps = jnp.asarray(sa["top_p"])
        batched = jax.jit(sampler_mod.sample_batched)

        def run():
            toks = batched(logits, key, temps, tks, tps)
            np.asarray(toks)               # the step's single sync

        run()
        return _time_loop(run, iters)

    def run():
        for r in decode_reqs:
            tok = sampler_mod.sample(
                logits[r.slot:r.slot + 1], key,
                temperature=r.params.temperature,
                top_k=r.params.top_k, top_p=r.params.top_p)
            int(tok[0])                    # per-request sync

    run()
    return _time_loop(run, iters)


def bench_steploop(batch: int = 16, fused: bool = True,
                   backend: str = None, steps: int = 30,
                   warmup: int = 5) -> StepLoopResult:
    """Steady-state step-loop timing for one engine mode."""
    eng, reqs = build_steady_engine(batch, fused, backend)
    decode_reqs = sorted((r for r in reqs), key=lambda r: r.slot)
    try:
        for _ in range(warmup):
            eng.step()
        step_ms = _time_loop(eng.step, steps)
        dispatch_ms, kernel_ms = _bench_decode_closure(
            eng, decode_reqs, max(4, steps // 2))
        state_build_ms = _time_loop(
            lambda: eng.kv.decode_state([r.slot for r in decode_reqs]),
            max(4, steps // 2))
        sample_ms = _bench_sampling(eng, decode_reqs, max(4, steps // 2))
        return StepLoopResult(
            batch=batch, fused=fused, backend=eng.kernel_backend,
            steps=steps, step_ms=step_ms, kernel_ms=kernel_ms,
            dispatch_ms=dispatch_ms, state_build_ms=state_build_ms,
            sample_ms=sample_ms, state_reuses=eng.kv.state_reuses,
            state_rebuilds=eng.kv.state_rebuilds,
            recompiles=eng.recompiles())
    finally:
        eng.shutdown()


def run_steploop_table(batches=(4, 16), backend: str = None,
                       steps: int = 30, emit=print):
    """The ``--table steploop`` body: fused vs unfused rows per batch;
    returns the fused batch-max result for the acceptance gate."""
    gate = None
    for batch in batches:
        for fused in (True, False):
            r = bench_steploop(batch=batch, fused=fused, backend=backend,
                               steps=steps)
            tag = f"steploop.b{batch}.{'fused' if fused else 'unfused'}"
            emit(f"{tag}.step_ms,{r.step_ms:.3f},")
            emit(f"{tag}.kernel_ms,{r.kernel_ms:.3f},")
            emit(f"{tag}.dispatch_ms,{r.dispatch_ms:.3f},")
            emit(f"{tag}.state_build_ms,{r.state_build_ms:.3f},")
            emit(f"{tag}.sample_ms,{r.sample_ms:.3f},")
            emit(f"{tag}.host_ms,{r.host_ms:.3f},")
            emit(f"{tag}.host_kernel_ratio,{r.ratio:.3f},<1.0")
            if fused:
                emit(f"{tag}.state_reuse_frac,"
                     f"{r.state_reuses / max(1, r.state_reuses + r.state_rebuilds):.3f},")
            if fused and batch == max(batches):
                gate = r
    if gate is not None:
        verdict = "PASS" if gate.ratio < 1.0 else "FAIL"
        emit(f"steploop.b{gate.batch}.gate_host_lt_kernel,{verdict},PASS")
    return gate
