"""Deterministic, seekable synthetic data pipeline.

Batches are a pure function of (seed, step): resuming training at step k
after a crash reproduces the exact token stream with zero iterator state
to checkpoint — the step number in the train checkpoint IS the data
cursor.  The stream mixes a Zipfian unigram background with repeated
"phrase" n-grams so small models have learnable structure (loss drops
measurably within a few hundred steps in examples/train_demo.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_phrases: int = 64
    phrase_len: int = 8


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # zipfian unigram distribution
        ranks = np.arange(1, v + 1)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._phrases = root.integers(
            0, v, size=(cfg.n_phrases, cfg.phrase_len))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab_size, size=(b, s + 1), p=self._probs)
        # splice in phrases: deterministic local structure
        n_splice = max(1, s // (4 * cfg.phrase_len))
        for i in range(b):
            idx = rng.integers(0, cfg.n_phrases, size=n_splice)
            pos = rng.integers(0, s + 1 - cfg.phrase_len, size=n_splice)
            for j, p in zip(idx, pos):
                toks[i, p:p + cfg.phrase_len] = self._phrases[j]
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1
