"""AdamW with fp32 master weights (pure JAX, no optax dependency).

Optimizer state holds fp32 master params + first/second moments; model
params stay bf16 for compute.  Under the distributed train step the
moments and master copy are additionally sharded over the data axis
(ZeRO-1) via ``distributed.sharding.zero1_shardings``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    master: Any
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to 10%."""
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init_state(params: Any) -> AdamWState:
    f32 = lambda p: p.astype(jnp.float32)
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      master=jax.tree.map(f32, params),
                      m=jax.tree.map(z, params),
                      v=jax.tree.map(z, params))


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any,
                  state: AdamWState) -> Tuple[Any, AdamWState, Dict]:
    """grads fp32, params bf16 -> (new params bf16, new state, metrics)."""
    step = state.step + 1
    lr = lr_schedule(cfg, state.step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, mast):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        new_mast = mast - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                + cfg.weight_decay * mast)
        return m, v, new_mast

    out = jax.tree.map(upd, grads, state.m, state.v, state.master)
    m = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda mast: mast.astype(jnp.bfloat16), master)
    new_state = AdamWState(step=step, master=master, m=m, v=v)
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
