"""Mesh-independent, content-addressed, delta-encoded checkpoints.

Fault-tolerance design (DESIGN.md §Fault-tolerance):

  * arrays are saved *logically* (fully gathered per leaf) with a JSON
    manifest — a checkpoint written on a 16x16 mesh restores onto 2x16x16,
    a single host, or any elastic re-configuration;
  * every leaf is SHA-256 content-addressed into a shared blob store and
    the manifest references blobs by hash — step-over-step checkpoints
    only write leaves that changed (paper §III-F delta-encoding applied
    to training state: optimizer moments change every step, but e.g.
    frozen embeddings or the step-invariant config never re-serialize);
  * writes are atomic (tmp + rename) so a crash mid-checkpoint never
    corrupts the latest valid one.

On a real multi-host pod each host writes only its addressable shards and
the manifest is assembled on host 0; the content-addressing and manifest
format are unchanged (documented, not simulated here).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def _hash_array(arr: np.ndarray) -> str:
    h = hashlib.sha256()
    h.update(str(arr.dtype).encode())
    h.update(str(arr.shape).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.blob_dir = os.path.join(directory, "blobs")
        self.keep = keep
        os.makedirs(self.blob_dir, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None
             ) -> Dict[str, Any]:
        leaves = _leaf_paths(tree)
        manifest: Dict[str, Any] = {"step": step, "leaves": {},
                                    "extra": extra or {}}
        new_bytes = reused = 0
        for key, leaf in leaves:
            arr = np.asarray(jax.device_get(leaf))
            # bf16 has no numpy dtype: store as uint16 view + tag
            tag = None
            if arr.dtype == jax.numpy.bfloat16:
                tag = "bfloat16"
                arr = arr.view(np.uint16)
            h = _hash_array(arr)
            blob = os.path.join(self.blob_dir, h + ".npy")
            if not os.path.exists(blob):
                fd, tmp = tempfile.mkstemp(dir=self.blob_dir)
                os.close(fd)
                np.save(tmp, arr, allow_pickle=False)
                os.replace(tmp + ".npy" if os.path.exists(tmp + ".npy")
                           else tmp, blob)
                new_bytes += arr.nbytes
            else:
                reused += arr.nbytes
            manifest["leaves"][key] = {"hash": h, "dtype": str(arr.dtype),
                                       "tag": tag,
                                       "shape": list(arr.shape)}
        manifest["delta"] = {"new_bytes": new_bytes,
                             "reused_bytes": reused}
        path = os.path.join(self.dir, f"ckpt_{step:08d}.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, path)
        self._gc()
        return manifest

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = [int(f[5:13]) for f in os.listdir(self.dir)
                 if f.startswith("ckpt_") and f.endswith(".json")]
        return max(steps) if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None
                ) -> Tuple[Any, Dict]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError("no checkpoint found")
        with open(os.path.join(self.dir, f"ckpt_{step:08d}.json")) as f:
            manifest = json.load(f)
        leaves = _leaf_paths(tree_like)
        out = []
        for key, leaf in leaves:
            meta = manifest["leaves"][key]
            arr = np.load(os.path.join(self.blob_dir, meta["hash"] + ".npy"))
            if meta.get("tag") == "bfloat16":
                arr = arr.view(jax.numpy.bfloat16)
            target_dtype = getattr(leaf, "dtype", arr.dtype)
            out.append(jax.numpy.asarray(arr, dtype=target_dtype))
        treedef = jax.tree_util.tree_structure(tree_like)
        return jax.tree_util.tree_unflatten(treedef, out), manifest

    # ------------------------------------------------------------------
    def _gc(self) -> None:
        """Drop old manifests; keep blobs referenced by surviving ones."""
        files = sorted(f for f in os.listdir(self.dir)
                       if f.startswith("ckpt_") and f.endswith(".json"))
        for f in files[:-self.keep]:
            os.remove(os.path.join(self.dir, f))
        live = set()
        for f in os.listdir(self.dir):
            if f.startswith("ckpt_") and f.endswith(".json"):
                with open(os.path.join(self.dir, f)) as fh:
                    m = json.load(fh)
                live.update(v["hash"] for v in m["leaves"].values())
        for blob in os.listdir(self.blob_dir):
            if blob.endswith(".npy") and blob[:-4] not in live:
                os.remove(os.path.join(self.blob_dir, blob))
