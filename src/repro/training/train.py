"""Distributed train-step builder: microbatched gradient accumulation,
mixed precision, optional int8 gradient compression across the data axis.

The returned ``train_step(params, opt_state, batch)`` is a single pjit-able
function; in/out shardings come from the model's PSpec tree + the logical
rules (TP over "model", DP over "pod"/"data", ZeRO-1 opt state).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig
from repro.distributed import sharding as shlib
from repro.models.model import Model
from repro.training import optimizer as opt_mod
from repro.training.optimizer import AdamWConfig, AdamWState


def pick_n_microbatches(cfg: ModelConfig, shape: ShapeConfig,
                        n_data_shards: int, *, sp_degree: int = 1) -> int:
    """Bound the remat-saved activation footprint: with scan-over-layers +
    checkpoint, each layer saves its input [micro_bs, S, D] bf16, so the
    per-device saved-activation total is micro_bs * S * D * 2B * L.
    Target <= ~1.5 GB, leaving HBM for params, grads and score buffers."""
    per_shard = max(1, shape.global_batch // max(1, n_data_shards))
    budget = int(1.5e9)
    per_seq_bytes = shape.seq_len * cfg.d_model * 2 * max(1, cfg.n_layers) \
        // max(1, sp_degree)      # SP: saved residuals are seq-sharded
    max_micro_bs = max(1, budget // max(1, per_seq_bytes))
    n_micro = 1
    while per_shard // n_micro > max_micro_bs and n_micro < per_shard:
        n_micro *= 2
    while per_shard % n_micro != 0:
        n_micro //= 2
    return max(1, n_micro)


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback (beyond-paper distributed
# optimization; off by default, exercised in tests)
# ---------------------------------------------------------------------------
def compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def make_train_step(model: Model, *, adamw: AdamWConfig = AdamWConfig(),
                    n_micro: int = 1,
                    grad_compress: bool = False,
                    defer_grad_sync: bool = False,
                    bf16_grad_sync: bool = False) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  ``batch`` leading dim = per-call global batch.

    defer_grad_sync: differentiate the *scanned total loss* instead of
    value_and_grad per microbatch.  Per-micro grads then stay shard-local
    partial sums and GSPMD inserts ONE data-axis all-reduce at the
    cotangent output instead of one per microbatch (n_micro x less grad
    wire at the cost of one extra rematerialized forward).

    bf16_grad_sync: accumulate micro-grads at bf16 so the data-axis
    gradient all-reduces move half the bytes; the optimizer update still
    runs in f32 (standard large-scale practice; EXPERIMENTS §Perf)."""

    def loss_fn(params, mb):
        loss, metrics = model.train_loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: AdamWState, batch):
        if n_micro > 1 and defer_grad_sync:
            mbs = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)

            def total_loss(p):
                @jax.checkpoint
                def body(acc, mb):
                    loss, metrics = model.train_loss(p, mb)
                    return acc + loss, metrics

                s, metricses = jax.lax.scan(body, 0.0, mbs)
                return s / n_micro, metricses

            (loss, metricses), grads = jax.value_and_grad(
                total_loss, has_aux=True)(params)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            metrics = jax.tree.map(jnp.mean, metricses)
        elif n_micro > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)

            acc_dt = jnp.bfloat16 if bf16_grad_sync else jnp.float32

            def micro(acc, mb):
                (loss, metrics), grads = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(acc_dt), acc, grads)
                return acc, (loss, metrics)

            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt),
                                params)
            acc, (losses, metricses) = jax.lax.scan(micro, acc0, mbs)
            grads = jax.tree.map(
                lambda g: g.astype(jnp.float32) / n_micro, acc)
            metrics = jax.tree.map(jnp.mean, metricses)
        else:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        if grad_compress:
            def roundtrip(g):
                q, s = compress_int8(g)
                return decompress_int8(q, s)
            grads = jax.tree.map(roundtrip, grads)

        new_params, new_opt, om = opt_mod.apply_updates(
            adamw, params, grads, opt_state)
        metrics = {**metrics, **om}
        return new_params, new_opt, metrics

    return train_step


# ---------------------------------------------------------------------------
# sharding assembly for the pjit'd step
# ---------------------------------------------------------------------------
@dataclass
class TrainShardings:
    params: Any
    opt: Any
    batch: Any
    rules: Dict


def train_shardings(model: Model, mesh: Mesh,
                    batch_spec: Dict[str, jax.ShapeDtypeStruct],
                    *, zero1: bool = True,
                    rules: Optional[Dict] = None) -> TrainShardings:
    rules = rules or shlib.BASE_RULES
    p_sh = shlib.tree_shardings(model.specs, mesh, rules)
    if zero1:
        state_sh = shlib.zero1_shardings(model.specs, mesh, rules)
    else:
        state_sh = p_sh
    opt_sh = AdamWState(
        step=NamedSharding(mesh, P()),
        master=state_sh, m=state_sh, v=state_sh)
    frules = shlib._filter_axes(rules, mesh)
    b_axes = frules.get("batch")
    batch_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, P(*( (b_axes,) + (None,) * (len(s.shape) - 1) ))),
        batch_spec)
    return TrainShardings(p_sh, opt_sh, batch_sh, rules)


def abstract_opt_state(model: Model) -> AdamWState:
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    ap = model.abstract_params()
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      master=jax.tree.map(f32, ap),
                      m=jax.tree.map(f32, ap),
                      v=jax.tree.map(f32, ap))
