"""Unified configuration system for the serving/training framework.

A ``ModelConfig`` fully describes one architecture (attention variant, MoE,
SSM, hybrid, enc-dec, VLM).  A ``ShapeConfig`` describes one workload cell
(train / prefill / decode) with its sequence length and global batch.  The
cross product (arch x shape) defines the dry-run grid.

Everything downstream — the sizing engine (``core/sizing.py``), the model
builder (``models/model.py``), the sharding rules (``distributed/
sharding.py``) and the launcher — consumes these dataclasses.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Attention variants (paper §II-B / eq. (3))
# ---------------------------------------------------------------------------
MHA = "mha"
GQA = "gqa"
MQA = "mqa"
MLA = "mla"

# Model families (drives the block layout inside models/)
FAMILY_DECODER = "decoder"   # dense decoder-only transformer
FAMILY_MOE = "moe"           # decoder-only with MoE FFN
FAMILY_HYBRID = "hybrid"     # Mamba2 blocks + shared attention (Zamba2)
FAMILY_RWKV = "rwkv"         # RWKV6 "Finch" — attention-free
FAMILY_ENCDEC = "encdec"     # Whisper-style encoder-decoder
FAMILY_VLM = "vlm"           # text decoder + cross-attention image layers


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    qkv_bias: bool = False            # Qwen-2.5 uses bias on QKV
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # --- MLA (DeepSeek-style latent attention) --------------------------
    d_latent: int = 0
    d_rope: int = 0
    # --- MoE -------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0              # per-expert hidden dim (granite: 512)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM / Mamba2 (zamba2) -------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    attn_every: int = 0               # hybrid: shared attn block every k SSM layers
    # --- enc-dec (whisper) -------------------------------------------------
    n_enc_layers: int = 0
    enc_len: int = 0                  # precomputed frame embeddings (frontend stub)
    # --- VLM (llama3.2-vision) ---------------------------------------------
    cross_attn_every: int = 0         # cross-attn block before every k-th layer
    n_patches: int = 0                # precomputed patch embeddings (frontend stub)
    # --- internal layout (perf only; never changes model semantics) --------
    internal_pad_q_heads: int = 0     # pad q heads per GQA group so the
                                      # head dim divides TP; padded heads
                                      # are hard-masked to zero output
    internal_pad_experts: int = 0     # pad expert count to divide TP for
                                      # expert parallelism; padded experts
                                      # get -inf router logits
    # --- cache-block granularity override ---------------------------------
    kv_block_tokens: int = 0          # 0 -> variant default (sizing.block_tokens);
                                      # reduced replay configs shrink it so the
                                      # live engine sees trace-scale blocks

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def attention_variant(self) -> str:
        """Paper §III-A: infer variant from the model configuration.

        "if a latent dimension is specified, MLA is selected; otherwise the
        ratio h_q/h_kv distinguishes MHA, MQA and GQA."
        """
        if self.d_latent > 0:
            return MLA
        if self.family == FAMILY_RWKV:
            return "none"            # attention-free
        if self.n_kv_heads == self.n_heads:
            return MHA
        if self.n_kv_heads == 1:
            return MQA
        return GQA

    @property
    def q_group(self) -> int:
        return max(1, self.n_heads // max(1, self.n_kv_heads))

    @property
    def layout_q_heads(self) -> int:
        """Q-head count in the parameter layout (>= n_heads)."""
        return self.internal_pad_q_heads or self.n_heads

    @property
    def layout_q_group(self) -> int:
        return max(1, self.layout_q_heads // max(1, self.n_kv_heads))

    @property
    def layout_n_experts(self) -> int:
        return self.internal_pad_experts or self.n_experts

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def moe_ff(self) -> int:
        return self.expert_d_ff or self.d_ff

    def attn_layer_ids(self) -> Tuple[int, ...]:
        """For hybrid models: indices of SSM layers after which the shared
        attention block runs.  Zamba2 interleaves a shared attention block
        every ``attn_every`` Mamba2 layers."""
        if self.family != FAMILY_HYBRID or self.attn_every <= 0:
            return ()
        return tuple(range(self.attn_every - 1, self.n_layers, self.attn_every))

    def cross_attn_layer_ids(self) -> Tuple[int, ...]:
        if self.family != FAMILY_VLM or self.cross_attn_every <= 0:
            return ()
        return tuple(range(self.cross_attn_every - 1, self.n_layers,
                           self.cross_attn_every))

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.hd
        n_q, n_kv = self.n_heads, self.n_kv_heads
        emb = self.vocab_size * d
        out_head = 0 if self.tie_embeddings else self.vocab_size * d

        def attn_params() -> int:
            if self.attention_variant == MLA:
                # q/kv down+up projections + rope parts + output
                q = d * (self.d_latent + n_q * (hd + self.d_rope))
                kv = d * (self.d_latent + self.d_rope) + \
                    self.d_latent * n_q * 2 * hd
                o = n_q * hd * d
                return q + kv + o
            return d * (n_q * hd) + 2 * d * (n_kv * hd) + n_q * hd * d

        def ffn_params() -> int:
            if self.n_experts > 0:
                return (self.n_experts * 3 * d * self.moe_ff) + d * self.n_experts
            return 3 * d * self.d_ff

        def ssm_params() -> int:
            di = self.d_inner
            n_h = self.n_ssm_heads
            g_n = 2 * self.ssm_state
            in_p = d * (2 * di + g_n + n_h)
            conv = (di + g_n) * self.ssm_conv
            out_p = di * d
            return in_p + conv + out_p + 3 * n_h

        def rwkv_params() -> int:
            # time-mix (r,k,v,w,g + output) + channel-mix
            tm = 5 * d * d + d * d + 2 * (d * 32 + 32 * d)  # lora-ish extras
            cm = d * self.d_ff + self.d_ff * d
            return tm + cm

        per_layer = 0
        total = emb + out_head
        if self.family in (FAMILY_DECODER, FAMILY_MOE, FAMILY_VLM):
            per_layer = attn_params() + ffn_params() + 2 * d
            total += self.n_layers * per_layer
            if self.family == FAMILY_VLM:
                total += len(self.cross_attn_layer_ids()) * (attn_params() + 2 * d)
        elif self.family == FAMILY_HYBRID:
            total += self.n_layers * (ssm_params() + 2 * d)
            total += attn_params() + 3 * d * self.d_ff + 4 * d  # one shared block
        elif self.family == FAMILY_RWKV:
            total += self.n_layers * (rwkv_params() + 4 * d)
        elif self.family == FAMILY_ENCDEC:
            enc = self.n_enc_layers * (attn_params() + 3 * d * self.d_ff + 4 * d)
            dec = self.n_layers * (2 * attn_params() + 3 * d * self.d_ff + 6 * d)
            total += enc + dec
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.n_experts == 0:
            return self.param_count()
        dense_expert = 3 * self.d_model * self.moe_ff
        inactive = self.n_layers * (self.n_experts - self.top_k) * dense_expert
        return self.param_count() - inactive


# ---------------------------------------------------------------------------
# Workload shapes
# ---------------------------------------------------------------------------
KIND_TRAIN = "train"
KIND_PREFILL = "prefill"
KIND_DECODE = "decode"


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str
    seq_len: int
    global_batch: int
    # decode shapes: seq_len is the KV-cache length; one new token is decoded

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", KIND_TRAIN, 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", KIND_PREFILL, 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", KIND_DECODE, 32_768, 128),
    "long_500k": ShapeConfig("long_500k", KIND_DECODE, 524_288, 1),
}

# Families with sub-quadratic sequence mixing: the only ones that run the
# 500k-token cell (full-attention archs skip it; see DESIGN.md).
SUBQUADRATIC_FAMILIES = (FAMILY_HYBRID, FAMILY_RWKV)


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell is well-defined (DESIGN.md §Skips)."""
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, ("pure full-attention architecture: 500k-token decode "
                       "requires sub-quadratic sequence mixing")
    return True, ""


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------
def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant: runs a real fwd/train step on CPU."""
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )
    if cfg.family == FAMILY_RWKV:
        kw["n_kv_heads"] = 0
        kw["head_dim"] = 16
    if cfg.d_latent:
        kw.update(d_latent=32, d_rope=8)
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=2, expert_d_ff=32)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, attn_every=max(cfg.attn_every, 0) and 2)
        kw["n_layers"] = 4
    if cfg.n_enc_layers:
        kw.update(n_enc_layers=2, enc_len=16)
    if cfg.cross_attn_every:
        kw.update(cross_attn_every=2, n_patches=8)
        kw["n_layers"] = 4
    return replace(cfg, **kw)


def reduce_shape(shape: ShapeConfig) -> ShapeConfig:
    return ShapeConfig(shape.name + "-smoke", shape.kind,
                       seq_len=min(shape.seq_len, 64),
                       global_batch=min(shape.global_batch, 2))


def padded_head_layout(cfg: ModelConfig, tp: int,
                       max_overhead: float = 1.35) -> int:
    """Smallest per-GQA-group-padded q-head count divisible by `tp`
    (0 if none exists within the flop-overhead budget).  Padding q heads
    group-wise preserves the q->kv mapping under repeat-expansion while
    letting attention weights/activations shard evenly over TP."""
    hq, hkv = cfg.n_heads, max(1, cfg.n_kv_heads)
    if hq % tp == 0 or cfg.attention_variant in ("mla", "none"):
        return 0
    g = hq // hkv
    g_pad = g
    while (hkv * g_pad) % tp != 0:
        g_pad += 1
        if hkv * g_pad > hq * max_overhead:
            return 0
    return hkv * g_pad
