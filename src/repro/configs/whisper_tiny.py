"""whisper-tiny [audio] — enc-dec; conv frontend is a STUB (input_specs()
provides precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from repro.config import ModelConfig, FAMILY_ENCDEC

CONFIG = ModelConfig(
    name="whisper-tiny", family=FAMILY_ENCDEC,
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536, vocab_size=51865, rope_theta=0.0,   # whisper: learned/sinusoidal pos
    n_enc_layers=4, enc_len=1500, tie_embeddings=True,
)
