"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]"""
from repro.config import ModelConfig, FAMILY_HYBRID

CONFIG = ModelConfig(
    name="zamba2-1.2b", family=FAMILY_HYBRID,
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000, rope_theta=10_000.0,
    ssm_state=64, ssm_expand=2, ssm_conv=4, ssm_head_dim=64, attn_every=6,
)
