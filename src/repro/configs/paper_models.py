"""The four model configurations the paper evaluates (Tables I, III, VI).

These are sizing-engine inputs only — never compiled at full scale here.
Layer counts / head geometry from the public model cards; they reproduce the
paper's byte counts exactly (tests/test_sizing.py).
"""
from repro.config import ModelConfig, FAMILY_DECODER, FAMILY_MOE

DEEPSEEK_V3 = ModelConfig(
    name="deepseek-v3", family=FAMILY_DECODER,
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=18432, vocab_size=129280,
    d_latent=512, d_rope=64,          # MLA
)

LLAMA3_70B = ModelConfig(
    name="llama-3-70b", family=FAMILY_DECODER,
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
)

MIXTRAL_8X22B = ModelConfig(
    name="mixtral-8x22b", family=FAMILY_MOE,
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768, n_experts=8, top_k=2, expert_d_ff=16384,
)

QWEN2_5_72B = ModelConfig(
    name="qwen-2.5-72b", family=FAMILY_DECODER,
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064, qkv_bias=True,
)

PAPER_MODELS = {m.name: m for m in
                [DEEPSEEK_V3, LLAMA3_70B, MIXTRAL_8X22B, QWEN2_5_72B]}
