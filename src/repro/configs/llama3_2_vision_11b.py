"""llama-3.2-vision-11b [vlm] — cross-attn image layers every 5th layer.
Vision frontend is a STUB: input_specs() provides precomputed patch
embeddings. [hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.config import ModelConfig, FAMILY_VLM

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family=FAMILY_VLM,
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256, rope_theta=500_000.0,
    cross_attn_every=5, n_patches=1601,
)
