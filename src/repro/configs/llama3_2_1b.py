"""llama3.2-1b [dense] — small llama3. [hf:meta-llama/Llama-3.2-1B; unverified]"""
from repro.config import ModelConfig, FAMILY_DECODER

CONFIG = ModelConfig(
    name="llama3.2-1b", family=FAMILY_DECODER,
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=128256, rope_theta=500_000.0,
)
