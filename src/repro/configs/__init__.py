"""Assigned architecture registry.

Each module defines ``CONFIG`` (the exact published configuration) — the
registry maps ``--arch <id>`` to it.  ``paper_models`` holds the four model
configs the paper itself evaluates (used by the sizing benchmarks; never
compiled at full scale).
"""
from repro.config import ModelConfig

from repro.configs.llama3_2_1b import CONFIG as llama3_2_1b
from repro.configs.phi3_medium_14b import CONFIG as phi3_medium_14b
from repro.configs.qwen2_5_14b import CONFIG as qwen2_5_14b
from repro.configs.glm4_9b import CONFIG as glm4_9b
from repro.configs.granite_moe_3b_a800m import CONFIG as granite_moe_3b_a800m
from repro.configs.granite_moe_1b_a400m import CONFIG as granite_moe_1b_a400m
from repro.configs.llama3_2_vision_11b import CONFIG as llama3_2_vision_11b
from repro.configs.whisper_tiny import CONFIG as whisper_tiny
from repro.configs.zamba2_1_2b import CONFIG as zamba2_1_2b
from repro.configs.rwkv6_1_6b import CONFIG as rwkv6_1_6b

REGISTRY: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        llama3_2_1b, phi3_medium_14b, qwen2_5_14b, glm4_9b,
        granite_moe_3b_a800m, granite_moe_1b_a400m, llama3_2_vision_11b,
        whisper_tiny, zamba2_1_2b, rwkv6_1_6b,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]
