"""phi3-medium-14b [dense] — RoPE SwiGLU GQA. [arXiv:2404.14219; unverified]"""
from repro.config import ModelConfig, FAMILY_DECODER

CONFIG = ModelConfig(
    name="phi3-medium-14b", family=FAMILY_DECODER,
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, head_dim=128,
    d_ff=17920, vocab_size=100352, rope_theta=10_000.0,
)
