"""rwkv6-1.6b [ssm] — Finch, data-dependent decay; attention-free.
[arXiv:2404.05892; unverified]"""
from repro.config import ModelConfig, FAMILY_RWKV

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family=FAMILY_RWKV,
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=0, head_dim=64,
    d_ff=7168, vocab_size=65536, rope_theta=0.0, tie_embeddings=False,
)
