"""Public kernel ops with unified backend dispatch.

Every paged attention op resolves to one of three backends
(``kernels/backend.py``):

* ``pallas``    — compiled Pallas TPU kernels (the TPU default);
* ``interpret`` — the same kernel bodies on the Pallas interpreter
                  (debug/validation — Python-driven grid, slow);
* ``xla``       — jitted pure-``jax.numpy`` fallbacks
                  (``kernels/xla_fallback.py``; the off-TPU default).

Selection order: ``backend=`` argument > legacy ``interpret=`` boolean
(True -> ``interpret``, False -> ``pallas``) > ``REPRO_KERNEL_BACKEND``
env var > platform default.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import ref, xla_fallback
from repro.kernels.backend import (BACKENDS, default_backend,  # noqa: F401
                                   on_tpu, resolve_backend)
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.mla_paged_decode import mla_paged_decode
from repro.kernels.paged_attention import paged_decode_attention
from repro.kernels.paged_prefill import (mla_paged_prefill,
                                         mla_paged_prefill_segments,
                                         paged_prefill_attention,
                                         paged_prefill_segments)


# -- jitted Pallas entry points (interpret resolved to a static bool) -------
@functools.partial(jax.jit, static_argnames=("interpret",))
def _paged_decode_pallas(q, k_pages, v_pages, block_tables, lengths,
                         interpret: bool):
    return paged_decode_attention(q, k_pages, v_pages, block_tables,
                                  lengths, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret", "block_q",
                                             "block_k"))
def _flash_causal_pallas(q, k, v, block_q: int, block_k: int,
                         interpret: bool):
    return flash_prefill(q, k, v, block_q=block_q, block_k=block_k,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("d_latent", "scale",
                                             "interpret"))
def _mla_decode_pallas(q_lat, q_rope, latent_pages, block_tables, lengths,
                       d_latent: int, scale: float | None, interpret: bool):
    return mla_paged_decode(q_lat, q_rope, latent_pages, block_tables,
                            lengths, d_latent=d_latent, scale=scale,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _paged_prefill_pallas(q, k_chunk, v_chunk, k_pages, v_pages,
                          block_tables, offsets, interpret: bool):
    return paged_prefill_attention(q, k_chunk, v_chunk, k_pages, v_pages,
                                   block_tables, offsets,
                                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("d_latent", "scale",
                                             "interpret"))
def _mla_prefill_pallas(q_lat, q_rope, lat_chunk, latent_pages,
                        block_tables, offsets, d_latent: int,
                        scale: float | None, interpret: bool):
    return mla_paged_prefill(q_lat, q_rope, lat_chunk, latent_pages,
                             block_tables, offsets, d_latent=d_latent,
                             scale=scale, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _paged_prefill_seg_pallas(q, k_chunk, v_chunk, k_pages, v_pages,
                              block_tables, chunk_positions,
                              interpret: bool):
    return paged_prefill_segments(q, k_chunk, v_chunk, k_pages, v_pages,
                                  block_tables, chunk_positions,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("d_latent", "scale",
                                             "interpret"))
def _mla_prefill_seg_pallas(q_lat, q_rope, lat_chunk, latent_pages,
                            block_tables, chunk_positions, d_latent: int,
                            scale: float | None, interpret: bool):
    return mla_paged_prefill_segments(q_lat, q_rope, lat_chunk,
                                      latent_pages, block_tables,
                                      chunk_positions, d_latent=d_latent,
                                      scale=scale, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _paged_decode_int8_pallas(q, k_pages, v_pages, k_scales, v_scales,
                              block_tables, lengths, interpret: bool):
    from repro.kernels.paged_attention import paged_decode_attention_int8
    return paged_decode_attention_int8(q, k_pages, v_pages, k_scales,
                                       v_scales, block_tables, lengths,
                                       interpret=interpret)


# -- dispatching public ops -------------------------------------------------
def paged_decode(q, k_pages, v_pages, block_tables, lengths,
                 backend: str | None = None, interpret: bool | None = None):
    """Paged decode attention (GQA/MHA/MQA): q [B,Hq,hd] over block-table
    -indirected KV pages -> [B,Hq,hd]."""
    be = resolve_backend(backend, interpret)
    if be == "xla":
        return xla_fallback.paged_decode_attention_xla(
            q, k_pages, v_pages, block_tables, lengths)
    return _paged_decode_pallas(q, k_pages, v_pages, block_tables, lengths,
                                interpret=(be == "interpret"))


def flash_causal(q, k, v, block_q: int = 128, block_k: int = 128,
                 backend: str | None = None, interpret: bool | None = None):
    """Causal prefill attention. q [B,S,Hq,hd], k/v [B,S,Hkv,hd]."""
    be = resolve_backend(backend, interpret)
    if be == "xla":
        return xla_fallback.flash_causal_xla(q, k, v)
    return _flash_causal_pallas(q, k, v, block_q=block_q, block_k=block_k,
                                interpret=(be == "interpret"))


def mla_decode(q_lat, q_rope, latent_pages, block_tables, lengths,
               d_latent: int, scale: float | None = None,
               backend: str | None = None, interpret: bool | None = None):
    """Absorbed-MLA paged decode over latent pages -> ctx [B,Hq,dl]."""
    be = resolve_backend(backend, interpret)
    if be == "xla":
        return xla_fallback.mla_paged_decode_xla(
            q_lat, q_rope, latent_pages, block_tables, lengths,
            d_latent=d_latent, scale=scale)
    return _mla_decode_pallas(q_lat, q_rope, latent_pages, block_tables,
                              lengths, d_latent=d_latent, scale=scale,
                              interpret=(be == "interpret"))


def paged_prefill(q, k_chunk, v_chunk, k_pages, v_pages, block_tables,
                  offsets, backend: str | None = None,
                  interpret: bool | None = None):
    """Chunked prefill: full attention to pool tokens < offset (block
    table indirection) + causal attention within the chunk."""
    be = resolve_backend(backend, interpret)
    if be == "xla":
        return xla_fallback.paged_prefill_attention_xla(
            q, k_chunk, v_chunk, k_pages, v_pages, block_tables, offsets)
    return _paged_prefill_pallas(q, k_chunk, v_chunk, k_pages, v_pages,
                                 block_tables, offsets,
                                 interpret=(be == "interpret"))


def mla_prefill(q_lat, q_rope, lat_chunk, latent_pages, block_tables,
                offsets, d_latent: int, scale: float | None = None,
                backend: str | None = None, interpret: bool | None = None):
    """Absorbed-MLA chunked prefill over latent pages."""
    be = resolve_backend(backend, interpret)
    if be == "xla":
        return xla_fallback.mla_paged_prefill_xla(
            q_lat, q_rope, lat_chunk, latent_pages, block_tables, offsets,
            d_latent=d_latent, scale=scale)
    return _mla_prefill_pallas(q_lat, q_rope, lat_chunk, latent_pages,
                               block_tables, offsets, d_latent=d_latent,
                               scale=scale, interpret=(be == "interpret"))


def paged_prefill_seg(q, k_chunk, v_chunk, k_pages, v_pages, block_tables,
                      chunk_positions, backend: str | None = None,
                      interpret: bool | None = None):
    """Segment prefill: per-query absolute positions (``chunk_positions``
    [B,C] int32, ascending valid entries, negative = padding) so one
    chunk can span multiple prompt gaps with resumed pool-resident
    segments between them.  Queries attend every resident pool token
    below their own position — excluding the chunk's not-yet-scattered
    positions — plus chunk tokens causally."""
    be = resolve_backend(backend, interpret)
    if be == "xla":
        return xla_fallback.paged_prefill_segments_xla(
            q, k_chunk, v_chunk, k_pages, v_pages, block_tables,
            chunk_positions)
    return _paged_prefill_seg_pallas(q, k_chunk, v_chunk, k_pages, v_pages,
                                     block_tables, chunk_positions,
                                     interpret=(be == "interpret"))


def mla_prefill_seg(q_lat, q_rope, lat_chunk, latent_pages, block_tables,
                    chunk_positions, d_latent: int,
                    scale: float | None = None,
                    backend: str | None = None,
                    interpret: bool | None = None):
    """Absorbed-MLA segment prefill over latent pages (same position
    semantics as ``paged_prefill_seg``)."""
    be = resolve_backend(backend, interpret)
    if be == "xla":
        return xla_fallback.mla_paged_prefill_segments_xla(
            q_lat, q_rope, lat_chunk, latent_pages, block_tables,
            chunk_positions, d_latent=d_latent, scale=scale)
    return _mla_prefill_seg_pallas(q_lat, q_rope, lat_chunk, latent_pages,
                                   block_tables, chunk_positions,
                                   d_latent=d_latent, scale=scale,
                                   interpret=(be == "interpret"))


def paged_decode_int8(q, k_pages, v_pages, k_scales, v_scales,
                      block_tables, lengths, backend: str | None = None,
                      interpret: bool | None = None):
    """int8-paged decode (per-token-head scales, in-register dequant)."""
    be = resolve_backend(backend, interpret)
    if be == "xla":
        return xla_fallback.paged_decode_attention_int8_xla(
            q, k_pages, v_pages, k_scales, v_scales, block_tables, lengths)
    return _paged_decode_int8_pallas(q, k_pages, v_pages, k_scales,
                                     v_scales, block_tables, lengths,
                                     interpret=(be == "interpret"))


# re-export oracles for test convenience
paged_decode_ref = ref.paged_decode_attention_ref
flash_causal_ref = ref.flash_prefill_ref
mla_decode_ref = ref.mla_paged_decode_ref
paged_prefill_ref = ref.paged_prefill_attention_ref
mla_prefill_ref = ref.mla_paged_prefill_ref
paged_prefill_seg_ref = ref.paged_prefill_segments_ref
mla_prefill_seg_ref = ref.mla_paged_prefill_segments_ref
