"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the kernel bodies execute in
Python for validation); on TPU backends the compiled MXU path is used.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.mla_paged_decode import mla_paged_decode
from repro.kernels.paged_attention import paged_decode_attention
from repro.kernels.paged_prefill import (mla_paged_prefill,
                                         paged_prefill_attention)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode(q, k_pages, v_pages, block_tables, lengths,
                 interpret: bool | None = None):
    it = (not _on_tpu()) if interpret is None else interpret
    return paged_decode_attention(q, k_pages, v_pages, block_tables,
                                  lengths, interpret=it)


@functools.partial(jax.jit, static_argnames=("interpret", "block_q",
                                             "block_k"))
def flash_causal(q, k, v, block_q: int = 128, block_k: int = 128,
                 interpret: bool | None = None):
    it = (not _on_tpu()) if interpret is None else interpret
    return flash_prefill(q, k, v, block_q=block_q, block_k=block_k,
                         interpret=it)


@functools.partial(jax.jit, static_argnames=("d_latent", "scale",
                                             "interpret"))
def mla_decode(q_lat, q_rope, latent_pages, block_tables, lengths,
               d_latent: int, scale: float | None = None,
               interpret: bool | None = None):
    it = (not _on_tpu()) if interpret is None else interpret
    return mla_paged_decode(q_lat, q_rope, latent_pages, block_tables,
                            lengths, d_latent=d_latent, scale=scale,
                            interpret=it)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill(q, k_chunk, v_chunk, k_pages, v_pages, block_tables,
                  offsets, interpret: bool | None = None):
    """Chunked prefill: full attention to pool tokens < offset (block
    table indirection) + causal attention within the chunk."""
    it = (not _on_tpu()) if interpret is None else interpret
    return paged_prefill_attention(q, k_chunk, v_chunk, k_pages, v_pages,
                                   block_tables, offsets, interpret=it)


@functools.partial(jax.jit, static_argnames=("d_latent", "scale",
                                             "interpret"))
def mla_prefill(q_lat, q_rope, lat_chunk, latent_pages, block_tables,
                offsets, d_latent: int, scale: float | None = None,
                interpret: bool | None = None):
    """Absorbed-MLA chunked prefill over latent pages."""
    it = (not _on_tpu()) if interpret is None else interpret
    return mla_paged_prefill(q_lat, q_rope, lat_chunk, latent_pages,
                             block_tables, offsets, d_latent=d_latent,
                             scale=scale, interpret=it)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_int8(q, k_pages, v_pages, k_scales, v_scales,
                      block_tables, lengths, interpret: bool | None = None):
    from repro.kernels.paged_attention import paged_decode_attention_int8
    it = (not _on_tpu()) if interpret is None else interpret
    return paged_decode_attention_int8(q, k_pages, v_pages, k_scales,
                                       v_scales, block_tables, lengths,
                                       interpret=it)


# re-export oracles for test convenience
paged_decode_ref = ref.paged_decode_attention_ref
flash_causal_ref = ref.flash_prefill_ref
mla_decode_ref = ref.mla_paged_decode_ref
paged_prefill_ref = ref.paged_prefill_attention_ref
mla_prefill_ref = ref.mla_paged_prefill_ref
