"""Compiled XLA fallbacks for the paged attention ops.

Off-TPU the Pallas kernels only run in *interpret mode* — a Python-
driven grid that re-enters the interpreter per (batch, page) step and
dominated replay wall-clock on CPU (PR 1/PR 2 measurement artifacts).
These are production-shape, jitted pure-``jax.numpy`` implementations of
the same contracts: the block-table indirection becomes one batched
gather over the pool (``jnp.take`` — XLA fuses it into the attention
computation), masking replaces the grid's page gating, and the softmax
is dense over the gathered window.  No Python runs per page.

Numerics match the ``ref.py`` oracles (same contraction order, f32
accumulation, the shared ``NEG_INF`` mask value) — the oracles remain
the test ground truth; these are their promotion into the serving path,
selected by ``kernels/backend.py`` (the off-TPU default).

Trade-off vs the Pallas path: the gather materializes each request's
full ``[P_max * page]`` KV window, so peak memory scales with the
padded block-table width rather than the VMEM-resident single page of
the flash-accumulator kernels — the right trade everywhere except the
TPU, where the compiled Pallas kernels stay the default.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.ref import NEG_INF


@jax.jit
def paged_decode_attention_xla(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, block_tables: jax.Array,
                               lengths: jax.Array) -> jax.Array:
    """q [B,Hq,hd]; k/v_pages [N,page,Hkv,hd]; block_tables [B,P] int32;
    lengths [B] int32 -> [B,Hq,hd].  GQA/MHA/MQA via head grouping."""
    b, hq, hd = q.shape
    _, page, hkv, _ = k_pages.shape
    p_max = block_tables.shape[1]
    g = hq // hkv
    t = p_max * page

    # one batched gather per pool: [B, P, page, Hkv, hd] -> [B, T, Hkv, hd]
    k = jnp.take(k_pages, block_tables, axis=0, mode="clip").reshape(b, t, hkv, hd)
    v = jnp.take(v_pages, block_tables, axis=0, mode="clip").reshape(b, t, hkv, hd)
    qg = q.reshape(b, hkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    pos = jnp.arange(t)
    s = jnp.where(pos[None, None, None, :] < lengths[:, None, None, None],
                  s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgt,bthd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, hd).astype(q.dtype)


@jax.jit
def paged_decode_attention_int8_xla(q, k_pages, v_pages, k_scales,
                                    v_scales, block_tables, lengths):
    """int8 pages + per-token-head scales: gather, dequantize, attend.
    (The DMA-traffic halving of the int8 Pallas kernel does not apply —
    XLA dequantizes in registers after a full-width gather.)"""
    b = q.shape[0]
    page, hkv, hd = k_pages.shape[1:]
    t = block_tables.shape[1] * page
    ks = jnp.take(k_scales, block_tables, axis=0, mode="clip").reshape(b, t, hkv, 1)
    vs = jnp.take(v_scales, block_tables, axis=0, mode="clip").reshape(b, t, hkv, 1)
    k = jnp.take(k_pages, block_tables, axis=0, mode="clip").reshape(b, t, hkv, hd)
    v = jnp.take(v_pages, block_tables, axis=0, mode="clip").reshape(b, t, hkv, hd)
    k = k.astype(jnp.float32) * ks.astype(jnp.float32)
    v = v.astype(jnp.float32) * vs.astype(jnp.float32)

    hq = q.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k) / math.sqrt(hd)
    pos = jnp.arange(t)
    s = jnp.where(pos[None, None, None, :] < lengths[:, None, None, None],
                  s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgt,bthd->bhgd", p, v)
    return o.reshape(b, hq, hd).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("d_latent", "scale"))
def mla_paged_decode_xla(q_lat: jax.Array, q_rope: jax.Array,
                         latent_pages: jax.Array, block_tables: jax.Array,
                         lengths: jax.Array, *, d_latent: int,
                         scale: float | None = None) -> jax.Array:
    """Absorbed-MLA decode over latent pages: q_lat [B,Hq,dl];
    q_rope [B,Hq,dr]; latent_pages [N,page,dl+dr] -> ctx [B,Hq,dl]."""
    b, hq, dl = q_lat.shape
    dr = q_rope.shape[-1]
    _, page, dtot = latent_pages.shape
    t = block_tables.shape[1] * page
    if scale is None:
        scale = 1.0 / math.sqrt(dl // 4 + dr)  # ref-oracle convention

    lat = jnp.take(latent_pages, block_tables, axis=0, mode="clip").reshape(b, t, dtot)
    lat = lat.astype(jnp.float32)
    c, kr = lat[..., :d_latent], lat[..., d_latent:]
    s = (jnp.einsum("bhl,btl->bht", q_lat.astype(jnp.float32), c)
         + jnp.einsum("bhr,btr->bht", q_rope.astype(jnp.float32), kr)) * scale
    pos = jnp.arange(t)
    s = jnp.where(pos[None, None, :] < lengths[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bht,btl->bhl", p, c).astype(q_lat.dtype)


@jax.jit
def paged_prefill_attention_xla(q: jax.Array, k_chunk: jax.Array,
                                v_chunk: jax.Array, k_pages: jax.Array,
                                v_pages: jax.Array, block_tables: jax.Array,
                                offsets: jax.Array) -> jax.Array:
    """Chunked prefill: q [B,C,Hq,hd] at absolute positions offset+i
    attends pool tokens < offset (block-table gather) plus chunk tokens
    j <= i.  The chunk KV is dense — not yet scattered into the pool."""
    b, c, hq, hd = q.shape
    _, page, hkv, _ = k_pages.shape
    p_max = block_tables.shape[1]
    g = hq // hkv
    t_prior = p_max * page

    kp = jnp.take(k_pages, block_tables, axis=0, mode="clip").reshape(b, t_prior, hkv, hd)
    vp = jnp.take(v_pages, block_tables, axis=0, mode="clip").reshape(b, t_prior, hkv, hd)
    k = jnp.concatenate([kp, k_chunk], axis=1)       # [B, T, Hkv, hd]
    v = jnp.concatenate([vp, v_chunk], axis=1)
    qg = q.reshape(b, c, hkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bchgd,bthd->bchgt", qg, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    pos = jnp.arange(t_prior + c)
    # pool tokens < offset, plus causal within the chunk
    prior = pos[None, None, :] < offsets[:, None, None]        # [B, 1, T]
    causal = (pos[None, None, :] >= t_prior) & \
        (pos[None, None, :] - t_prior <= jnp.arange(c)[None, :, None])
    mask = prior | causal                                      # [B, C, T]
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bchgt,bthd->bchgd", p, v.astype(jnp.float32))
    return o.reshape(b, c, hq, hd).astype(q.dtype)


@jax.jit
def paged_prefill_segments_xla(q: jax.Array, k_chunk: jax.Array,
                               v_chunk: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, block_tables: jax.Array,
                               chunk_positions: jax.Array) -> jax.Array:
    """Segment prefill: query i of row b sits at absolute position
    ``chunk_positions[b, i]`` (ascending valid entries; negative =
    padding) and attends every resident pool token below its position —
    excluding the chunk's own not-yet-scattered positions — plus chunk
    tokens j <= i.  Generalizes ``paged_prefill_attention_xla`` to a
    chunk spanning multiple prompt gaps with resumed (pool-resident)
    segments between them."""
    b, c, hq, hd = q.shape
    _, page, hkv, _ = k_pages.shape
    p_max = block_tables.shape[1]
    g = hq // hkv
    t_prior = p_max * page

    kp = jnp.take(k_pages, block_tables, axis=0, mode="clip").reshape(b, t_prior, hkv, hd)
    vp = jnp.take(v_pages, block_tables, axis=0, mode="clip").reshape(b, t_prior, hkv, hd)
    k = jnp.concatenate([kp, k_chunk], axis=1)       # [B, T, Hkv, hd]
    v = jnp.concatenate([vp, v_chunk], axis=1)
    qg = q.reshape(b, c, hkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bchgd,bthd->bchgt", qg, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    pos = jnp.arange(t_prior + c)
    own = jnp.any(pos[None, None, :] == chunk_positions[:, :, None],
                  axis=1)                                      # [B, T]
    prior = (pos[None, None, :] < chunk_positions[:, :, None]) \
        & ~own[:, None, :]                                     # [B, C, T]
    causal = (pos[None, None, :] >= t_prior) & \
        (pos[None, None, :] - t_prior <= jnp.arange(c)[None, :, None])
    mask = prior | causal
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bchgt,bthd->bchgd", p, v.astype(jnp.float32))
    return o.reshape(b, c, hq, hd).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("d_latent", "scale"))
def mla_paged_prefill_segments_xla(q_lat: jax.Array, q_rope: jax.Array,
                                   lat_chunk: jax.Array,
                                   latent_pages: jax.Array,
                                   block_tables: jax.Array,
                                   chunk_positions: jax.Array, *,
                                   d_latent: int,
                                   scale: float | None = None) -> jax.Array:
    """Absorbed-MLA segment prefill (same position semantics as
    ``paged_prefill_segments_xla``) -> ctx [B,C,Hq,dl]."""
    b, c, hq, dl = q_lat.shape
    dr = q_rope.shape[-1]
    _, page, dtot = latent_pages.shape
    t_prior = block_tables.shape[1] * page
    if scale is None:
        scale = 1.0 / math.sqrt(dl // 4 + dr)  # ref-oracle convention

    lat_p = jnp.take(latent_pages, block_tables,
                     axis=0, mode="clip").reshape(b, t_prior, dtot)
    lat = jnp.concatenate([lat_p, lat_chunk], axis=1).astype(jnp.float32)
    c_kv, kr = lat[..., :d_latent], lat[..., d_latent:]
    s = (jnp.einsum("bchl,btl->bcht", q_lat.astype(jnp.float32), c_kv)
         + jnp.einsum("bchr,btr->bcht", q_rope.astype(jnp.float32),
                      kr)) * scale
    pos = jnp.arange(t_prior + c)
    own = jnp.any(pos[None, None, :] == chunk_positions[:, :, None], axis=1)
    prior = (pos[None, None, :] < chunk_positions[:, :, None]) \
        & ~own[:, None, :]
    causal = (pos[None, None, :] >= t_prior) & \
        (pos[None, None, :] - t_prior <= jnp.arange(c)[None, :, None])
    s = jnp.where((prior | causal)[:, :, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bcht,btl->bchl", p, c_kv).astype(q_lat.dtype)


@functools.partial(jax.jit, static_argnames=("d_latent", "scale"))
def mla_paged_prefill_xla(q_lat: jax.Array, q_rope: jax.Array,
                          lat_chunk: jax.Array, latent_pages: jax.Array,
                          block_tables: jax.Array, offsets: jax.Array, *,
                          d_latent: int,
                          scale: float | None = None) -> jax.Array:
    """Absorbed-MLA chunked prefill: q_lat [B,C,Hq,dl]; q_rope
    [B,C,Hq,dr]; lat_chunk [B,C,dl+dr]; latent_pages [N,page,dl+dr]
    -> ctx [B,C,Hq,dl]."""
    b, c, hq, dl = q_lat.shape
    dr = q_rope.shape[-1]
    _, page, dtot = latent_pages.shape
    t_prior = block_tables.shape[1] * page
    if scale is None:
        scale = 1.0 / math.sqrt(dl // 4 + dr)  # ref-oracle convention

    lat_p = jnp.take(latent_pages, block_tables,
                     axis=0, mode="clip").reshape(b, t_prior, dtot)
    lat = jnp.concatenate([lat_p, lat_chunk], axis=1).astype(jnp.float32)
    c_kv, kr = lat[..., :d_latent], lat[..., d_latent:]
    s = (jnp.einsum("bchl,btl->bcht", q_lat.astype(jnp.float32), c_kv)
         + jnp.einsum("bchr,btr->bcht", q_rope.astype(jnp.float32),
                      kr)) * scale
    pos = jnp.arange(t_prior + c)
    prior = pos[None, None, :] < offsets[:, None, None]
    causal = (pos[None, None, :] >= t_prior) & \
        (pos[None, None, :] - t_prior <= jnp.arange(c)[None, :, None])
    s = jnp.where((prior | causal)[:, :, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bcht,btl->bchl", p, c_kv).astype(q_lat.dtype)


@functools.partial(jax.jit, static_argnames=())
def flash_causal_xla(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Dense causal attention (the flash-prefill contract) as one fused
    XLA computation.  q [B,S,Hq,hd], k/v [B,S,Hkv,hd] -> [B,S,Hq,hd]."""
    b, s_len, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s_len, hkv, g, hd).astype(jnp.float32)
    sc = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    sc = sc / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((s_len, s_len), bool))
    sc = jnp.where(mask[None, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, s_len, hq, hd).astype(q.dtype)
