"""Kernel-backend resolution — the single authority for how the paged
attention ops execute.

Three backends per op:

* ``pallas``    — the compiled Pallas TPU kernels (MXU path, scalar-
                  prefetch block tables).  Only meaningful on TPU.
* ``interpret`` — the same Pallas kernel bodies run by the Python-driven
                  interpreter grid.  Numerically identical to ``pallas``
                  and available everywhere, but orders of magnitude
                  slower — a debugging/validation mode, not a serving
                  path.
* ``xla``       — jitted pure-``jax.numpy`` implementations
                  (``kernels/xla_fallback.py``): batched block-table
                  gathers plus dense masked softmax attention.  Compiled
                  on every JAX backend — the off-TPU serving default.

Resolution order: explicit argument > ``REPRO_KERNEL_BACKEND`` env var >
platform default (``pallas`` on TPU, ``xla`` elsewhere).  Every entry
point — the ``ops.py`` wrappers, the individual kernel modules' direct
call paths, ``EngineConfig(kernel_backend=...)`` — routes through this
module, so a direct kernel call on TPU can never silently run
interpreted.
"""
from __future__ import annotations

import functools
import os

import jax

BACKENDS = ("pallas", "interpret", "xla")
ENV_VAR = "REPRO_KERNEL_BACKEND"


@functools.lru_cache(maxsize=1)
def on_tpu() -> bool:
    """True when the default JAX backend is a TPU (cached per process —
    the platform cannot change under a live process)."""
    return jax.default_backend() == "tpu"


def _validated(name: str, source: str) -> str:
    name = name.strip().lower()
    if name not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {name!r} (from {source}); "
            f"expected one of {BACKENDS}")
    if name == "pallas" and not on_tpu():
        # fail at resolution (engine construction / CLI parse) with a
        # clear message instead of deep inside jit with a Mosaic
        # lowering error on the first decode step
        raise ValueError(
            f"kernel backend 'pallas' (from {source}) requires a TPU "
            f"(running on {jax.default_backend()!r}); use 'xla' or "
            f"'interpret' off-TPU")
    return name


def default_backend() -> str:
    """The process-wide default: ``REPRO_KERNEL_BACKEND`` if set, else
    ``pallas`` on TPU / ``xla`` everywhere else."""
    env = os.environ.get(ENV_VAR)
    if env:
        return _validated(env, f"${ENV_VAR}")
    return "pallas" if on_tpu() else "xla"


def resolve_backend(backend: str | None = None,
                    interpret: bool | None = None) -> str:
    """Resolve an op call's backend.

    ``backend`` wins when given; the legacy ``interpret`` boolean keeps
    the pre-dispatch call sites working (True -> ``interpret``, False ->
    ``pallas``); ``None``/``None`` falls through to
    :func:`default_backend`.
    """
    if backend is not None:
        return _validated(backend, "backend argument")
    if interpret is not None:
        return ("interpret" if interpret
                else _validated("pallas", "interpret=False argument"))
    return default_backend()


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Default for the raw Pallas kernel entry points
    (``paged_decode_attention`` et al., which have no ``xla`` path):
    interpret off-TPU, compiled on TPU, with an explicit
    ``REPRO_KERNEL_BACKEND=interpret``/``pallas`` honored on any
    platform (``xla`` has no meaning for a raw Pallas call and keeps
    the platform default).  Replaces the per-module ``interpret: bool =
    True`` hard defaults that could silently run a direct TPU call
    through the interpreter."""
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get(ENV_VAR)
    if env:
        name = _validated(env, f"${ENV_VAR}")
        if name == "interpret":
            return True
        if name == "pallas":      # only resolvable on TPU (_validated)
            return False
    return not on_tpu()
