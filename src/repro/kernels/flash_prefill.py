"""Causal flash attention (prefill/training) — Pallas TPU kernel.

The XLA fallback (models/attention.causal_attention) computes the full
rectangular S x S score matrix and masks half of it away — 2x the causal
ideal in both FLOPs and score traffic (measured in EXPERIMENTS.md §Perf).
This kernel skips fully-masked KV blocks via the grid structure, holds
the running softmax in VMEM (no HBM score materialization) and performs
the [block_q, hd] x [hd, block_k] contractions on the MXU with
128-aligned tiles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, block_q: int, block_k: int, scale: float):
    qi = pl.program_id(2)      # query block
    ki = pl.program_id(3)      # kv block (innermost, sequential)
    n_k = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal block skip: kv block strictly above the diagonal => no work
    @pl.when(ki * block_k <= (qi + 1) * block_q - 1)
    def _attend():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # [bq, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # [bk, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.einsum("qd,kd->qk", q, k) * scale          # [bq, bk]
        qpos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + p @ v
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0, :, 0, :] = (acc_ref[...] /
                             jnp.maximum(l_ref[...], 1e-30)
                             ).astype(o_ref.dtype)


def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  block_q: int = 128, block_k: int = 128,
                  interpret: bool | None = None) -> jax.Array:
    """Causal attention. q [B,S,Hq,hd], k/v [B,S,Hkv,hd] -> [B,S,Hq,hd].

    GQA handled by expanding each query head to its KV head via the head
    grid dimension (k/v blocks indexed at h // group).
    """
    interpret = resolve_interpret(interpret)
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    n_q = s // block_q
    n_k = s // block_k
    scale = 1.0 / math.sqrt(hd)

    kernel = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                          scale=scale),
        grid=(b, hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bi, hi, qi, ki: (bi, ki, hi // g, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bi, hi, qi, ki: (bi, ki, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((b, s, hq, hd), q.dtype),
        interpret=interpret,
    )
    return kernel(q, k, v)
