"""Chunked prefill over a paged KV pool — Pallas TPU kernels.

The unified token-budget step loop (Sarathi-style mixed batches) feeds
prompt *chunks* through these kernels: a fixed-size block of C query
tokens attends with full attention to the request's already-resident
KV pages (block-table indirection, exactly like the paged decode
kernels) and causally to the chunk's own freshly-projected KV, which
arrives as a dense operand and is only scattered into the pool *after*
the layer stack runs.

Grid: (batch, n_pages + 1).  Page iterations stream prior pages through
VMEM; iterations at or past the offset are compute-gated (``pl.when``)
AND their index map clamps to the last useful page, so the pipeline
elides the redundant DMA (consecutive identical block indices reuse the
staged copy) — a chunk early in the prompt pays for no empty pages.
The final grid step attends the chunk against itself with a causal
mask and writes the output.  Flash
softmax stats (m, l, acc) persist in VMEM scratch across the sequential
page iterations; the block table and per-request offsets are
scalar-prefetch operands, so page resolution happens on the scalar core
ahead of the DMA.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

NEG_INF = -1e30


def _prefill_kernel(
    # scalar-prefetch operands
    block_tables_ref,      # [B, P] int32
    offsets_ref,           # [B] int32  (tokens already resident in pages)
    # array operands (blocked)
    q_ref,                 # [1, C, Hq, hd]
    kc_ref,                # [1, C, Hkv, hd]  chunk KV (not yet in the pool)
    vc_ref,                # [1, C, Hkv, hd]
    kp_ref,                # [1, page, Hkv, hd]  pool page
    vp_ref,                # [1, page, Hkv, hd]
    # outputs
    o_ref,                 # [1, C, Hq, hd]
    # scratch
    m_ref,                 # [C, Hq] f32
    l_ref,                 # [C, Hq] f32
    acc_ref,               # [C, Hq, hd] f32
    *, page: int, n_prior: int, chunk: int,
):
    b = pl.program_id(0)
    p = pl.program_id(1)
    offset = offsets_ref[b]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _update(s, v, hkv, g):
        """Online-softmax update; s [C, Hq, T], v [T, Hkv, hd]."""
        m_prev = m_ref[...]                              # [C, Hq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        prob = jnp.exp(s - m_new[..., None])             # [C, Hq, T]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(prob, axis=-1)
        pg = prob.reshape(chunk, hkv, g, -1)
        pv = jnp.einsum("chgt,thd->chgd", pg, v)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + \
            pv.reshape(chunk, -1, v.shape[-1])
        m_ref[...] = m_new

    # full attention to prior pages (tokens < offset); pages at or past
    # the offset hold no prior KV and are skipped outright
    @pl.when((p < n_prior) & (p * page < offset))
    def _prior():
        q = q_ref[0].astype(jnp.float32)                 # [C, Hq, hd]
        k = kp_ref[0].astype(jnp.float32)                # [page, Hkv, hd]
        v = vp_ref[0].astype(jnp.float32)
        c, hq, hd = q.shape
        hkv = k.shape[1]
        g = hq // hkv
        scale = 1.0 / math.sqrt(hd)
        qg = q.reshape(c, hkv, g, hd)
        s = jnp.einsum("chgd,thd->chgt", qg, k).reshape(c, hq, page) * scale
        pos = p * page + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, page), 2)
        s = jnp.where(pos < offset, s, NEG_INF)
        _update(s, v, hkv, g)

    # causal attention within the chunk itself, then finalize (the chunk
    # step is the last grid iteration)
    @pl.when(p == n_prior)
    def _chunk():
        q = q_ref[0].astype(jnp.float32)
        k = kc_ref[0].astype(jnp.float32)                # [C, Hkv, hd]
        v = vc_ref[0].astype(jnp.float32)
        c, hq, hd = q.shape
        hkv = k.shape[1]
        g = hq // hkv
        scale = 1.0 / math.sqrt(hd)
        qg = q.reshape(c, hkv, g, hd)
        s = jnp.einsum("chgd,thd->chgt", qg, k).reshape(c, hq, c) * scale
        qpos = jax.lax.broadcasted_iota(jnp.int32, (c, 1, c), 0)
        kpos = jax.lax.broadcasted_iota(jnp.int32, (c, 1, c), 2)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
        _update(s, v, hkv, g)
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[..., None]
                    ).astype(o_ref.dtype)


def paged_prefill_attention(q: jax.Array, k_chunk: jax.Array,
                            v_chunk: jax.Array, k_pages: jax.Array,
                            v_pages: jax.Array, block_tables: jax.Array,
                            offsets: jax.Array, *,
                            interpret: bool | None = None) -> jax.Array:
    """q [B,C,Hq,hd]; k/v_chunk [B,C,Hkv,hd]; k/v_pages [N,page,Hkv,hd];
    block_tables [B,P] int32; offsets [B] int32 -> out [B,C,Hq,hd].

    Query i of request b sits at absolute position offsets[b] + i: it
    attends every pool token < offsets[b] through the block table, plus
    chunk tokens j <= i.  The chunk's KV must NOT yet be written to the
    pool (it is passed densely) — the caller scatters it afterwards via
    ``PagedKVCache.write_chunk``.
    """
    interpret = resolve_interpret(interpret)
    b, c, hq, hd = q.shape
    n, page, hkv, _ = k_pages.shape
    p_max = block_tables.shape[1]

    def _page_idx(bi, pi, bt, off):
        # iterations at/past the offset (and the final chunk step) read
        # no pool page: clamp to the last page holding prior tokens so
        # consecutive identical indices elide the DMA entirely
        last_useful = jnp.maximum((off[bi] + page - 1) // page - 1, 0)
        return (bt[bi, jnp.minimum(pi, jnp.minimum(last_useful,
                                                   p_max - 1))], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, p_max + 1),
        in_specs=[
            pl.BlockSpec((1, c, hq, hd), lambda bi, pi, bt, off: (bi, 0, 0, 0)),
            pl.BlockSpec((1, c, hkv, hd), lambda bi, pi, bt, off: (bi, 0, 0, 0)),
            pl.BlockSpec((1, c, hkv, hd), lambda bi, pi, bt, off: (bi, 0, 0, 0)),
            pl.BlockSpec((1, page, hkv, hd), _page_idx),
            pl.BlockSpec((1, page, hkv, hd), _page_idx),
        ],
        out_specs=pl.BlockSpec((1, c, hq, hd),
                               lambda bi, pi, bt, off: (bi, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((c, hq), jnp.float32),
            pltpu.VMEM((c, hq), jnp.float32),
            pltpu.VMEM((c, hq, hd), jnp.float32),
        ],
    )
    kernel = pl.pallas_call(
        functools.partial(_prefill_kernel, page=page, n_prior=p_max,
                          chunk=c),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c, hq, hd), q.dtype),
        interpret=interpret,
    )
    return kernel(block_tables, offsets, q, k_chunk, v_chunk,
                  k_pages, v_pages)


# ---------------------------------------------------------------------------
# Segment prefill: per-query absolute positions instead of one scalar
# offset.  A chunk may span multiple prompt *gaps* with resumed
# (pool-resident) content segments between them: query i at absolute
# position cpos[i] attends every resident pool token below its position
# — excluding the chunk's own not-yet-scattered positions — plus chunk
# tokens j <= i.  With cpos = offset + arange(C) this reduces exactly to
# the scalar-offset kernel above.
# ---------------------------------------------------------------------------
def _pool_limits(chunk_positions: jax.Array, c: int) -> jax.Array:
    """Tokens of resident pool context any query may attend, per row:
    the start position of the trailing contiguous run of valid chunk
    positions (everything from there up is the chunk's own writes, never
    read from the pool).  Drives page-iteration gating and the DMA
    clamp, and reduces to ``offsets`` in the degenerate contiguous
    case."""
    cp = chunk_positions
    valid = cp >= 0
    idx = jnp.arange(c, dtype=cp.dtype)[None, :]
    d = cp - idx                         # constant along a contiguous run
    any_valid = jnp.any(valid, axis=1)
    last = jnp.argmax(jnp.where(valid, idx, -1), axis=1)       # [B]
    d_last = jnp.take_along_axis(d, last[:, None], axis=1)     # [B, 1]
    in_suffix = idx <= last[:, None]
    ok = jnp.where(in_suffix, (d == d_last) & valid, True)
    suffix_all = jnp.flip(
        jnp.cumprod(jnp.flip(ok.astype(jnp.int32), 1), 1), 1).astype(bool)
    big = jnp.asarray(jnp.iinfo(jnp.int32).max, cp.dtype)
    run_min = jnp.min(
        jnp.where(suffix_all & in_suffix & valid, cp, big), axis=1)
    return jnp.where(any_valid, run_min, 0).astype(jnp.int32)


def _prefill_seg_kernel(
    # scalar-prefetch operands
    block_tables_ref,      # [B, P] int32
    limits_ref,            # [B] int32  (resident pool tokens attendable)
    # array operands (blocked)
    cp_ref,                # [1, C, 1] int32  per-query absolute positions
    q_ref,                 # [1, C, Hq, hd]
    kc_ref,                # [1, C, Hkv, hd]  chunk KV (not yet in the pool)
    vc_ref,                # [1, C, Hkv, hd]
    kp_ref,                # [1, page, Hkv, hd]  pool page
    vp_ref,                # [1, page, Hkv, hd]
    # outputs
    o_ref,                 # [1, C, Hq, hd]
    # scratch
    m_ref,                 # [C, Hq] f32
    l_ref,                 # [C, Hq] f32
    acc_ref,               # [C, Hq, hd] f32
    *, page: int, n_prior: int, chunk: int,
):
    b = pl.program_id(0)
    p = pl.program_id(1)
    limit = limits_ref[b]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _update(s, v, hkv, g):
        """Online-softmax update; s [C, Hq, T], v [T, Hkv, hd]."""
        m_prev = m_ref[...]                              # [C, Hq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        prob = jnp.exp(s - m_new[..., None])             # [C, Hq, T]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(prob, axis=-1)
        pg = prob.reshape(chunk, hkv, g, -1)
        pv = jnp.einsum("chgt,thd->chgd", pg, v)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + \
            pv.reshape(chunk, -1, v.shape[-1])
        m_ref[...] = m_new

    # full attention to resident pool tokens below each query's own
    # position; pages wholly at/past the limit hold nothing attendable
    @pl.when((p < n_prior) & (p * page < limit))
    def _prior():
        q = q_ref[0].astype(jnp.float32)                 # [C, Hq, hd]
        k = kp_ref[0].astype(jnp.float32)                # [page, Hkv, hd]
        v = vp_ref[0].astype(jnp.float32)
        c, hq, hd = q.shape
        hkv = k.shape[1]
        g = hq // hkv
        scale = 1.0 / math.sqrt(hd)
        qg = q.reshape(c, hkv, g, hd)
        s = jnp.einsum("chgd,thd->chgt", qg, k).reshape(c, hq, page) * scale
        cpos = cp_ref[0]                                 # [C, 1]
        keyp = p * page + jax.lax.broadcasted_iota(
            jnp.int32, (c, page), 1)                     # rows identical
        # pool slots this chunk itself will occupy are not yet written:
        # row j of eq marks cpos[j]'s slot; any() folds over the chunk
        excl = jnp.any(keyp == cpos, axis=0, keepdims=True)   # [1, page]
        mask = (keyp < cpos) & jnp.logical_not(excl)          # [C, page]
        s = jnp.where(mask[:, None, :], s, NEG_INF)
        _update(s, v, hkv, g)

    # causal attention within the chunk itself (positions are strictly
    # ascending, so index order == position order), then finalize
    @pl.when(p == n_prior)
    def _chunk():
        q = q_ref[0].astype(jnp.float32)
        k = kc_ref[0].astype(jnp.float32)                # [C, Hkv, hd]
        v = vc_ref[0].astype(jnp.float32)
        c, hq, hd = q.shape
        hkv = k.shape[1]
        g = hq // hkv
        scale = 1.0 / math.sqrt(hd)
        qg = q.reshape(c, hkv, g, hd)
        s = jnp.einsum("chgd,thd->chgt", qg, k).reshape(c, hq, c) * scale
        qpos = jax.lax.broadcasted_iota(jnp.int32, (c, 1, c), 0)
        kpos = jax.lax.broadcasted_iota(jnp.int32, (c, 1, c), 2)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
        _update(s, v, hkv, g)
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[..., None]
                    ).astype(o_ref.dtype)


def paged_prefill_segments(q: jax.Array, k_chunk: jax.Array,
                           v_chunk: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           chunk_positions: jax.Array, *,
                           interpret: bool | None = None) -> jax.Array:
    """q [B,C,Hq,hd]; k/v_chunk [B,C,Hkv,hd]; k/v_pages [N,page,Hkv,hd];
    block_tables [B,P] int32; chunk_positions [B,C] int32 -> [B,C,Hq,hd].

    Query i of request b sits at absolute position chunk_positions[b, i]
    (strictly ascending among valid entries; negative = padding): it
    attends every resident pool token below its position through the
    block table — the chunk's own not-yet-scattered positions excluded —
    plus chunk tokens j <= i.  Every position below a query's that is
    not in the chunk must already be resident (earlier gaps filled,
    resumed segments shared or injected).  The chunk's KV must NOT yet
    be written to the pool; the caller scatters it afterwards.
    """
    interpret = resolve_interpret(interpret)
    b, c, hq, hd = q.shape
    n, page, hkv, _ = k_pages.shape
    p_max = block_tables.shape[1]
    limits = _pool_limits(chunk_positions, c)
    cp3 = chunk_positions.astype(jnp.int32)[:, :, None]   # [B, C, 1]

    def _page_idx(bi, pi, bt, lim):
        # clamp to the last page holding attendable resident tokens so
        # consecutive identical indices elide the DMA entirely
        last_useful = jnp.maximum((lim[bi] + page - 1) // page - 1, 0)
        return (bt[bi, jnp.minimum(pi, jnp.minimum(last_useful,
                                                   p_max - 1))], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, p_max + 1),
        in_specs=[
            pl.BlockSpec((1, c, 1), lambda bi, pi, bt, lim: (bi, 0, 0)),
            pl.BlockSpec((1, c, hq, hd), lambda bi, pi, bt, lim: (bi, 0, 0, 0)),
            pl.BlockSpec((1, c, hkv, hd), lambda bi, pi, bt, lim: (bi, 0, 0, 0)),
            pl.BlockSpec((1, c, hkv, hd), lambda bi, pi, bt, lim: (bi, 0, 0, 0)),
            pl.BlockSpec((1, page, hkv, hd), _page_idx),
            pl.BlockSpec((1, page, hkv, hd), _page_idx),
        ],
        out_specs=pl.BlockSpec((1, c, hq, hd),
                               lambda bi, pi, bt, lim: (bi, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((c, hq), jnp.float32),
            pltpu.VMEM((c, hq), jnp.float32),
            pltpu.VMEM((c, hq, hd), jnp.float32),
        ],
    )
    kernel = pl.pallas_call(
        functools.partial(_prefill_seg_kernel, page=page, n_prior=p_max,
                          chunk=c),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c, hq, hd), q.dtype),
        interpret=interpret,
    )
    return kernel(block_tables, limits, cp3, q, k_chunk, v_chunk,
                  k_pages, v_pages)


# ---------------------------------------------------------------------------
# Absorbed-MLA chunked prefill: queries move into latent space, pages are
# dense [page, dl+dr] strips shared by all heads (same layout as
# kernels/mla_paged_decode.py), so one matmul per page serves every head.
# ---------------------------------------------------------------------------
def _mla_prefill_kernel(block_tables_ref, offsets_ref, q_lat_ref,
                        q_rope_ref, lat_chunk_ref, lat_page_ref, o_ref,
                        m_ref, l_ref, acc_ref,
                        *, page: int, n_prior: int, chunk: int,
                        d_latent: int, scale: float):
    b = pl.program_id(0)
    p = pl.program_id(1)
    offset = offsets_ref[b]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _update(s, c_kv):
        m_prev = m_ref[...]                              # [C, Hq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        prob = jnp.exp(s - m_new[..., None])             # [C, Hq, T]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(prob, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + \
            jnp.einsum("cht,tl->chl", prob, c_kv)
        m_ref[...] = m_new

    @pl.when((p < n_prior) & (p * page < offset))
    def _prior():
        ql = q_lat_ref[0].astype(jnp.float32)            # [C, Hq, dl]
        qr = q_rope_ref[0].astype(jnp.float32)           # [C, Hq, dr]
        lat = lat_page_ref[0].astype(jnp.float32)        # [page, dl+dr]
        c_kv, kr = lat[:, :d_latent], lat[:, d_latent:]
        s = (jnp.einsum("chl,tl->cht", ql, c_kv)
             + jnp.einsum("chr,tr->cht", qr, kr)) * scale
        pos = p * page + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, page), 2)
        s = jnp.where(pos < offset, s, NEG_INF)
        _update(s, c_kv)

    @pl.when(p == n_prior)
    def _chunk():
        ql = q_lat_ref[0].astype(jnp.float32)
        qr = q_rope_ref[0].astype(jnp.float32)
        lat = lat_chunk_ref[0].astype(jnp.float32)       # [C, dl+dr]
        c_kv, kr = lat[:, :d_latent], lat[:, d_latent:]
        s = (jnp.einsum("chl,tl->cht", ql, c_kv)
             + jnp.einsum("chr,tr->cht", qr, kr)) * scale
        qpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, 1, chunk), 0)
        kpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, 1, chunk), 2)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
        _update(s, c_kv)
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[..., None]
                    ).astype(o_ref.dtype)


def mla_paged_prefill(q_lat: jax.Array, q_rope: jax.Array,
                      lat_chunk: jax.Array, latent_pages: jax.Array,
                      block_tables: jax.Array, offsets: jax.Array, *,
                      d_latent: int, scale: float = None,
                      interpret: bool | None = None) -> jax.Array:
    """q_lat [B,C,Hq,dl]; q_rope [B,C,Hq,dr]; lat_chunk [B,C,dl+dr];
    latent_pages [N,page,dl+dr]; -> ctx [B,C,Hq,dl] (caller applies
    W_uv + the output projection, as in the paged decode kernel)."""
    interpret = resolve_interpret(interpret)
    b, c, hq, dl = q_lat.shape
    dr = q_rope.shape[-1]
    n, page, dtot = latent_pages.shape
    p_max = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(dl // 4 + dr)  # ref-oracle convention

    def _page_idx(bi, pi, bt, off):
        last_useful = jnp.maximum((off[bi] + page - 1) // page - 1, 0)
        return (bt[bi, jnp.minimum(pi, jnp.minimum(last_useful,
                                                   p_max - 1))], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, p_max + 1),
        in_specs=[
            pl.BlockSpec((1, c, hq, dl), lambda bi, pi, bt, off: (bi, 0, 0, 0)),
            pl.BlockSpec((1, c, hq, dr), lambda bi, pi, bt, off: (bi, 0, 0, 0)),
            pl.BlockSpec((1, c, dtot), lambda bi, pi, bt, off: (bi, 0, 0)),
            pl.BlockSpec((1, page, dtot), _page_idx),
        ],
        out_specs=pl.BlockSpec((1, c, hq, dl),
                               lambda bi, pi, bt, off: (bi, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((c, hq), jnp.float32),
            pltpu.VMEM((c, hq), jnp.float32),
            pltpu.VMEM((c, hq, dl), jnp.float32),
        ],
    )
    kernel = pl.pallas_call(
        functools.partial(_mla_prefill_kernel, page=page, n_prior=p_max,
                          chunk=c, d_latent=dl, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c, hq, dl), q_lat.dtype),
        interpret=interpret,
    )
    return kernel(block_tables, offsets, q_lat, q_rope, lat_chunk,
                  latent_pages)


def _mla_prefill_seg_kernel(block_tables_ref, limits_ref, cp_ref,
                            q_lat_ref, q_rope_ref, lat_chunk_ref,
                            lat_page_ref, o_ref, m_ref, l_ref, acc_ref,
                            *, page: int, n_prior: int, chunk: int,
                            d_latent: int, scale: float):
    b = pl.program_id(0)
    p = pl.program_id(1)
    limit = limits_ref[b]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _update(s, c_kv):
        m_prev = m_ref[...]                              # [C, Hq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        prob = jnp.exp(s - m_new[..., None])             # [C, Hq, T]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(prob, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + \
            jnp.einsum("cht,tl->chl", prob, c_kv)
        m_ref[...] = m_new

    @pl.when((p < n_prior) & (p * page < limit))
    def _prior():
        ql = q_lat_ref[0].astype(jnp.float32)            # [C, Hq, dl]
        qr = q_rope_ref[0].astype(jnp.float32)           # [C, Hq, dr]
        lat = lat_page_ref[0].astype(jnp.float32)        # [page, dl+dr]
        c_kv, kr = lat[:, :d_latent], lat[:, d_latent:]
        s = (jnp.einsum("chl,tl->cht", ql, c_kv)
             + jnp.einsum("chr,tr->cht", qr, kr)) * scale
        cpos = cp_ref[0]                                 # [C, 1]
        keyp = p * page + jax.lax.broadcasted_iota(
            jnp.int32, (chunk, page), 1)
        excl = jnp.any(keyp == cpos, axis=0, keepdims=True)   # [1, page]
        mask = (keyp < cpos) & jnp.logical_not(excl)          # [C, page]
        s = jnp.where(mask[:, None, :], s, NEG_INF)
        _update(s, c_kv)

    @pl.when(p == n_prior)
    def _chunk():
        ql = q_lat_ref[0].astype(jnp.float32)
        qr = q_rope_ref[0].astype(jnp.float32)
        lat = lat_chunk_ref[0].astype(jnp.float32)       # [C, dl+dr]
        c_kv, kr = lat[:, :d_latent], lat[:, d_latent:]
        s = (jnp.einsum("chl,tl->cht", ql, c_kv)
             + jnp.einsum("chr,tr->cht", qr, kr)) * scale
        qpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, 1, chunk), 0)
        kpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, 1, chunk), 2)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
        _update(s, c_kv)
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[..., None]
                    ).astype(o_ref.dtype)


def mla_paged_prefill_segments(q_lat: jax.Array, q_rope: jax.Array,
                               lat_chunk: jax.Array,
                               latent_pages: jax.Array,
                               block_tables: jax.Array,
                               chunk_positions: jax.Array, *,
                               d_latent: int, scale: float = None,
                               interpret: bool | None = None) -> jax.Array:
    """Absorbed-MLA segment prefill (same position semantics as
    ``paged_prefill_segments``): q_lat [B,C,Hq,dl]; q_rope [B,C,Hq,dr];
    lat_chunk [B,C,dl+dr]; latent_pages [N,page,dl+dr];
    chunk_positions [B,C] int32 -> ctx [B,C,Hq,dl]."""
    interpret = resolve_interpret(interpret)
    b, c, hq, dl = q_lat.shape
    dr = q_rope.shape[-1]
    n, page, dtot = latent_pages.shape
    p_max = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(dl // 4 + dr)  # ref-oracle convention
    limits = _pool_limits(chunk_positions, c)
    cp3 = chunk_positions.astype(jnp.int32)[:, :, None]   # [B, C, 1]

    def _page_idx(bi, pi, bt, lim):
        last_useful = jnp.maximum((lim[bi] + page - 1) // page - 1, 0)
        return (bt[bi, jnp.minimum(pi, jnp.minimum(last_useful,
                                                   p_max - 1))], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, p_max + 1),
        in_specs=[
            pl.BlockSpec((1, c, 1), lambda bi, pi, bt, lim: (bi, 0, 0)),
            pl.BlockSpec((1, c, hq, dl), lambda bi, pi, bt, lim: (bi, 0, 0, 0)),
            pl.BlockSpec((1, c, hq, dr), lambda bi, pi, bt, lim: (bi, 0, 0, 0)),
            pl.BlockSpec((1, c, dtot), lambda bi, pi, bt, lim: (bi, 0, 0)),
            pl.BlockSpec((1, page, dtot), _page_idx),
        ],
        out_specs=pl.BlockSpec((1, c, hq, dl),
                               lambda bi, pi, bt, lim: (bi, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((c, hq), jnp.float32),
            pltpu.VMEM((c, hq), jnp.float32),
            pltpu.VMEM((c, hq, dl), jnp.float32),
        ],
    )
    kernel = pl.pallas_call(
        functools.partial(_mla_prefill_seg_kernel, page=page,
                          n_prior=p_max, chunk=c, d_latent=dl,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c, hq, dl), q_lat.dtype),
        interpret=interpret,
    )
    return kernel(block_tables, limits, cp3, q_lat, q_rope, lat_chunk,
                  latent_pages)
