"""MLA paged decode attention — Pallas TPU kernel (paper contribution #1).

Absorbed-form MLA decode reads only the (d_latent + d_rope)-wide latent
cache — the structural source of the paper's 57x memory claim.  Compared
to the GQA paged kernel the page tile is a dense 2-D [page, dl+dr] strip
(no head dim: the latent is shared by all query heads via the
up-projection absorbed into q), so the MXU contraction is
[Hq, dl] x [dl, page] — one matmul per page serving *all* heads.

Grid: (batch, num_pages), flash accumulators in VMEM scratch, block
table resolved by scalar prefetch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

NEG_INF = -1e30


def _mla_kernel(block_tables_ref, lengths_ref, q_lat_ref, q_rope_ref,
                lat_ref, o_ref, m_ref, l_ref, acc_ref,
                *, page: int, n_pages: int, d_latent: int, scale: float):
    b = pl.program_id(0)
    p = pl.program_id(1)
    length = lengths_ref[b]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = p * page

    @pl.when(start < length)
    def _attend():
        ql = q_lat_ref[0].astype(jnp.float32)          # [Hq, dl]
        qr = q_rope_ref[0].astype(jnp.float32)         # [Hq, dr]
        lat = lat_ref[0].astype(jnp.float32)           # [page, dl+dr]
        c, kr = lat[:, :d_latent], lat[:, d_latent:]
        s = (ql @ c.T + qr @ kr.T) * scale             # [Hq, page]
        pos = start + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        prob = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(prob, axis=1,
                                                  keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + prob @ c  # [Hq, dl]
        m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def mla_paged_decode(q_lat: jax.Array, q_rope: jax.Array,
                     latent_pages: jax.Array, block_tables: jax.Array,
                     lengths: jax.Array, *, d_latent: int,
                     head_dim: int = 128, scale: float = None,
                     interpret: bool | None = None) -> jax.Array:
    """q_lat [B,Hq,dl], q_rope [B,Hq,dr]; latent_pages [N,page,dl+dr];
    -> ctx [B,Hq,dl] (caller applies W_uv + output projection).

    ``scale`` overrides the softmax scale; the default keeps the
    dl//4 + dr convention of the reference oracle (hd ~ dl/4).  The
    live engine passes 1/sqrt(hd + dr) to match the absorbed-form
    dense decode exactly.
    """
    interpret = resolve_interpret(interpret)
    b, hq, dl = q_lat.shape
    dr = q_rope.shape[-1]
    n, page, dtot = latent_pages.shape
    p_max = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(dl // 4 + dr)  # matches ref convention

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, p_max),
        in_specs=[
            pl.BlockSpec((1, hq, dl), lambda bi, pi, bt, ln: (bi, 0, 0)),
            pl.BlockSpec((1, hq, dr), lambda bi, pi, bt, ln: (bi, 0, 0)),
            pl.BlockSpec((1, page, dtot),
                         lambda bi, pi, bt, ln: (bt[bi, pi], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hq, dl),
                               lambda bi, pi, bt, ln: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, dl), jnp.float32),
        ],
    )
    kernel = pl.pallas_call(
        functools.partial(_mla_kernel, page=page, n_pages=p_max,
                          d_latent=dl, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, dl), q_lat.dtype),
        interpret=interpret,
    )
    return kernel(block_tables, lengths, q_lat, q_rope, latent_pages)
