"""Paged decode attention — Pallas TPU kernel.

The serving-engine hot spot: one query per request attends over its KV
blocks scattered across a paged pool, indirected by a block table.  This
is the ragged-batch decode fast path the paper's Tier-0 block layout maps
onto (PagedAttention-compatible, §III-B Tier 0).

TPU adaptation (vs the CUDA original): the block table is a
*scalar-prefetch* operand — Pallas resolves each grid step's page index
on the scalar core before the DMA that stages the page into VMEM, so the
gather indirection costs nothing on the vector path.  Pages are sized so
one (page, h_kv, hd) tile fits VMEM alongside the query and the flash
accumulators; the MXU sees dense [Hq, hd] x [hd, page] contractions.

Grid: (batch, num_pages) — pages iterate innermost (sequential on TPU),
carrying running flash-softmax stats (m, l, acc) in VMEM scratch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret

DEFAULT_PAGE = 64
NEG_INF = -1e30


def _decode_kernel(
    # scalar-prefetch operands
    block_tables_ref,      # [B, P_max] int32
    lengths_ref,           # [B] int32
    # array operands (blocked)
    q_ref,                 # [1, Hq, hd]
    k_ref,                 # [1, page, Hkv, hd]
    v_ref,                 # [1, page, Hkv, hd]
    # outputs
    o_ref,                 # [1, Hq, hd]
    # scratch
    m_ref,                 # [Hq, 1] f32
    l_ref,                 # [Hq, 1] f32
    acc_ref,               # [Hq, hd] f32
    *, page: int, n_pages: int,
):
    b = pl.program_id(0)
    p = pl.program_id(1)
    length = lengths_ref[b]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = p * page
    valid_page = start < length

    @pl.when(valid_page)
    def _attend():
        q = q_ref[0].astype(jnp.float32)              # [Hq, hd]
        k = k_ref[0].astype(jnp.float32)              # [page, Hkv, hd]
        v = v_ref[0].astype(jnp.float32)
        hq, hd = q.shape
        hkv = k.shape[1]
        g = hq // hkv
        scale = 1.0 / math.sqrt(hd)
        qg = q.reshape(hkv, g, hd)
        s = jnp.einsum("hgd,thd->hgt", qg, k) * scale  # [Hkv, G, page]
        pos = start + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
        s = jnp.where(pos < length, s, NEG_INF)
        s = s.reshape(hq, page)
        m_prev = m_ref[...]                            # [Hq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        prob = jnp.exp(s - m_new)                      # [Hq, page]
        l_ref[...] = l_ref[...] * alpha + \
            jnp.sum(prob, axis=1, keepdims=True)
        pv = jnp.einsum("hgt,thd->hgd", prob.reshape(hkv, g, page), v)
        acc_ref[...] = acc_ref[...] * alpha + pv.reshape(hq, hd)
        m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *,
                           interpret: bool | None = None) -> jax.Array:
    """q [B,Hq,hd]; k/v_pages [N,page,Hkv,hd]; block_tables [B,P] int32;
    lengths [B] int32 -> out [B,Hq,hd].

    ``interpret`` defaults through ``backend.resolve_interpret``:
    compiled on TPU, interpreter elsewhere (True forces the Python-grid
    debug path; the serving-grade off-TPU route is the ``xla`` backend
    in ``ops.py``).
    """
    interpret = resolve_interpret(interpret)
    b, hq, hd = q.shape
    n, page, hkv, _ = k_pages.shape
    p_max = block_tables.shape[1]

    # scratch: running max / denom / accumulator live in VMEM across the
    # sequential page iterations
    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, p_max),
        in_specs=[
            pl.BlockSpec((1, hq, hd), lambda bi, pi, bt, ln: (bi, 0, 0)),
            pl.BlockSpec((1, page, hkv, hd),
                         lambda bi, pi, bt, ln: (bt[bi, pi], 0, 0, 0)),
            pl.BlockSpec((1, page, hkv, hd),
                         lambda bi, pi, bt, ln: (bt[bi, pi], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hq, hd), lambda bi, pi, bt, ln: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, hd), jnp.float32),
        ],
    )
    kernel = pl.pallas_call(
        functools.partial(_decode_kernel, page=page, n_pages=p_max),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, hd), q.dtype),
        interpret=interpret,
    )
    return kernel(block_tables, lengths, q, k_pages, v_pages)


# ---------------------------------------------------------------------------
# int8-quantized paged decode: pages stored int8 + per-token-head scales;
# dequantization happens in VMEM registers (the HBM->VMEM DMA moves 1-byte
# elements — the traffic halving that the XLA fallback cannot deliver,
# EXPERIMENTS §Perf cell A iter 3).
# ---------------------------------------------------------------------------
def _decode_kernel_int8(
    block_tables_ref, lengths_ref,
    q_ref,                 # [1, Hq, hd]
    k_ref, v_ref,          # [1, page, Hkv, hd] int8
    ks_ref, vs_ref,        # [1, page, Hkv, 1] scales
    o_ref,
    m_ref, l_ref, acc_ref,
    *, page: int, n_pages: int,
):
    b = pl.program_id(0)
    p = pl.program_id(1)
    length = lengths_ref[b]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = p * page

    @pl.when(start < length)
    def _attend():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32) * ks_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32) * vs_ref[0].astype(jnp.float32)
        hq, hd = q.shape
        hkv = k.shape[1]
        g = hq // hkv
        scale = 1.0 / math.sqrt(hd)
        qg = q.reshape(hkv, g, hd)
        s = jnp.einsum("hgd,thd->hgt", qg, k) * scale
        pos = start + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
        s = jnp.where(pos < length, s, NEG_INF)
        s = s.reshape(hq, page)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        prob = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(prob, axis=1,
                                                  keepdims=True)
        pv = jnp.einsum("hgt,thd->hgd", prob.reshape(hkv, g, page), v)
        acc_ref[...] = acc_ref[...] * alpha + pv.reshape(hq, hd)
        m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention_int8(q, k_pages, v_pages, k_scales, v_scales,
                                block_tables, lengths, *,
                                interpret: bool | None = None):
    """q [B,Hq,hd]; k/v_pages int8 [N,page,Hkv,hd]; scales
    [N,page,Hkv,1]; -> [B,Hq,hd]."""
    interpret = resolve_interpret(interpret)
    b, hq, hd = q.shape
    n, page, hkv, _ = k_pages.shape
    p_max = block_tables.shape[1]
    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, p_max),
        in_specs=[
            pl.BlockSpec((1, hq, hd), lambda bi, pi, bt, ln: (bi, 0, 0)),
            pl.BlockSpec((1, page, hkv, hd),
                         lambda bi, pi, bt, ln: (bt[bi, pi], 0, 0, 0)),
            pl.BlockSpec((1, page, hkv, hd),
                         lambda bi, pi, bt, ln: (bt[bi, pi], 0, 0, 0)),
            pl.BlockSpec((1, page, hkv, 1),
                         lambda bi, pi, bt, ln: (bt[bi, pi], 0, 0, 0)),
            pl.BlockSpec((1, page, hkv, 1),
                         lambda bi, pi, bt, ln: (bt[bi, pi], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hq, hd),
                               lambda bi, pi, bt, ln: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, hd), jnp.float32),
        ],
    )
    kernel = pl.pallas_call(
        functools.partial(_decode_kernel_int8, page=page, n_pages=p_max),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, hd), q.dtype),
        interpret=interpret,
    )
    return kernel(block_tables, lengths, q, k_pages, v_pages,
                  k_scales, v_scales)
