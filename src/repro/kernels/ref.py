"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables,
                               lengths) -> jax.Array:
    """q [B,Hq,hd]; k/v_pages [N,page,Hkv,hd]; block_tables [B,P];
    lengths [B] -> [B,Hq,hd]."""
    b, hq, hd = q.shape
    n, page, hkv, _ = k_pages.shape
    p_max = block_tables.shape[1]
    g = hq // hkv

    def one(qb, bt, ln):
        k = k_pages[bt].reshape(p_max * page, hkv, hd)   # gather pages
        v = v_pages[bt].reshape(p_max * page, hkv, hd)
        qg = qb.reshape(hkv, g, hd).astype(jnp.float32)
        s = jnp.einsum("hgd,thd->hgt", qg, k.astype(jnp.float32))
        s = s / math.sqrt(hd)
        pos = jnp.arange(p_max * page)
        s = jnp.where(pos[None, None, :] < ln, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("hgt,thd->hgd", p, v.astype(jnp.float32))
        return o.reshape(hq, hd)

    return jax.vmap(one)(q, block_tables, lengths).astype(q.dtype)


def mla_paged_decode_ref(q_lat, q_rope, latent_pages, block_tables,
                         lengths, d_latent: int,
                         scale: float = None) -> jax.Array:
    """q_lat [B,Hq,dl]; q_rope [B,Hq,dr]; latent_pages [N,page,dl+dr];
    -> ctx [B,Hq,dl] (absorbed-form attention output in latent space)."""
    b, hq, dl = q_lat.shape
    dr = q_rope.shape[-1]
    n, page, dtot = latent_pages.shape
    p_max = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(dl // 4 + dr)  # hd ~ dl/4 convention of caller

    def one(ql, qr, bt, ln):
        lat = latent_pages[bt].reshape(p_max * page, dtot)
        c, kr = lat[:, :dl], lat[:, dl:]
        s = (jnp.einsum("hl,tl->ht", ql.astype(jnp.float32),
                        c.astype(jnp.float32))
             + jnp.einsum("hr,tr->ht", qr.astype(jnp.float32),
                          kr.astype(jnp.float32))) * scale
        pos = jnp.arange(p_max * page)
        s = jnp.where(pos[None, :] < ln, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("ht,tl->hl", p, c.astype(jnp.float32))

    return jax.vmap(one)(q_lat, q_rope, block_tables, lengths
                         ).astype(q_lat.dtype)


def flash_prefill_ref(q, k, v) -> jax.Array:
    """Causal attention oracle. q [B,S,Hq,hd], k/v [B,S,Hkv,hd]."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, hd).astype(jnp.float32)
    sc = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    sc = sc / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask[None, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, s, hq, hd).astype(q.dtype)


def paged_decode_attention_int8_ref(q, k_pages, v_pages, k_scales,
                                    v_scales, block_tables, lengths):
    """Dequantize-then-attend oracle for the int8 paged kernel."""
    k = k_pages.astype(jnp.float32) * k_scales.astype(jnp.float32)
    v = v_pages.astype(jnp.float32) * v_scales.astype(jnp.float32)
    return paged_decode_attention_ref(q, k.astype(q.dtype),
                                      v.astype(q.dtype),
                                      block_tables, lengths)
