"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables,
                               lengths) -> jax.Array:
    """q [B,Hq,hd]; k/v_pages [N,page,Hkv,hd]; block_tables [B,P];
    lengths [B] -> [B,Hq,hd]."""
    b, hq, hd = q.shape
    n, page, hkv, _ = k_pages.shape
    p_max = block_tables.shape[1]
    g = hq // hkv

    def one(qb, bt, ln):
        k = k_pages[bt].reshape(p_max * page, hkv, hd)   # gather pages
        v = v_pages[bt].reshape(p_max * page, hkv, hd)
        qg = qb.reshape(hkv, g, hd).astype(jnp.float32)
        s = jnp.einsum("hgd,thd->hgt", qg, k.astype(jnp.float32))
        s = s / math.sqrt(hd)
        pos = jnp.arange(p_max * page)
        s = jnp.where(pos[None, None, :] < ln, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("hgt,thd->hgd", p, v.astype(jnp.float32))
        return o.reshape(hq, hd)

    return jax.vmap(one)(q, block_tables, lengths).astype(q.dtype)


def mla_paged_decode_ref(q_lat, q_rope, latent_pages, block_tables,
                         lengths, d_latent: int,
                         scale: float = None) -> jax.Array:
    """q_lat [B,Hq,dl]; q_rope [B,Hq,dr]; latent_pages [N,page,dl+dr];
    -> ctx [B,Hq,dl] (absorbed-form attention output in latent space)."""
    b, hq, dl = q_lat.shape
    dr = q_rope.shape[-1]
    n, page, dtot = latent_pages.shape
    p_max = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(dl // 4 + dr)  # hd ~ dl/4 convention of caller

    def one(ql, qr, bt, ln):
        lat = latent_pages[bt].reshape(p_max * page, dtot)
        c, kr = lat[:, :dl], lat[:, dl:]
        s = (jnp.einsum("hl,tl->ht", ql.astype(jnp.float32),
                        c.astype(jnp.float32))
             + jnp.einsum("hr,tr->ht", qr.astype(jnp.float32),
                          kr.astype(jnp.float32))) * scale
        pos = jnp.arange(p_max * page)
        s = jnp.where(pos[None, :] < ln, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("ht,tl->hl", p, c.astype(jnp.float32))

    return jax.vmap(one)(q_lat, q_rope, block_tables, lengths
                         ).astype(q_lat.dtype)


def paged_prefill_attention_ref(q, k_chunk, v_chunk, k_pages, v_pages,
                                block_tables, offsets) -> jax.Array:
    """Chunked-prefill oracle: q [B,C,Hq,hd] at positions offset+i attends
    pool tokens < offset (via block table) plus chunk tokens j <= i."""
    b, c, hq, hd = q.shape
    n, page, hkv, _ = k_pages.shape
    p_max = block_tables.shape[1]
    g = hq // hkv

    def one(qb, kc, vc, bt, off):
        kp = k_pages[bt].reshape(p_max * page, hkv, hd)
        vp = v_pages[bt].reshape(p_max * page, hkv, hd)
        k = jnp.concatenate([kp, kc], axis=0)            # [T, Hkv, hd]
        v = jnp.concatenate([vp, vc], axis=0)
        qg = qb.reshape(c, hkv, g, hd).astype(jnp.float32)
        s = jnp.einsum("chgd,thd->chgt", qg, k.astype(jnp.float32))
        s = s.reshape(c, hq, -1) / math.sqrt(hd)
        pos = jnp.arange(p_max * page + c)
        prior = pos[None, :] < off                       # pool tokens
        causal = (pos[None, :] >= p_max * page) & \
            (pos[None, :] - p_max * page <= jnp.arange(c)[:, None])
        mask = prior | causal                            # [C, T]
        s = jnp.where(mask[:, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("cht,thd->chd",
                       p.reshape(c, hq, -1),
                       jnp.repeat(v, g, axis=1).astype(jnp.float32))
        return o

    return jax.vmap(one)(q, k_chunk, v_chunk, block_tables, offsets
                         ).astype(q.dtype)


def paged_prefill_segments_ref(q, k_chunk, v_chunk, k_pages, v_pages,
                               block_tables, chunk_positions) -> jax.Array:
    """Segment-prefill oracle: query i of row b sits at absolute position
    ``chunk_positions[b, i]`` (strictly ascending among valid entries;
    negative entries are padding).  It attends every *resident* pool
    token below its position — pool positions t < cpos[i] that are NOT
    one of the chunk's own positions (the chunk's KV arrives densely and
    is only scattered into the pool afterwards) — plus chunk tokens
    j <= i.  Every position below cpos[i] not in cpos must already be
    resident (earlier gaps filled, resumed segments shared/injected).
    With cpos = offset + arange(C) this reduces exactly to
    ``paged_prefill_attention_ref``."""
    b, c, hq, hd = q.shape
    n, page, hkv, _ = k_pages.shape
    p_max = block_tables.shape[1]
    g = hq // hkv

    def one(qb, kc, vc, bt, cpos):
        kp = k_pages[bt].reshape(p_max * page, hkv, hd)
        vp = v_pages[bt].reshape(p_max * page, hkv, hd)
        k = jnp.concatenate([kp, kc], axis=0)            # [T, Hkv, hd]
        v = jnp.concatenate([vp, vc], axis=0)
        qg = qb.reshape(c, hkv, g, hd).astype(jnp.float32)
        s = jnp.einsum("chgd,thd->chgt", qg, k.astype(jnp.float32))
        s = s.reshape(c, hq, -1) / math.sqrt(hd)
        pos = jnp.arange(p_max * page + c)
        own = jnp.any(pos[None, :] == cpos[:, None], axis=0)   # [T]
        prior = (pos[None, :] < cpos[:, None]) & ~own[None, :]
        causal = (pos[None, :] >= p_max * page) & \
            (pos[None, :] - p_max * page <= jnp.arange(c)[:, None])
        mask = prior | causal                            # [C, T]
        s = jnp.where(mask[:, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("cht,thd->chd",
                       p.reshape(c, hq, -1),
                       jnp.repeat(v, g, axis=1).astype(jnp.float32))
        return o

    return jax.vmap(one)(q, k_chunk, v_chunk, block_tables, chunk_positions
                         ).astype(q.dtype)


def mla_paged_prefill_ref(q_lat, q_rope, lat_chunk, latent_pages,
                          block_tables, offsets, d_latent: int,
                          scale: float = None) -> jax.Array:
    """Absorbed-MLA chunked-prefill oracle -> ctx [B,C,Hq,dl]."""
    b, c, hq, dl = q_lat.shape
    dr = q_rope.shape[-1]
    n, page, dtot = latent_pages.shape
    p_max = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(dl // 4 + dr)

    def one(ql, qr, lc, bt, off):
        lat = jnp.concatenate(
            [latent_pages[bt].reshape(p_max * page, dtot), lc], axis=0)
        c_kv, kr = lat[:, :dl], lat[:, dl:]
        s = (jnp.einsum("chl,tl->cht", ql.astype(jnp.float32),
                        c_kv.astype(jnp.float32))
             + jnp.einsum("chr,tr->cht", qr.astype(jnp.float32),
                          kr.astype(jnp.float32))) * scale
        pos = jnp.arange(p_max * page + c)
        prior = pos[None, :] < off
        causal = (pos[None, :] >= p_max * page) & \
            (pos[None, :] - p_max * page <= jnp.arange(c)[:, None])
        s = jnp.where((prior | causal)[:, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("cht,tl->chl", p, c_kv.astype(jnp.float32))

    return jax.vmap(one)(q_lat, q_rope, lat_chunk, block_tables, offsets
                         ).astype(q_lat.dtype)


def mla_paged_prefill_segments_ref(q_lat, q_rope, lat_chunk, latent_pages,
                                   block_tables, chunk_positions,
                                   d_latent: int,
                                   scale: float = None) -> jax.Array:
    """Absorbed-MLA segment-prefill oracle (same position semantics as
    ``paged_prefill_segments_ref``) -> ctx [B,C,Hq,dl]."""
    b, c, hq, dl = q_lat.shape
    dr = q_rope.shape[-1]
    n, page, dtot = latent_pages.shape
    p_max = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(dl // 4 + dr)

    def one(ql, qr, lc, bt, cpos):
        lat = jnp.concatenate(
            [latent_pages[bt].reshape(p_max * page, dtot), lc], axis=0)
        c_kv, kr = lat[:, :dl], lat[:, dl:]
        s = (jnp.einsum("chl,tl->cht", ql.astype(jnp.float32),
                        c_kv.astype(jnp.float32))
             + jnp.einsum("chr,tr->cht", qr.astype(jnp.float32),
                          kr.astype(jnp.float32))) * scale
        pos = jnp.arange(p_max * page + c)
        own = jnp.any(pos[None, :] == cpos[:, None], axis=0)
        prior = (pos[None, :] < cpos[:, None]) & ~own[None, :]
        causal = (pos[None, :] >= p_max * page) & \
            (pos[None, :] - p_max * page <= jnp.arange(c)[:, None])
        s = jnp.where((prior | causal)[:, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("cht,tl->chl", p, c_kv.astype(jnp.float32))

    return jax.vmap(one)(q_lat, q_rope, lat_chunk, block_tables,
                         chunk_positions).astype(q_lat.dtype)


def flash_prefill_ref(q, k, v) -> jax.Array:
    """Causal attention oracle. q [B,S,Hq,hd], k/v [B,S,Hkv,hd]."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, hd).astype(jnp.float32)
    sc = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    sc = sc / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask[None, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, s, hq, hd).astype(q.dtype)


def paged_decode_attention_int8_ref(q, k_pages, v_pages, k_scales,
                                    v_scales, block_tables, lengths):
    """Dequantize-then-attend oracle for the int8 paged kernel."""
    k = k_pages.astype(jnp.float32) * k_scales.astype(jnp.float32)
    v = v_pages.astype(jnp.float32) * v_scales.astype(jnp.float32)
    return paged_decode_attention_ref(q, k.astype(q.dtype),
                                      v.astype(q.dtype),
                                      block_tables, lengths)
