# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# Layout: ops.py is the only entry point callers should use — it
# dispatches each op to a backend resolved by backend.py ("pallas"
# compiled TPU kernels, "xla" compiled jnp fallbacks in
# xla_fallback.py, "interpret" Pallas-interpreter debugging);
# ref.py holds the pure-jnp oracles that every backend is tested
# against.
