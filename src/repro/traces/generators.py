"""Synthetic workload trace generators (paper §V-A Workloads).

Each generator yields a stream of ``BlockAccess`` events — the cache-block
level abstraction the paper's trace replay operates on.  Distributional
targets follow the paper's descriptions:

  * ShareGPT-like: multi-turn conversations, mean input 500 / output 300
    tokens; a session's *input* history is re-read each turn (variable
    reuse), model outputs are single-use scratch ("intermediate reasoning
    is typically single-use", §III-C).
  * LMSYS-Chat-1M-like: mean prompt 1,200 tokens with high system-prompt
    reuse (a Zipf-dominated pool of prompt templates).
  * Synthetic Agentic: ReAct-style sessions with 5-15 tool invocations;
    tool-context blocks are shared within and across sessions; handoffs
    reset reuse.

Sessions are interleaved turn-by-turn over a concurrency window, so the
gap between one session's consecutive turns carries many other sessions'
traffic: recency != reuse, which is exactly the structure the Bayesian
predictor exploits and reactive LRU cannot (paper Problem 3).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

BLOCK = 128     # tokens per block (GQA block size, core/sizing.py)


@dataclass(frozen=True)
class BlockAccess:
    content_id: Tuple[int, ...]       # token-block surrogate (hashable)
    block_type: str
    transition: str
    session: str
    tool: Optional[str] = None
    new_session: bool = False


def _blocks(rng, kind: str, ident: int, n_tokens: int) -> List[Tuple]:
    """Content ids for n_tokens worth of blocks; identical (kind, ident)
    yields identical content (dedup / reuse target)."""
    n = max(1, int(round(n_tokens / BLOCK)))
    return [(hash((kind, ident, i)) & 0x7FFFFFFF,) for i in range(n)]


@dataclass
class TraceConfig:
    n_sessions: int = 200
    seed: int = 0
    concurrency: int = 32            # interleaved active sessions


Turn = List[BlockAccess]


def _sharegpt_session(rng, s: int) -> List[Turn]:
    sid = f"sg{s}"
    n_turns = int(rng.integers(2, 9))
    sys_id = int(rng.integers(0, 48))            # 48-prompt pool
    sys_blocks = _blocks(rng, "sys", sys_id, 300)
    history: List[Tuple] = []
    turns: List[Turn] = []
    for t in range(n_turns):
        ev: Turn = []
        first = (t == 0)
        for b in sys_blocks:
            ev.append(BlockAccess(b, "system_prompt", "reasoning_step",
                                  sid, new_session=first))
            first = False
        for b in history:                        # inputs only
            ev.append(BlockAccess(b, "user_context", "reasoning_step", sid))
        inp = _blocks(rng, "u", int(rng.integers(0, 1 << 30)),
                      max(64, int(rng.normal(500, 150))))
        out = _blocks(rng, "r", int(rng.integers(0, 1 << 30)),
                      max(64, int(rng.normal(300, 100))))
        for b in inp:
            ev.append(BlockAccess(b, "user_context", "reasoning_step", sid))
        for b in out:                            # single-use scratch
            ev.append(BlockAccess(b, "intermediate_reasoning",
                                  "reasoning_step", sid))
        history.extend(inp)
        history = history[-12:]
        turns.append(ev)
    return turns


def _lmsys_session(rng, s: int) -> List[Turn]:
    sid = f"lm{s}"
    n_turns = int(rng.integers(1, 6))
    tpl = min(11, int(rng.zipf(1.5)) - 1)        # 12 templates, zipf-heavy
    tpl_blocks = _blocks(rng, "tpl", tpl, 900)
    history: List[Tuple] = []
    turns: List[Turn] = []
    for t in range(n_turns):
        ev: Turn = []
        first = (t == 0)
        for b in tpl_blocks:
            ev.append(BlockAccess(b, "system_prompt", "same_tool_repeat",
                                  sid, new_session=first))
            first = False
        for b in history:
            ev.append(BlockAccess(b, "user_context", "reasoning_step", sid))
        inp = _blocks(rng, "u", int(rng.integers(0, 1 << 30)),
                      max(64, int(rng.normal(450, 150))))
        out = _blocks(rng, "r", int(rng.integers(0, 1 << 30)),
                      max(64, int(rng.normal(500, 150))))
        for b in inp:
            ev.append(BlockAccess(b, "user_context", "reasoning_step", sid))
        for b in out:
            ev.append(BlockAccess(b, "intermediate_reasoning",
                                  "reasoning_step", sid))
        history.extend(inp)
        history = history[-8:]
        turns.append(ev)
    return turns


TOOLS = [f"tool{i}" for i in range(32)]
_TOOL_CTX_CACHE: dict = {}


def _tool_ctx(rng, i: int) -> List[Tuple]:
    if i not in _TOOL_CTX_CACHE:
        _TOOL_CTX_CACHE[i] = _blocks(rng, "tool", i, 1100)
    return _TOOL_CTX_CACHE[i]


def _agentic_session(rng, s: int) -> List[Turn]:
    sid = f"ag{s}"
    n_calls = int(rng.integers(5, 16))
    sys_blocks = _blocks(rng, "agent_sys", int(rng.integers(0, 16)), 400)
    prev_tool: Optional[str] = None
    palette = rng.choice(len(TOOLS), size=3, replace=False)
    turns: List[Turn] = []
    first = True
    for c in range(n_calls):
        ev: Turn = []
        if prev_tool is not None and rng.random() < 0.55:
            tool = prev_tool
        elif rng.random() < 0.1:
            tool = TOOLS[int(rng.integers(0, len(TOOLS)))]
        else:
            tool = TOOLS[int(rng.choice(palette))]
        if prev_tool is None:
            trans = "reasoning_step"
        elif tool == prev_tool:
            trans = "same_tool_repeat"
        elif rng.random() < 0.1:
            trans = "agent_handoff"
        else:
            trans = "tool_switch"
        for b in sys_blocks:
            ev.append(BlockAccess(b, "system_prompt", trans, sid,
                                  tool=tool, new_session=first))
            first = False
        for b in _tool_ctx(rng, TOOLS.index(tool)):
            ev.append(BlockAccess(b, "tool_context", trans, sid, tool=tool))
        think = _blocks(rng, "think", int(rng.integers(0, 1 << 30)),
                        max(64, int(rng.normal(800, 250))))
        for b in think:
            ev.append(BlockAccess(b, "intermediate_reasoning", trans, sid,
                                  tool=tool))
        prev_tool = tool
        turns.append(ev)
    return turns


def _interleave_turns(sessions: List[List[Turn]],
                      cfg: TraceConfig) -> List[BlockAccess]:
    """One scheduling quantum = one full turn; a session's next turn
    arrives after ~concurrency other turns of traffic."""
    rng = np.random.default_rng(cfg.seed + 99)
    out: List[BlockAccess] = []
    pending = list(sessions)
    rng.shuffle(pending)
    live: List[List[Turn]] = []
    while pending or live:
        while pending and len(live) < cfg.concurrency:
            live.append(pending.pop())
        i = int(rng.integers(0, len(live)))
        out.extend(live[i].pop(0))
        if not live[i]:
            live.pop(i)
    return out


def _make(gen_session, cfg: TraceConfig, salt: int) -> List[BlockAccess]:
    rng = np.random.default_rng(cfg.seed + salt)
    sessions = [gen_session(rng, s) for s in range(cfg.n_sessions)]
    return _interleave_turns(sessions, cfg)


SESSION_GENERATORS = {"sharegpt": (_sharegpt_session, 0),
                      "lmsys": (_lmsys_session, 1),
                      "agentic": (_agentic_session, 2)}


def workload_sessions(workload: str, cfg: TraceConfig) -> List[List[Turn]]:
    """Session-level view of a workload: each session is a list of turns,
    each turn a list of ``BlockAccess`` events in submission order.

    The block-level traces (``sharegpt_trace`` & co.) interleave these
    same sessions turn-by-turn; the serving replay adapter
    (``traces/serving_replay.py``) instead drives each session's turns
    through the live ``ServingEngine`` as multi-turn requests.  Salts
    match ``_make``, so session content is identical to the flat trace
    under the same ``TraceConfig``.

    ``file:<path>`` workloads load a real ShareGPT/LMSYS JSON dump
    (``traces/ingest.py``) instead of a synthetic generator; the first
    ``cfg.n_sessions`` conversations replay block-for-block.
    """
    if workload.startswith("file:"):
        from repro.traces.ingest import file_sessions
        return file_sessions(workload[len("file:"):], cfg.n_sessions)
    gen, salt = SESSION_GENERATORS[workload]
    if workload == "agentic":
        _TOOL_CTX_CACHE.clear()
    rng = np.random.default_rng(cfg.seed + salt)
    return [gen(rng, s) for s in range(cfg.n_sessions)]


def sharegpt_trace(cfg: TraceConfig) -> List[BlockAccess]:
    return _make(_sharegpt_session, cfg, 0)


def lmsys_trace(cfg: TraceConfig) -> List[BlockAccess]:
    return _make(_lmsys_session, cfg, 1)


def agentic_trace(cfg: TraceConfig) -> List[BlockAccess]:
    _TOOL_CTX_CACHE.clear()
    return _make(_agentic_session, cfg, 2)


GENERATORS = {"sharegpt": sharegpt_trace, "lmsys": lmsys_trace,
              "agentic": agentic_trace}
