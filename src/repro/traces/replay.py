"""Trace replay evaluation (paper §V-E, Table V).

Feeds a block-access trace through the PredictiveCacheManager: every
access first registers the block (dedup makes repeat content a single
block), then performs the tiered lookup.  Hit rate is measured at tiers
0+1 ("GPU + CPU DRAM"), exactly the paper's Table V definition.

Capacity pressure: replay tier specs shrink tier 0/1 so the hot set
cannot hold the whole working set — this is where LRU / EMA / Bayesian
policies separate.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.config import ModelConfig
from repro.configs.paper_models import LLAMA3_70B
from repro.core import sizing
from repro.core.cache_manager import PredictiveCacheManager
from repro.core.tiers import GB, PAPER_TIER_SPECS, TierSpec
from repro.traces.generators import (GENERATORS, BlockAccess, TraceConfig)


def replay_tier_specs(cfg: ModelConfig, *, hot_blocks: int = 600,
                      t1_blocks: int = 900) -> tuple:
    """Scaled-down tier capacities (block counts) for replay pressure."""
    bb = sizing.block_bytes(cfg)
    base = PAPER_TIER_SPECS
    return (
        TierSpec(0, base[0].name, base[0].bandwidth, base[0].latency,
                 base[0].cost_per_gb_hour, hot_blocks * bb),
        TierSpec(1, base[1].name, base[1].bandwidth, base[1].latency,
                 base[1].cost_per_gb_hour, t1_blocks * bb),
        base[2], base[3], base[4], base[5],
    )


@dataclass
class ReplayResult:
    workload: str
    policy: str
    hit_rate: float
    accesses: int
    dedup_hits: int
    fetch_time: float
    recompute_time: float
    promotions: int
    demotions: int
    wall_s: float
    predictor_snapshot: Optional[dict] = None


def replay(trace: Sequence[BlockAccess], cfg: ModelConfig, *,
           policy: str = "bayesian", hot_blocks: int = 600,
           t1_blocks: Optional[int] = None,
           enable_multi_tier: bool = True,
           enable_dedup: bool = True,
           enable_prefetch: bool = True,
           enable_head_eviction: bool = True,
           workload: str = "?",
           predictor_kwargs: Optional[dict] = None,
           policy_kwargs: Optional[dict] = None) -> ReplayResult:
    mgr = PredictiveCacheManager(
        cfg, specs=replay_tier_specs(
            cfg, hot_blocks=hot_blocks,
            t1_blocks=t1_blocks if t1_blocks is not None else hot_blocks),
        policy=policy, enable_dedup=enable_dedup,
        enable_prefetch=enable_prefetch,
        enable_head_eviction=enable_head_eviction,
        enable_multi_tier=enable_multi_tier)
    if predictor_kwargs:
        from repro.core.bayesian import BayesianReusePredictor
        mgr.predictor = BayesianReusePredictor(**predictor_kwargs)
    if policy_kwargs:
        from repro.core.eviction import BayesianPolicy
        mgr.evictor = BayesianPolicy(mgr.head_tracker, **policy_kwargs)
    seen: Dict = {}
    t0 = time.time()
    prev_session_tool: Dict[str, str] = {}
    for i, ev in enumerate(trace):
        if ev.tool is not None:
            prev = prev_session_tool.get(ev.session)
            if prev != ev.tool:
                mgr.on_tool_switch(prev, ev.tool)
                prev_session_tool[ev.session] = ev.tool
        bid = seen.get(ev.content_id)
        if bid is None or bid not in mgr.metas:
            bid, _ = mgr.register_block(
                ev.content_id, block_type=ev.block_type,
                recompute_cost=0.02)
            seen[ev.content_id] = bid
            # first-touch registration is not a lookup: skip access
            mgr.tick(0.1)
            continue
        mgr.access(bid, transition=ev.transition)
        mgr.tick(0.1)
        if i % 512 == 0:
            mgr.age_all()
    st = mgr.stats
    return ReplayResult(
        workload=workload, policy=policy, hit_rate=st.hit_rate,
        accesses=st.accesses, dedup_hits=st.dedup_hits,
        fetch_time=st.fetch_time, recompute_time=st.recompute_time,
        promotions=st.promotions, demotions=st.demotions,
        wall_s=time.time() - t0,
        predictor_snapshot=mgr.predictor.snapshot())


# Per-workload replay capacity (tier-0 = tier-1 blocks): chosen so the
# reusable core exceeds the hot set (capacity pressure) — see DESIGN.md
# §Trace-calibration.  The paper does not publish its replay cache size.
REPLAY_HOT_BLOCKS = {"sharegpt": 150, "lmsys": 100, "agentic": 120}


def run_table_v(cfg: ModelConfig = LLAMA3_70B, *, n_sessions: int = 100,
                seeds: Sequence[int] = (0, 1, 2, 3, 4),
                policies: Sequence[str] = ("lru", "ema", "bayesian")
                ) -> List[dict]:
    """Paper Table V: {workloads} x {lru, ema, bayesian}, n-seed mean+std."""
    import numpy as np
    rows = []
    for wl, gen in GENERATORS.items():
        for policy in policies:
            rates = []
            for seed in seeds:
                trace = gen(TraceConfig(n_sessions=n_sessions, seed=seed))
                r = replay(trace, cfg, policy=policy, workload=wl,
                           hot_blocks=REPLAY_HOT_BLOCKS[wl])
                rates.append(r.hit_rate)
            rows.append({"workload": wl, "policy": policy,
                         "hit_mean": float(np.mean(rates)),
                         "hit_std": float(np.std(rates)),
                         "n_accesses": r.accesses})
    return rows
