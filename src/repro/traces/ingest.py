"""Load real ShareGPT / LMSYS JSON dumps into the trace session
structure (``List[List[Turn]]``, ``Turn = List[BlockAccess]``) so
published conversation dumps replay through ``traces/serving_replay.py``
and the block-level simulators unmodified.

Formats (auto-detected per record):

* **ShareGPT** — ``[{"id": ..., "conversations": [{"from":
  "human"|"gpt"|"system", "value": str}, ...]}, ...]``
* **LMSYS** (lmsys-chat-1m style) — ``[{"conversation_id": ...,
  "conversation": [{"role": "user"|"assistant"|"system", "content":
  str}, ...]}, ...]``

Both ``.json`` (one array) and ``.jsonl`` (one record per line) files
load.  There is no tokenizer in this repo, so text is block-aligned by
a word-count token estimate (~4/3 tokens per whitespace word) and each
``BLOCK``-token chunk becomes one content id — a stable digest of the
chunk text, so identical text (a system prompt shared across sessions,
an unchanged conversation prefix) maps to identical content ids and is
visible to dedup, the radix prefix index and the fleet-shared tier
exactly like the synthetic generators' content.

Turn shape mirrors ``generators._sharegpt_session``: every turn re-reads
the system prompt and the truncated input history (inputs only, last
``history_blocks`` blocks), then the new user input, then the
assistant reply as single-use ``intermediate_reasoning`` output blocks.

The ``workload_sessions`` interface dispatches here for workloads named
``file:<path>`` — e.g. ``ServingReplayConfig(workload=
"file:/data/sharegpt.json")`` replays a real dump through the live
engine with no other change.
"""
from __future__ import annotations

import json
import zlib
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.traces.generators import BLOCK, BlockAccess, Turn

# token estimate per whitespace word (the usual ~0.75 words/token)
_TOKENS_PER_WORD = 4.0 / 3.0


def _estimate_tokens(text: str) -> int:
    return max(1, int(round(len(text.split()) * _TOKENS_PER_WORD)))


def text_blocks(text: str, block_tokens: int = BLOCK) -> List[Tuple[int, ...]]:
    """Block-align ``text``: split into ``block_tokens``-sized chunks on
    word boundaries; each chunk's content id is a stable digest of the
    chunk text (identical text -> identical ids, across processes).

    Sizing is pure integer arithmetic (``block_tokens * 3 // 4`` words
    per block — the inverse of the 4/3 tokens-per-word estimate), so
    chunk boundaries can never drift with float rounding.  Explicit
    tail rule: a trailing fragment estimated under half a block merges
    into the previous chunk instead of minting its own content id —
    the replay materializes every id at full block size, so a
    nearly-empty tail block both inflated reuse accounting and gave
    re-ingested text a digest that depended on where the mis-sized
    tail happened to fall."""
    words = text.split()
    if not words:
        return []
    words_per_block = max(1, (block_tokens * 3) // 4)
    chunks = [words[i:i + words_per_block]
              for i in range(0, len(words), words_per_block)]
    if len(chunks) > 1 and _estimate_tokens(
            " ".join(chunks[-1])) < block_tokens // 2:
        chunks[-2].extend(chunks.pop())
    out: List[Tuple[int, ...]] = []
    for chunk in chunks:
        s = " ".join(chunk)
        out.append((zlib.crc32(s.encode("utf-8")) & 0x7FFFFFFF,))
    return out


# ---------------------------------------------------------------------------
# record parsing
# ---------------------------------------------------------------------------
def _messages(record: dict) -> Optional[List[Tuple[str, str]]]:
    """Normalize one dump record to [(role, text), ...] with roles in
    {"system", "user", "assistant"}; None if the record is neither
    format."""
    if "conversations" in record:          # ShareGPT
        roles = {"human": "user", "user": "user", "gpt": "assistant",
                 "chatgpt": "assistant", "bing": "assistant",
                 "bard": "assistant", "assistant": "assistant",
                 "system": "system"}
        out = []
        for m in record["conversations"]:
            role = roles.get(str(m.get("from", "")).lower())
            if role and m.get("value"):
                out.append((role, str(m["value"])))
        return out
    if "conversation" in record:           # LMSYS
        out = []
        for m in record["conversation"]:
            role = str(m.get("role", "")).lower()
            if role in ("system", "user", "assistant") and m.get("content"):
                out.append((role, str(m["content"])))
        return out
    return None


def _session_id(record: dict, index: int) -> str:
    for key in ("id", "conversation_id", "session_id"):
        if key in record:
            return f"ing-{record[key]}"
    return f"ing-{index}"


def _session_turns(messages: List[Tuple[str, str]], sid: str, *,
                   block_tokens: int, history_blocks: int,
                   max_turns: Optional[int]) -> List[Turn]:
    """Pair user->assistant exchanges into turns with the generator's
    event shape (system + history + input + output per turn)."""
    sys_blocks: List[Tuple[int, ...]] = []
    exchanges: List[Tuple[List, List]] = []   # (input blocks, output blocks)
    pending_user: List[str] = []
    for role, text in messages:
        if role == "system" and not exchanges and not pending_user:
            sys_blocks.extend(text_blocks(text, block_tokens))
        elif role == "user":
            pending_user.append(text)
        elif role == "assistant" and pending_user:
            inp = text_blocks(" ".join(pending_user), block_tokens)
            out = text_blocks(text, block_tokens)
            exchanges.append((inp, out))
            pending_user = []
    turns: List[Turn] = []
    history: List[Tuple[int, ...]] = []
    first = True
    for inp, out in exchanges[:max_turns]:
        ev: Turn = []
        for b in sys_blocks:
            ev.append(BlockAccess(b, "system_prompt", "reasoning_step",
                                  sid, new_session=first))
            first = False
        for b in history:                       # inputs only, truncated
            ev.append(BlockAccess(b, "user_context", "reasoning_step",
                                  sid, new_session=first))
            first = False
        for b in inp:
            ev.append(BlockAccess(b, "user_context", "reasoning_step",
                                  sid, new_session=first))
            first = False
        for b in out:                           # single-use scratch
            ev.append(BlockAccess(b, "intermediate_reasoning",
                                  "reasoning_step", sid,
                                  new_session=first))
            first = False
        history.extend(inp)
        history = history[-history_blocks:]
        turns.append(ev)
    return turns


def _iter_records(path: Path):
    text = path.read_text(encoding="utf-8")
    if path.suffix == ".jsonl":
        for line in text.splitlines():
            line = line.strip()
            if line:
                yield json.loads(line)
        return
    data = json.loads(text)
    if isinstance(data, dict):                 # single-record dump
        data = [data]
    yield from data


def load_sessions(path, *, block_tokens: int = BLOCK,
                  max_sessions: Optional[int] = None,
                  max_turns: Optional[int] = None,
                  history_blocks: int = 12) -> List[List[Turn]]:
    """Load a ShareGPT/LMSYS dump into session/turn structure.

    ``history_blocks`` caps the re-read input history per turn (matches
    the synthetic ShareGPT generator's truncation — the divergence that
    caps radix prefix reuse on this workload is a property of the data
    pipeline, so real dumps reproduce it too)."""
    path = Path(path)
    sessions: List[List[Turn]] = []
    for i, record in enumerate(_iter_records(path)):
        if max_sessions is not None and len(sessions) >= max_sessions:
            break
        if not isinstance(record, dict):
            continue
        msgs = _messages(record)
        if not msgs:
            continue
        turns = _session_turns(msgs, _session_id(record, i),
                               block_tokens=block_tokens,
                               history_blocks=history_blocks,
                               max_turns=max_turns)
        if turns:
            sessions.append(turns)
    if not sessions:
        raise ValueError(f"{path}: no ShareGPT/LMSYS conversations found")
    return sessions


# cache keyed by (resolved path, mtime): replay sweeps re-enter
# workload_sessions once per cell, and real dumps are large
_CACHE: Dict[Tuple[str, float, int], List[List[Turn]]] = {}


def file_sessions(spec: str, n_sessions: int) -> List[List[Turn]]:
    """``workload_sessions`` entry point for ``file:<path>`` workloads:
    the first ``n_sessions`` conversations of the dump."""
    path = Path(spec)
    key = (str(path.resolve()), path.stat().st_mtime, 0)
    if key not in _CACHE:
        _CACHE[key] = load_sessions(path)
    return _CACHE[key][:n_sessions]
