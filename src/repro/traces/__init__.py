from repro.traces.generators import (GENERATORS, TraceConfig, BlockAccess,
                                     sharegpt_trace, lmsys_trace,
                                     agentic_trace, workload_sessions)
from repro.traces.replay import replay, run_table_v, ReplayResult

_SERVING_REPLAY = ("ServingReplayConfig", "ServingReplayResult",
                   "run_serving_replay", "run_replay_serving_table")


def __getattr__(name):
    # lazy: serving_replay pulls in jax + the full model/serving stack,
    # which the lightweight block-level trace consumers don't need
    if name in _SERVING_REPLAY:
        from repro.traces import serving_replay
        return getattr(serving_replay, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
