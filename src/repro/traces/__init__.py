from repro.traces.generators import (GENERATORS, TraceConfig, BlockAccess,
                                     sharegpt_trace, lmsys_trace,
                                     agentic_trace)
from repro.traces.replay import replay, run_table_v, ReplayResult
