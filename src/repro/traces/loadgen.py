"""Open-loop Poisson load generation for the wall-clock serving
front-end (``serving/frontend.py``).

The trace replay (``serving_replay.py``) drives a *virtual* clock:
arrivals are a fixed interarrival ramp and a session's next turn waits
for the previous turn's completion.  A real latency-vs-QPS curve needs
**open-loop** load — arrivals keep coming at the offered rate whether or
not the server keeps up, so queueing delay (and the SLO admission
controller's response to it) is visible in the TTFT tail.  This module
produces that load as a *deterministic schedule*:

  * request bodies are drawn from the existing session generators
    (``generators.workload_sessions``: sharegpt / lmsys / agentic /
    ``file:<path>`` real-trace ingestion) through the same turn-spec
    materialization the replay uses, so the front-end sees the same
    prefix-reuse structure the virtual-clock replay validated;
  * arrival *times* are a Poisson process at ``rate_qps`` drawn from a
    seeded, injectable RNG — the whole schedule is a pure function of
    ``(workload, rate, seed)``, so every load test is reproducible and
    the property tests (``tests/test_loadgen.py``) can assert on the
    process statistics without timing races;
  * a session's turns stay in order in the schedule (turn k+1 is
    assigned a later arrival than turn k) but do **not** wait for
    completion — open loop, by construction.

The schedule is plain data (``List[Arrival]``); the front-end's
``serve_schedule`` replays it against a real or virtual clock.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class Arrival:
    """One scheduled request: submit ``prompt`` at ``t`` seconds after
    the load run starts (timestamps are monotone across the schedule)."""
    t: float
    session_id: str
    turn: int                      # turn index within the session
    prompt: Tuple[int, ...]
    block_types: Tuple[str, ...]
    tool: Optional[str]
    max_new: int
    last_turn: bool                # False -> submit with retain_blocks


class PoissonLoadGen:
    """Seeded open-loop Poisson arrival-time generator.

    ``rng`` is injectable so tests can substitute any ``Generator``;
    by default a fresh ``np.random.default_rng(seed)`` makes the
    process a pure function of ``(rate_qps, seed)``.
    """

    def __init__(self, rate_qps: float, *, seed: int = 0, rng=None):
        if rate_qps <= 0:
            raise ValueError(f"rate_qps must be > 0, got {rate_qps}")
        self.rate_qps = float(rate_qps)
        self.rng = np.random.default_rng(seed) if rng is None else rng

    def interarrivals(self, n: int) -> np.ndarray:
        """n exponential gaps with mean 1/rate (seconds)."""
        return self.rng.exponential(1.0 / self.rate_qps, size=n)

    def arrival_times(self, *, n: Optional[int] = None,
                      duration_s: Optional[float] = None) -> List[float]:
        """Monotone non-decreasing arrival timestamps: either exactly
        ``n`` arrivals, or every arrival landing before ``duration_s``."""
        if (n is None) == (duration_s is None):
            raise ValueError("pass exactly one of n / duration_s")
        if n is not None:
            return list(np.cumsum(self.interarrivals(n)))
        out: List[float] = []
        t = 0.0
        while True:
            t += float(self.rng.exponential(1.0 / self.rate_qps))
            if t >= duration_s:
                return out
            out.append(t)


def _turn_bodies(workload: str, *, n_sessions: int, max_turns: int,
                 block_tokens: int, max_new_cap: int, seed: int
                 ) -> List[list]:
    """Session turn specs through the replay's materialization (one
    trace block -> one ``block_tokens``-token engine block), so the
    front-end load carries the same reuse structure the replay
    validated.  Imported lazily — the schedule shape (timing) never
    depends on it."""
    from repro.core import sizing
    from repro.traces.generators import TraceConfig, workload_sessions
    from repro.traces.serving_replay import _turn_spec, replay_model_config
    cfg = replay_model_config(block_tokens)
    bt = sizing.block_tokens(cfg)
    sessions = workload_sessions(
        workload, TraceConfig(n_sessions=n_sessions, seed=seed))
    cache: Dict[Tuple, List[int]] = {}
    return [[_turn_spec(t, bt, cfg.vocab_size, max_new_cap, cache)
             for t in sess[:max_turns]] for sess in sessions]


def trace_load(workload: str, rate_qps: float, *,
               duration_s: Optional[float] = None,
               n_requests: Optional[int] = None,
               seed: int = 0, n_sessions: int = 16, max_turns: int = 4,
               block_tokens: int = 16, max_new_cap: int = 4,
               concurrency: int = 8) -> List[Arrival]:
    """An open-loop request schedule: session turns drawn from the
    ``workload`` generator (or ``file:<path>`` ingestion), interleaved
    over a ``concurrency`` window (so consecutive arrivals mix
    sessions, like real traffic), with Poisson arrival times at
    ``rate_qps``.

    Deterministic: the same ``(workload, rate_qps, seed, ...)`` yields a
    byte-identical schedule.  Timestamps are strictly ordered per
    session (turn k+1 after turn k) and monotone overall; the stream
    cycles through the session pool if ``duration_s``/``n_requests``
    demands more turns than the pool holds.
    """
    specs = _turn_bodies(workload, n_sessions=n_sessions,
                         max_turns=max_turns, block_tokens=block_tokens,
                         max_new_cap=max_new_cap, seed=seed)
    if not any(specs):
        raise ValueError(f"workload {workload!r} produced no turns")
    gen = PoissonLoadGen(rate_qps, seed=seed + 1)
    times = gen.arrival_times(n=n_requests, duration_s=duration_s)

    # deterministic session interleave (mirrors the trace generators'
    # turn-quantum interleaving): keep up to `concurrency` sessions
    # live, draw the next turn from a seeded-random live session
    rng = np.random.default_rng(seed + 2)
    out: List[Arrival] = []
    pending: List[Tuple[int, List]] = []
    live: List[List] = []
    epoch = 0
    for k, t in enumerate(times):
        if not pending and not live:
            # (re)fill from the session pool; later epochs get fresh
            # session ids so a cycled schedule doesn't alias sessions
            pending = [(i, list(s)) for i, s in enumerate(specs) if s]
            rng.shuffle(pending)
            epoch += 1
        while pending and len(live) < concurrency:
            idx, turns = pending.pop()
            sid = turns[0].session_id
            if epoch > 1:
                sid = f"{sid}.e{epoch}"
            live.append([sid, 0, turns])
        j = int(rng.integers(0, len(live)))
        sid, turn_i, turns = live[j]
        spec = turns[turn_i]
        last = turn_i + 1 >= len(turns)
        out.append(Arrival(
            t=float(t), session_id=sid, turn=turn_i,
            prompt=tuple(spec.prompt),
            block_types=tuple(spec.block_types),
            tool=spec.tool, max_new=spec.max_new, last_turn=last))
        if last:
            live.pop(j)
        else:
            live[j][1] = turn_i + 1
    return out


def offered_summary(arrivals: List[Arrival]) -> dict:
    """Schedule-level accounting (the load side of the goodput
    ledger): request count, span, realized offered rate, prompt-token
    volume."""
    if not arrivals:
        return {"requests": 0, "span_s": 0.0, "offered_qps": 0.0,
                "prompt_tokens": 0, "sessions": 0}
    span = arrivals[-1].t - arrivals[0].t
    return {
        "requests": len(arrivals),
        "span_s": span,
        "offered_qps": len(arrivals) / span if span > 0 else float("inf"),
        "prompt_tokens": sum(len(a.prompt) for a in arrivals),
        "sessions": len({a.session_id for a in arrivals}),
    }
