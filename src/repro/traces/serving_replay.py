"""Trace→engine serving replay: the paper's §V-E evaluation driven
through the real ``ServingEngine`` instead of the standalone cache
manager.

``traces/replay.py`` replays block-access traces against the
``PredictiveCacheManager`` alone — scheduling, paged-pool CoW sharing,
chunked prefill and tier-transfer latency never enter the picture.  This
adapter closes that gap: it converts the same ShareGPT / LMSYS / agentic
session generators (``traces/generators.py``) into **timed multi-turn
request streams** and drives them open-loop against a live engine under
a virtual clock:

  * each turn submits the **full conversation prefix** (system prompt +
    input history + new input), so cross-turn and cross-session reuse
    flows through the real radix-match → CoW-page-share / tier-payload
    injection path instead of a metadata lookup;
  * one trace block maps to exactly one engine KV block
    (``ModelConfig.kv_block_tokens`` shrinks the engine block so reduced
    models see trace-scale reuse granularity), keeping the engine's
    hit accounting block-for-block comparable with Table V;
  * sessions arrive open-loop at a fixed virtual interarrival; within a
    session the next turn submits after the previous turn's completion
    plus a think-time gap (closed-loop per conversation, like a real
    chat client);
  * the virtual clock advances per engine step by a modelled step time:
    a fixed overhead, a per-token compute cost, and the manager's
    modelled tier-fetch / recompute stall for that step — so hit-rate
    differences between policies surface in TTFT/TBT, which is exactly
    the serving-layer interaction KVDrive (arXiv 2605.18071) argues
    block-level replay cannot capture.

Tier capacities reuse ``traces/replay.py::replay_tier_specs`` (scaled-
down tiers 0/1 so the reusable working set exceeds the hot set) with
``EngineConfig(tier0_from_budget=False)`` so the pressure capacities
stand.

Hit-rate definition (Table V analogue, measured at the engine):
``engine_hit_rate = hot-hit prompt blocks / previously-seen prompt
blocks``.  The denominator is trace ground truth — a prompt block whose
content appeared in an earlier-submitted turn (first touch excluded,
exactly like ``replay.py``).  The numerator is the engine's own
accounting (``Request.hot_hit_blocks``): blocks actually served from
tiers 0-1.  Content that is resident but unreachable because the radix
prefix diverged (e.g. history truncation) therefore counts as a miss —
at the serving layer that compute is really paid, which is the point of
evaluating end-to-end.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config import ModelConfig, reduce_config
from repro.core import sizing
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Phase, Request, SamplingParams
from repro.traces.generators import TraceConfig, Turn, workload_sessions
from repro.traces.replay import replay_tier_specs


def replay_model_config(block_tokens: int = 32) -> ModelConfig:
    """Reduced llama3.2-1b with trace-scale KV blocks: one trace block
    (nominally 128 tokens) maps to one ``block_tokens``-token engine
    block, so a full multi-turn prompt stays CPU-sized while the reuse
    structure is preserved block-for-block."""
    from repro.configs import get_config
    cfg = reduce_config(get_config("llama3.2-1b"))
    return dataclasses.replace(cfg, name=cfg.name + "-replay",
                               kv_block_tokens=block_tokens)


# Per-workload tier-0/tier-1 pressure (block counts) for the live-engine
# replay: chosen so the reusable working set (sharegpt 244 / lmsys 110 /
# agentic 168 distinct blocks at 12 sessions, plus the turns' single-use
# output blocks) exceeds the hot set and the eviction policy has
# decisions to make — cf. REPLAY_HOT_BLOCKS for the block-level replay.
ENGINE_REPLAY_BLOCKS: Dict[str, Tuple[int, int]] = {
    "sharegpt": (48, 72),
    "lmsys": (32, 48),
    "agentic": (64, 96),
}


@dataclass
class ServingReplayConfig:
    workload: str = "agentic"
    policy: str = "bayesian"            # lru | ema | bayesian
    n_sessions: int = 12
    seed: int = 0
    max_turns: int = 6                  # cap turns per session (CPU budget)
    max_new_cap: int = 4                # cap decode tokens per turn
    block_tokens: int = 32              # engine tokens per trace block
    page_tokens: int = 32
    prefill_chunk_tokens: int = 64
    max_step_tokens: int = 160
    n_slots: int = 8                    # target decode concurrency
    hot_blocks: Optional[int] = None    # tier-0 capacity (None: per-workload)
    t1_blocks: Optional[int] = None     # tier-1 capacity (None: per-workload)
    async_transfers: bool = True        # real async worker path; False runs
    #                                     transfers inline — bit-for-bit
    #                                     deterministic (thread completion
    #                                     timing is polled per step, so a
    #                                     prefetch promotion may land a step
    #                                     earlier or later between runs)
    # --- virtual clock model ---------------------------------------------
    session_interarrival_s: float = 0.005
    think_time_s: float = 0.02
    step_overhead_s: float = 1.5e-3
    per_token_s: float = 4e-5
    stall_weight: float = 1.0           # modelled fetch/recompute stall
    fetch_stall_s: float = 1e-3         # per lower-tier promotion: at paper
    #                                     scale a block is MBs (not the
    #                                     reduced model's KBs), so a CXL/
    #                                     NVMe fetch costs ~1 ms — the
    #                                     reduced transfer_time under-
    #                                     states it by the size ratio
    max_steps: int = 50_000


@dataclass
class ServingReplayResult:
    workload: str
    policy: str
    engine_hit_rate: float         # hot (tier 0-1) hits / seen blocks
    reuse_rate: float              # any-tier cache-served / seen blocks
    seen_blocks: int
    manager_hit_rate: float        # PredictiveCacheManager hot-hit rate
    manager_replay_hit_rate: float
    hot_hits_t0: int               # pool (CoW-shareable) hits
    hot_hits_t1: int               # DRAM-resident hits
    cow_share_hits: int            # engine: blocks served by CoW page map
    inject_hits: int               # engine: blocks served by payload inject
    promotions: int
    demotions: int
    requests_done: int
    sessions: int
    generated_tokens: int
    ttft_p50: float                # virtual seconds
    ttft_p95: float
    tbt_p50: float
    tbt_p95: float
    throughput_tok_s: float        # generated tokens / virtual time
    virtual_time_s: float
    steps: int
    wall_s: float


@dataclass
class _TurnSpec:
    session_id: str
    prompt: List[int]
    block_types: List[str]
    acct_cids: List[Tuple]         # accountable content ids (full blocks)
    tool: Optional[str]
    max_new: int


@dataclass
class _Tracked:
    req: Request
    session: int
    submit_v: float
    seen_blocks: int
    token_times: List[float] = field(default_factory=list)
    done_v: Optional[float] = None


def _materialize(cid: Tuple, bt: int, vocab: int,
                 cache: Dict[Tuple, List[int]]) -> List[int]:
    """Content id -> a deterministic block of ``bt`` tokens.  Identical
    ids yield identical tokens, so the engine's content-hash dedup and
    radix prefix matching see the trace's sharing structure."""
    toks = cache.get(cid)
    if toks is None:
        rng = np.random.default_rng(cid[0])
        toks = [int(t) for t in rng.integers(0, vocab, size=bt)]
        cache[cid] = toks
    return toks


def _turn_spec(turn: Turn, bt: int, vocab: int, max_new_cap: int,
               cache: Dict[Tuple, List[int]]) -> _TurnSpec:
    """One trace turn -> a request spec.

    The prompt is the turn's full conversation prefix (system + history
    + input, in event order) **plus the turn's output blocks** at the
    end: after a real turn, the model's reply occupies KV alongside the
    prompt, and the trace marks those ``intermediate_reasoning`` blocks
    single-use.  Materializing them prompt-side puts the same block
    population in the live pool — single-use scratch that the eviction
    policy must get out of the way of reusable context, which is the
    paper's Problem 3 (recency != reuse; decoding the full reply
    token-by-token on CPU would cost ~bt x more for identical cache
    behaviour).  The next turn's prompt never repeats them, so the radix
    prefix diverges exactly where the trace says it does.  Decode load
    is a capped handful of sampled tokens per turn."""
    prompt: List[int] = []
    btypes: List[str] = []
    cids: List[Tuple] = []
    tool: Optional[str] = None
    out_blocks = 0
    for ev in turn:
        if ev.tool is not None:
            tool = ev.tool
        if ev.block_type == "intermediate_reasoning":
            out_blocks += 1
        prompt.extend(_materialize(ev.content_id, bt, vocab, cache))
        btypes.append(ev.block_type)
        cids.append(ev.content_id)
    # prefill covers prompt[:-1]: the final block stays one token short
    # of full, so it is neither registered nor matchable — exclude it
    # from the hit accounting (it can never be a hit or a miss)
    return _TurnSpec(session_id=turn[0].session, prompt=prompt,
                     block_types=btypes, acct_cids=cids[:-1], tool=tool,
                     max_new=max(1, min(max_new_cap, out_blocks)))


def build_engine(rcfg: ServingReplayConfig, cfg: Optional[ModelConfig] = None,
                 max_len: int = 768) -> ServingEngine:
    cfg = replay_model_config(rcfg.block_tokens) if cfg is None else cfg
    hot, t1 = ENGINE_REPLAY_BLOCKS.get(rcfg.workload, (64, 96))
    hot = rcfg.hot_blocks if rcfg.hot_blocks is not None else hot
    t1 = rcfg.t1_blocks if rcfg.t1_blocks is not None else t1
    specs = replay_tier_specs(cfg, hot_blocks=hot, t1_blocks=t1)
    ecfg = EngineConfig(
        max_len=max_len,
        kv_budget_bytes=rcfg.n_slots * sizing.seq_bytes(cfg, max_len),
        policy=rcfg.policy,
        deadline_s=1e9,                 # virtual time: no wall-clock
        #                                 straggler preemption
        seed=rcfg.seed,
        tier_specs=specs,
        tier0_from_budget=False,        # keep the replay pressure capacity
        async_transfers=rcfg.async_transfers,
        page_tokens=rcfg.page_tokens,
        prefill_chunk_tokens=rcfg.prefill_chunk_tokens,
        max_step_tokens=rcfg.max_step_tokens)
    return ServingEngine(cfg, ecfg)


def _percentile(vals: Sequence[float], p: float) -> float:
    vals = sorted(vals)
    if not vals:
        return 0.0
    return vals[min(len(vals) - 1, int(p * len(vals)))]


def run_serving_replay(rcfg: ServingReplayConfig,
                       turn_log: Optional[List[dict]] = None
                       ) -> ServingReplayResult:
    """Replay one workload x policy through the live engine.

    ``turn_log`` (optional) receives one dict per submitted turn
    (session, turn index, request id, virtual submit time) — the
    determinism / ordering tests assert on it.
    """
    cfg = replay_model_config(rcfg.block_tokens)
    bt = sizing.block_tokens(cfg)
    sessions = workload_sessions(
        rcfg.workload, TraceConfig(n_sessions=rcfg.n_sessions,
                                   seed=rcfg.seed))
    cache: Dict[Tuple, List[int]] = {}
    specs: List[List[_TurnSpec]] = [
        [_turn_spec(t, bt, cfg.vocab_size, rcfg.max_new_cap, cache)
         for t in sess[:rcfg.max_turns]]
        for sess in sessions]
    max_prompt = max(len(t.prompt) for s in specs for t in s)
    max_len = max_prompt + rcfg.max_new_cap + 2
    max_len = -(-max_len // rcfg.page_tokens) * rcfg.page_tokens
    eng = build_engine(rcfg, cfg, max_len=max_len)

    n_sess = len(specs)
    next_turn = [0] * n_sess
    ready_v = [i * rcfg.session_interarrival_s for i in range(n_sess)]
    in_flight: List[Optional[int]] = [None] * n_sess   # request_id
    seen: set = set()
    tracked: Dict[int, _Tracked] = {}
    vt = 0.0
    t_wall = time.time()
    steps = 0

    def pending(i: int) -> bool:
        return next_turn[i] < len(specs[i])

    while any(pending(i) for i in range(n_sess)) \
            or eng.scheduler.has_work():
        # open-loop submission: every session whose next turn is due
        for i in range(n_sess):
            if not pending(i) or in_flight[i] is not None \
                    or ready_v[i] > vt:
                continue
            spec = specs[i][next_turn[i]]
            n_seen = sum(1 for c in spec.acct_cids if c in seen)
            seen.update(spec.acct_cids)
            req = eng.submit(
                spec.prompt,
                params=SamplingParams(max_new_tokens=spec.max_new),
                session_id=spec.session_id,
                block_types=spec.block_types,
                tool=spec.tool,
                retain_blocks=next_turn[i] + 1 < len(specs[i]))
            tracked[req.request_id] = _Tracked(
                req=req, session=i, submit_v=vt, seen_blocks=n_seen)
            in_flight[i] = req.request_id
            if turn_log is not None:
                turn_log.append({"session": spec.session_id,
                                 "turn": next_turn[i],
                                 "request_id": req.request_id,
                                 "submit_v": vt,
                                 "prompt_len": len(spec.prompt)})
            next_turn[i] += 1
        if eng.scheduler.has_work():
            st = eng.manager.stats
            f0, r0, p0 = st.fetch_time, st.recompute_time, st.promotions
            produced = eng.step()
            steps += 1
            step_tokens = eng.last_step_prefill_tokens + produced
            vt += (rcfg.step_overhead_s + rcfg.per_token_s * step_tokens
                   + rcfg.fetch_stall_s * (st.promotions - p0)
                   + rcfg.stall_weight * ((st.fetch_time - f0)
                                          + (st.recompute_time - r0)))
            # per-token virtual timestamps (decode emits <=1/step/request)
            for t in tracked.values():
                if t.done_v is not None:
                    continue
                while len(t.token_times) < len(t.req.generated):
                    t.token_times.append(vt)
                if t.req.phase is Phase.DONE:
                    t.done_v = vt
                    in_flight[t.session] = None
                    ready_v[t.session] = vt + rcfg.think_time_s
        else:
            # idle: jump the clock to the next session arrival
            nxt = min((ready_v[i] for i in range(n_sess) if pending(i)),
                      default=vt)
            vt = max(vt, nxt)
        if steps >= rcfg.max_steps:
            break
    eng.shutdown()

    done = [t for t in tracked.values() if t.done_v is not None]
    seen_total = sum(t.seen_blocks for t in done)
    hot = sum(min(t.req.hot_hit_blocks, t.seen_blocks) for t in done)
    served = sum(min(t.req.prefix_hit_blocks, t.seen_blocks) for t in done)
    ttfts = [t.token_times[0] - t.submit_v for t in done if t.token_times]
    tbts = [b - a for t in done
            for a, b in zip(t.token_times, t.token_times[1:])]
    gen = sum(len(t.req.generated) for t in done)
    mst = eng.manager.stats
    return ServingReplayResult(
        workload=rcfg.workload, policy=rcfg.policy,
        engine_hit_rate=hot / seen_total if seen_total else 0.0,
        reuse_rate=served / seen_total if seen_total else 0.0,
        seen_blocks=seen_total,
        manager_hit_rate=mst.hit_rate,
        manager_replay_hit_rate=mst.replay_hit_rate,
        hot_hits_t0=mst.hot_hits_t0, hot_hits_t1=mst.hot_hits_t1,
        cow_share_hits=eng.cow_share_hits, inject_hits=eng.inject_hits,
        promotions=mst.promotions, demotions=mst.demotions,
        requests_done=len(done), sessions=n_sess,
        generated_tokens=gen,
        ttft_p50=_percentile(ttfts, 0.50), ttft_p95=_percentile(ttfts, 0.95),
        tbt_p50=_percentile(tbts, 0.50), tbt_p95=_percentile(tbts, 0.95),
        throughput_tok_s=gen / vt if vt > 0 else 0.0,
        virtual_time_s=vt, steps=steps, wall_s=time.time() - t_wall)


def run_replay_serving_table(
        workloads: Sequence[str] = ("sharegpt", "lmsys", "agentic"),
        policies: Sequence[str] = ("lru", "ema", "bayesian"), *,
        n_sessions: int = 12, seed: int = 0, max_turns: int = 6,
        ) -> List[ServingReplayResult]:
    """Table-V-style sweep through the live engine (one seed: the live
    replay is ~100x the cost of the block-level replay per run; the
    block-level ``run_table_v`` remains the multi-seed statistics)."""
    out = []
    for wl in workloads:
        for policy in policies:
            out.append(run_serving_replay(ServingReplayConfig(
                workload=wl, policy=policy, n_sessions=n_sessions,
                seed=seed, max_turns=max_turns)))
    return out
