"""Trace→engine serving replay: the paper's §V-E evaluation driven
through the real ``ServingEngine`` — single-engine or multi-replica —
instead of the standalone cache manager.

``traces/replay.py`` replays block-access traces against the
``PredictiveCacheManager`` alone — scheduling, paged-pool CoW sharing,
chunked prefill and tier-transfer latency never enter the picture.  This
adapter closes that gap: it converts the same ShareGPT / LMSYS / agentic
session generators (``traces/generators.py``) into **timed multi-turn
request streams** and drives them open-loop against live engines under
a virtual clock:

  * each turn submits the **full conversation prefix** (system prompt +
    input history + new input), so cross-turn and cross-session reuse
    flows through the real radix-match → CoW-page-share / tier-payload
    injection path instead of a metadata lookup;
  * one trace block maps to exactly one engine KV block
    (``ModelConfig.kv_block_tokens`` shrinks the engine block so reduced
    models see trace-scale reuse granularity), keeping the engine's
    hit accounting block-for-block comparable with Table V;
  * sessions arrive open-loop at a fixed virtual interarrival; within a
    session the next turn submits after the previous turn's completion
    plus a think-time gap (closed-loop per conversation, like a real
    chat client);
  * the virtual clock advances per fleet step by a modelled step time:
    a fixed overhead, a per-token compute cost, and the modelled
    tier-fetch / recompute stall for that step (see *Fetch-stall
    model*) — so hit-rate differences between policies surface in
    TTFT/TBT, which is exactly the serving-layer interaction KVDrive
    (arXiv 2605.18071) argues block-level replay cannot capture.

**Multi-replica replay** (``run_cluster_replay``): the same turn
streams route through a ``serving/cluster.py::ReplicaCluster`` — every
busy replica steps once per fleet iteration (replicas run concurrently,
so the clock advances by the *slowest* replica's step time), sessions
route by the configured policy (consistent-hash affinity vs round-robin
vs least-loaded), and mid-replay ``fail_replica`` / ``add_replica``
events measure the failover recomputation tax and elastic-scale-out
remapping.  Hit rates are reported per replica and fleet-wide against
the *global* previously-seen ground truth, so routing that fragments
sessions across replicas shows up directly as a fleet hit-rate drop —
the cross-replica placement effect the KV-cache management survey
(arXiv 2607.02574) calls the deciding factor at scale.

Fetch-stall model: at paper scale a KV block is MBs (the reduced
model's blocks are KBs), so the virtual clock cannot reuse the
manager's reduced-size fetch accounting verbatim.  With
``fetch_stall_model="spec"`` (default) every demand fetch from a
non-hot tier stalls the clock by that tier's
``TierSpec.transfer_time`` evaluated at the **target model's** block
bytes (``target_model``, default llama-3-70b); asynchronous prefetch
promotions are not charged — they overlap compute, which is the
paper's §IV design point.  ``fetch_stall_model="fixed"`` keeps the
previous behaviour: a flat ``fetch_stall_s`` per promotion plus the
reduced-size fetch/recompute accounting.

Tier capacities reuse ``traces/replay.py::replay_tier_specs`` (scaled-
down tiers 0/1 so the reusable working set exceeds the hot set) with
``EngineConfig(tier0_from_budget=False)`` so the pressure capacities
stand.

Hit-rate definition (Table V analogue, measured at the engine):
``engine_hit_rate = hot-hit prompt blocks / previously-seen prompt
blocks``.  The denominator is trace ground truth — a prompt block whose
content appeared in an earlier-submitted turn (first touch excluded,
exactly like ``replay.py``), **fleet-wide**: under multi-replica
routing a block previously seen on replica A but routed to replica B
counts against B's hit rate, because at the serving layer that compute
really is re-paid.  The numerator is the engine's own accounting
(``Request.hot_hit_blocks``): blocks actually served from tiers 0-1.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config import ModelConfig, reduce_config
from repro.core import sizing
from repro.core.faults import FaultInjector, FaultProfile
from repro.serving.cluster import ReplicaCluster, make_router
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Phase, Request, SamplingParams
from repro.traces.generators import TraceConfig, Turn, workload_sessions
from repro.traces.replay import replay_tier_specs


def replay_model_config(block_tokens: int = 32) -> ModelConfig:
    """Reduced llama3.2-1b with trace-scale KV blocks: one trace block
    (nominally 128 tokens) maps to one ``block_tokens``-token engine
    block, so a full multi-turn prompt stays CPU-sized while the reuse
    structure is preserved block-for-block."""
    from repro.configs import get_config
    cfg = reduce_config(get_config("llama3.2-1b"))
    return dataclasses.replace(cfg, name=cfg.name + "-replay",
                               kv_block_tokens=block_tokens)


# Per-workload tier-0/tier-1 pressure (block counts) for the live-engine
# replay: chosen so the reusable working set (sharegpt 244 / lmsys 110 /
# agentic 168 distinct blocks at 12 sessions, plus the turns' single-use
# output blocks) exceeds the hot set and the eviction policy has
# decisions to make — cf. REPLAY_HOT_BLOCKS for the block-level replay.
# Multi-replica runs keep these capacities PER REPLICA: the fleet's
# aggregate hot capacity grows with n, which is exactly the deployment
# trade the cluster sweep measures (more aggregate cache, colder slices
# under naive routing).
ENGINE_REPLAY_BLOCKS: Dict[str, Tuple[int, int]] = {
    "sharegpt": (48, 72),
    "lmsys": (32, 48),
    "agentic": (64, 96),
}


@dataclass
class ServingReplayConfig:
    workload: str = "agentic"
    policy: str = "bayesian"            # lru | ema | bayesian
    n_sessions: int = 12
    seed: int = 0
    max_turns: int = 6                  # cap turns per session (CPU budget)
    max_new_cap: int = 4                # cap decode tokens per turn
    block_tokens: int = 32              # engine tokens per trace block
    page_tokens: int = 32
    prefill_chunk_tokens: int = 64
    max_step_tokens: int = 160
    n_slots: int = 8                    # target decode concurrency
    hot_blocks: Optional[int] = None    # tier-0 capacity (None: per-workload)
    t1_blocks: Optional[int] = None     # tier-1 capacity (None: per-workload)
    t2_blocks: Optional[int] = None     # cap CXL blocks (None: paper scale;
    #                                     the chaos table caps it to push
    #                                     demotion traffic into NVMe/RDMA)
    t3_blocks: Optional[int] = None     # cap NVMe blocks likewise
    async_transfers: bool = True        # real async worker path; False runs
    #                                     transfers inline — bit-for-bit
    #                                     deterministic (thread completion
    #                                     timing is polled per step, so a
    #                                     prefetch promotion may land a step
    #                                     earlier or later between runs)
    # --- virtual clock model ---------------------------------------------
    session_interarrival_s: float = 0.005
    think_time_s: float = 0.02
    step_overhead_s: float = 1.5e-3
    per_token_s: float = 4e-5
    stall_weight: float = 1.0           # modelled fetch/recompute stall
    fetch_stall_model: str = "spec"     # "spec": per-fetch stall derived
    #                                     from TierSpec.transfer_time at the
    #                                     TARGET model's block bytes, charged
    #                                     per demand fetch from each non-hot
    #                                     tier (async prefetch promotions
    #                                     overlap compute — not charged).
    #                                     "fixed": the pre-PR4 flat charge
    #                                     below, kept as an A/B fallback.
    target_model: str = "llama-3-70b"   # paper model whose block bytes set
    #                                     the spec-derived stall
    fetch_stall_s: float = 1e-3         # "fixed" mode: flat stall per
    #                                     promotion (the old constant)
    kernel_backend: Optional[str] = None   # paged-op backend ("pallas" /
    #                                     "interpret" / "xla"); None
    #                                     resolves via kernels/backend.py
    #                                     (xla off-TPU — several times
    #                                     faster replay wall-clock than
    #                                     the old interpret-mode default)
    fused_step: bool = True             # fused jitted decode+sample step
    #                                     closure (False: per-request
    #                                     sampling A/B — greedy replay is
    #                                     token-identical, so hit rates
    #                                     must match either way)
    segment_reuse: bool = True          # content-segment index: resume
    #                                     matching blocks mid-prompt beyond
    #                                     the contiguous radix prefix
    #                                     (False: monolithic-radix A/B)
    # --- fault injection (chaos replay) -----------------------------------
    fault_profiles: Optional[Dict[int, FaultProfile]] = None
    #                                     per-tier chaos profiles; None
    #                                     attaches no injector, and the
    #                                     fault path is fully inert — the
    #                                     replay reproduces the fault-free
    #                                     numbers bit-identically
    fault_seed: int = 0                 # injector RNG seed
    transfer_timeout_s: float = 30.0    # async transfer watchdog (wall s);
    #                                     expired transfers come back as
    #                                     failed events -> recompute
    max_steps: int = 50_000


@dataclass
class ClusterReplayConfig(ServingReplayConfig):
    """Multi-replica replay: ``ServingReplayConfig`` plus fleet shape,
    routing policy and optional mid-replay membership events."""
    n_replicas: int = 2
    routing: str = "affine"             # affine | round_robin |
    #                                     least_loaded | prefix
    ring_salt: str = ""                 # affine: seeds the session→replica
    #                                     assignment without renaming nodes
    fail_replica_after_turns: Optional[int] = None   # fail one replica once
    #                                     this many turns completed fleet-wide
    fail_replica_name: Optional[str] = None          # victim (default: the
    #                                     replica with the most live work)
    add_replica_after_turns: Optional[int] = None    # scale out by one
    #                                     replica at this completion count
    shared_tier: bool = False           # bind every replica's tier 4 to one
    #                                     fleet-shared content-addressed store
    warmup_on_add: bool = False         # push remapped sessions' prefix
    #                                     blocks to a joining replica before
    #                                     it takes traffic


@dataclass
class ServingReplayResult:
    workload: str
    policy: str
    engine_hit_rate: float         # hot (tier 0-1) hits / seen blocks
    reuse_rate: float              # any-tier cache-served / seen blocks
    seen_blocks: int
    manager_hit_rate: float        # PredictiveCacheManager hot-hit rate
    manager_replay_hit_rate: float
    hot_hits_t0: int               # pool (CoW-shareable) hits
    hot_hits_t1: int               # DRAM-resident hits
    cow_share_hits: int            # engine: blocks served by CoW page map
    inject_hits: int               # engine: blocks served by payload inject
    promotions: int
    demotions: int
    requests_done: int
    sessions: int
    generated_tokens: int
    ttft_p50: float                # virtual seconds
    ttft_p95: float
    tbt_p50: float
    tbt_p95: float
    throughput_tok_s: float        # generated tokens / virtual time
    virtual_time_s: float
    steps: int
    wall_s: float
    # segment reuse (zeros when segment_reuse=False)
    segment_hit_blocks: int = 0    # mid-prompt blocks resumed via the
    #                                content-segment index (capped per
    #                                request at the seen ground truth)
    segment_share_hits: int = 0    # engine: resumed by CoW page map
    segment_inject_hits: int = 0   # engine: resumed by payload inject
    segment_lookups: int = 0       # manager: match_segments calls
    segment_lookup_s: float = 0.0  # manager: wall time in those lookups
    # fault injection / robustness (zeros when fault_profiles is None)
    turns_submitted: int = 0       # every dispatched turn; the zero-hung
    #                                invariant is turns_submitted ==
    #                                requests_done
    ttft_p99: float = 0.0          # virtual seconds (chaos-table metric)
    retries: int = 0               # transient errors absorbed by retry
    io_errors: int = 0             # ops that exhausted the retry budget
    integrity_failures: int = 0    # corrupt payloads caught by checksum
    fetch_recomputes: int = 0      # failed fetches converted to recompute
    retry_delay_s: float = 0.0     # modelled backoff charged to the clock
    tier_health: Dict[int, str] = field(default_factory=dict)
    injected: Dict[str, int] = field(default_factory=dict)


@dataclass
class ReplicaReplayStats:
    """One replica's slice of a cluster replay (hit denominators are
    the fleet-wide previously-seen ground truth for the requests that
    COMPLETED on this replica)."""
    name: str
    failed: bool                   # replica was killed mid-replay
    requests_done: int
    seen_blocks: int
    hot_hit_blocks: int
    hit_rate: float                # tier-0/1 hits / seen blocks
    reuse_rate: float              # any-tier served / seen blocks
    manager_hit_rate: float        # the replica manager's own hot-hit rate
    promotions: int
    demotions: int
    shared_hit_blocks: int = 0     # blocks imported from the fleet tier


@dataclass
class ClusterReplayResult:
    workload: str
    policy: str
    routing: str
    n_replicas: int                # replicas that ever served traffic
    fleet_hit_rate: float          # tier-0/1 hits / seen blocks, fleet-wide
    fleet_reuse_rate: float
    seen_blocks: int
    per_replica: List[ReplicaReplayStats]
    redispatched: int              # failover requeues
    reprefill_tokens: int          # prompt+generated tokens whose KV died
    failed_replicas: List[str]
    requests_done: int
    sessions: int
    generated_tokens: int
    ttft_p50: float                # virtual seconds (includes the failover
    ttft_p95: float                # re-prefill tax for redispatched turns)
    tbt_p50: float
    tbt_p95: float
    throughput_tok_s: float
    virtual_time_s: float
    steps: int                     # fleet iterations
    wall_s: float
    # fleet-shared tier 4 (zeros when shared_tier=False)
    shared_tier: bool = False
    shared_hit_blocks: int = 0     # fleet-tier imports across all requests
    shared_hit_rate: float = 0.0   # shared imports / seen blocks
    fleet_hit_rate_incl_shared: float = 0.0  # (hot + shared imports) / seen:
    #                                the fleet-level hit — a shared import is
    #                                a tier-4 fetch, not a re-prefill
    # scale-out warm-up (zeros unless add_replica fired mid-replay)
    joined_replica: str = ""
    postjoin_ttft_p95: float = 0.0  # turns served by the joiner
    steady_ttft_p95: float = 0.0    # turns elsewhere, never redispatched
    warmed_blocks: int = 0
    warmed_sessions: int = 0
    ttft_p99: float = 0.0


@dataclass
class _TurnSpec:
    session_id: str
    prompt: List[int]
    block_types: List[str]
    acct_cids: List[Tuple]         # accountable content ids (full blocks)
    tool: Optional[str]
    max_new: int


@dataclass
class _Tracked:
    req: Request
    session: int
    submit_v: float
    seen_blocks: int
    replica: str = ""
    redispatches: int = 0
    token_times: List[float] = field(default_factory=list)
    done_v: Optional[float] = None


def _materialize(cid: Tuple, bt: int, vocab: int,
                 cache: Dict[Tuple, List[int]]) -> List[int]:
    """Content id -> a deterministic block of ``bt`` tokens.  Identical
    ids yield identical tokens, so the engine's content-hash dedup and
    radix prefix matching see the trace's sharing structure."""
    toks = cache.get(cid)
    if toks is None:
        rng = np.random.default_rng(cid[0])
        toks = [int(t) for t in rng.integers(0, vocab, size=bt)]
        cache[cid] = toks
    return toks


def _turn_spec(turn: Turn, bt: int, vocab: int, max_new_cap: int,
               cache: Dict[Tuple, List[int]]) -> _TurnSpec:
    """One trace turn -> a request spec.

    The prompt is the turn's full conversation prefix (system + history
    + input, in event order) **plus the turn's output blocks** at the
    end: after a real turn, the model's reply occupies KV alongside the
    prompt, and the trace marks those ``intermediate_reasoning`` blocks
    single-use.  Materializing them prompt-side puts the same block
    population in the live pool — single-use scratch that the eviction
    policy must get out of the way of reusable context, which is the
    paper's Problem 3 (recency != reuse; decoding the full reply
    token-by-token on CPU would cost ~bt x more for identical cache
    behaviour).  The next turn's prompt never repeats them, so the radix
    prefix diverges exactly where the trace says it does.  Decode load
    is a capped handful of sampled tokens per turn."""
    prompt: List[int] = []
    btypes: List[str] = []
    cids: List[Tuple] = []
    tool: Optional[str] = None
    out_blocks = 0
    for ev in turn:
        if ev.tool is not None:
            tool = ev.tool
        if ev.block_type == "intermediate_reasoning":
            out_blocks += 1
        prompt.extend(_materialize(ev.content_id, bt, vocab, cache))
        btypes.append(ev.block_type)
        cids.append(ev.content_id)
    # prefill covers prompt[:-1]: the final block stays one token short
    # of full, so it is neither registered nor matchable — exclude it
    # from the hit accounting (it can never be a hit or a miss)
    return _TurnSpec(session_id=turn[0].session, prompt=prompt,
                     block_types=btypes, acct_cids=cids[:-1], tool=tool,
                     max_new=max(1, min(max_new_cap, out_blocks)))


def build_engine(rcfg: ServingReplayConfig, cfg: Optional[ModelConfig] = None,
                 max_len: int = 768) -> ServingEngine:
    cfg = replay_model_config(rcfg.block_tokens) if cfg is None else cfg
    hot, t1 = ENGINE_REPLAY_BLOCKS.get(rcfg.workload, (64, 96))
    hot = rcfg.hot_blocks if rcfg.hot_blocks is not None else hot
    t1 = rcfg.t1_blocks if rcfg.t1_blocks is not None else t1
    specs = replay_tier_specs(cfg, hot_blocks=hot, t1_blocks=t1)
    if rcfg.t2_blocks is not None or rcfg.t3_blocks is not None:
        bb = sizing.block_bytes(cfg)
        specs = list(specs)
        if rcfg.t2_blocks is not None:
            specs[2] = dataclasses.replace(specs[2],
                                           capacity=rcfg.t2_blocks * bb)
        if rcfg.t3_blocks is not None:
            specs[3] = dataclasses.replace(specs[3],
                                           capacity=rcfg.t3_blocks * bb)
        specs = tuple(specs)
    ecfg = EngineConfig(
        max_len=max_len,
        kv_budget_bytes=rcfg.n_slots * sizing.seq_bytes(cfg, max_len),
        policy=rcfg.policy,
        deadline_s=1e9,                 # virtual time: no wall-clock
        #                                 straggler preemption
        seed=rcfg.seed,
        tier_specs=specs,
        tier0_from_budget=False,        # keep the replay pressure capacity
        async_transfers=rcfg.async_transfers,
        page_tokens=rcfg.page_tokens,
        prefill_chunk_tokens=rcfg.prefill_chunk_tokens,
        max_step_tokens=rcfg.max_step_tokens,
        kernel_backend=rcfg.kernel_backend,
        fused_step=rcfg.fused_step,
        segment_reuse=rcfg.segment_reuse,
        fault_injector=(FaultInjector(dict(rcfg.fault_profiles),
                                      seed=rcfg.fault_seed)
                        if rcfg.fault_profiles else None),
        transfer_timeout_s=rcfg.transfer_timeout_s)
    return ServingEngine(cfg, ecfg)


# ---------------------------------------------------------------------------
# virtual-clock fetch-stall model
# ---------------------------------------------------------------------------
class _FetchStallModel:
    """Per-step virtual-clock stall from one engine's manager deltas.

    ``spec`` mode (default): each demand fetch from a non-hot tier —
    visible as a ``ManagerStats.tier_hits`` increment on tiers outside
    ``hot_tiers`` — stalls the clock by that tier's
    ``TierSpec.transfer_time`` at the *target* model's block bytes
    (paper-scale MB blocks, not the reduced model's KB blocks).
    Recompute stalls still charge at ``stall_weight``.  Async prefetch
    promotions are free: they overlap compute (§IV).

    ``fixed`` mode: the pre-PR4 model — a flat ``fetch_stall_s`` per
    promotion plus the reduced-size fetch/recompute accounting.
    """

    def __init__(self, rcfg: ServingReplayConfig, engine: ServingEngine):
        self.mode = rcfg.fetch_stall_model
        if self.mode not in ("spec", "fixed"):
            raise ValueError(
                f"fetch_stall_model must be 'spec' or 'fixed', "
                f"got {rcfg.fetch_stall_model!r}")
        self.fixed_s = rcfg.fetch_stall_s
        self.weight = rcfg.stall_weight
        self.hot_tiers = engine.manager.hot_tiers
        from repro.configs.paper_models import PAPER_MODELS
        target = PAPER_MODELS[rcfg.target_model]
        bb = sizing.block_bytes(target)
        self.target_block_bytes = bb
        self.tier_stall_s = {t.spec.tier_id: t.spec.transfer_time(bb)
                             for t in engine.manager.hierarchy.tiers}

    def snapshot(self, engine: ServingEngine) -> tuple:
        st = engine.manager.stats
        hy = engine.manager.hierarchy
        inj = hy.fault_injector
        bo = (dict(inj.read_brownouts_by_tier) if inj is not None else {})
        return (st.fetch_time, st.recompute_time, st.promotions,
                dict(st.tier_hits), hy.counters.retry_delay_s, bo)

    def _fault_stall(self, engine: ServingEngine, rd0: float,
                     bo0: dict) -> float:
        """Virtual seconds of injected-fault latency this step: retry
        backoff delays (modelled, accumulated by ``run_io``) plus the
        brownout inflation of demand-fetch transfers — each read
        brownout turns one tier fetch into ``mult`` fetches' worth of
        stall at the target model's block bytes."""
        hy = engine.manager.hierarchy
        stall = hy.counters.retry_delay_s - rd0
        inj = hy.fault_injector
        if inj is not None:
            for tid, n in inj.read_brownouts_by_tier.items():
                d = n - bo0.get(tid, 0)
                if d > 0:
                    mult = inj.profiles[tid].brownout_latency_mult
                    stall += d * (mult - 1.0) * self.tier_stall_s[tid]
        return stall

    def charge(self, engine: ServingEngine, snap: tuple) -> float:
        f0, r0, p0, th0, rd0, bo0 = snap
        st = engine.manager.stats
        fault_s = self._fault_stall(engine, rd0, bo0)
        if self.mode == "fixed":
            return (fault_s + self.fixed_s * (st.promotions - p0)
                    + self.weight * ((st.fetch_time - f0)
                                     + (st.recompute_time - r0)))
        stall = fault_s + self.weight * (st.recompute_time - r0)
        for tier, n in st.tier_hits.items():
            if tier in self.hot_tiers:
                continue
            d = n - th0.get(tier, 0)
            if d > 0:
                stall += d * self.tier_stall_s[tier]
        return stall


def _percentile(vals: Sequence[float], p: float) -> float:
    vals = sorted(vals)
    if not vals:
        return 0.0
    return vals[min(len(vals) - 1, int(p * len(vals)))]


# ---------------------------------------------------------------------------
# the shared replay loop (single engine == 1-replica cluster)
# ---------------------------------------------------------------------------
@dataclass
class _ReplayCore:
    cluster: ReplicaCluster
    tracked: Dict[int, _Tracked]
    seen_total: int
    virtual_time: float
    steps: int
    wall_s: float
    sessions: int
    join_name: str = ""            # replica added mid-replay ("" if none)
    join_v: float = 0.0            # virtual time of the join


def _run_replay_core(rcfg: ServingReplayConfig, *, n_replicas: int = 1,
                     routing: str = "affine", ring_salt: str = "",
                     fail_after: Optional[int] = None,
                     fail_name: Optional[str] = None,
                     add_after: Optional[int] = None,
                     shared_tier: bool = False,
                     warmup_on_add: bool = False,
                     turn_log: Optional[List[dict]] = None) -> _ReplayCore:
    """Drive one workload x policy through ``n_replicas`` live engines
    under the shared virtual clock; the single-engine replay is exactly
    the 1-replica case."""
    cfg = replay_model_config(rcfg.block_tokens)
    bt = sizing.block_tokens(cfg)
    sessions = workload_sessions(
        rcfg.workload, TraceConfig(n_sessions=rcfg.n_sessions,
                                   seed=rcfg.seed))
    cache: Dict[Tuple, List[int]] = {}
    specs: List[List[_TurnSpec]] = [
        [_turn_spec(t, bt, cfg.vocab_size, rcfg.max_new_cap, cache)
         for t in sess[:rcfg.max_turns]]
        for sess in sessions]
    max_prompt = max(len(t.prompt) for s in specs for t in s)
    max_len = max_prompt + rcfg.max_new_cap + 2
    max_len = -(-max_len // rcfg.page_tokens) * rcfg.page_tokens
    router = make_router(routing, salt=ring_salt) \
        if routing == "affine" else make_router(routing)
    cluster = ReplicaCluster(
        engine_factory=lambda: build_engine(rcfg, cfg, max_len=max_len),
        n_replicas=n_replicas, router=router, shared_tier=shared_tier)
    stall = _FetchStallModel(rcfg,
                             next(iter(cluster.engines.values())))

    n_sess = len(specs)
    next_turn = [0] * n_sess
    ready_v = [i * rcfg.session_interarrival_s for i in range(n_sess)]
    in_flight: List[Optional[int]] = [None] * n_sess   # request_id
    seen: set = set()
    tracked: Dict[int, _Tracked] = {}
    vt = 0.0
    t_wall = time.time()
    steps = 0
    done_count = 0
    failed_once = False
    added_once = False

    def pending(i: int) -> bool:
        return next_turn[i] < len(specs[i])

    while any(pending(i) for i in range(n_sess)) or cluster.has_work():
        # open-loop submission: every session whose next turn is due
        for i in range(n_sess):
            if not pending(i) or in_flight[i] is not None \
                    or ready_v[i] > vt:
                continue
            spec = specs[i][next_turn[i]]
            n_seen = sum(1 for c in spec.acct_cids if c in seen)
            seen.update(spec.acct_cids)
            target, req = cluster.dispatch(
                spec.prompt,
                params=SamplingParams(max_new_tokens=spec.max_new),
                session_id=spec.session_id,
                block_types=spec.block_types,
                tool=spec.tool,
                retain_blocks=next_turn[i] + 1 < len(specs[i]))
            tracked[req.request_id] = _Tracked(
                req=req, session=i, submit_v=vt, seen_blocks=n_seen,
                replica=target)
            in_flight[i] = req.request_id
            if turn_log is not None:
                turn_log.append({"session": spec.session_id,
                                 "turn": next_turn[i],
                                 "request_id": req.request_id,
                                 "submit_v": vt,
                                 "prompt_len": len(spec.prompt),
                                 "replica": target})
            next_turn[i] += 1
        busy = cluster.busy()
        if busy:
            # every busy replica steps once; replicas run concurrently,
            # so the fleet clock advances by the slowest replica's step
            dt_max = 0.0
            for name, eng in busy:
                snap = stall.snapshot(eng)
                produced = eng.step()
                step_tokens = eng.last_step_prefill_tokens + produced
                dt = (rcfg.step_overhead_s
                      + rcfg.per_token_s * step_tokens
                      + stall.charge(eng, snap))
                dt_max = max(dt_max, dt)
            vt += dt_max
            steps += 1
            # per-token virtual timestamps (decode emits <=1/step/request)
            for t in tracked.values():
                if t.done_v is not None:
                    continue
                while len(t.token_times) < len(t.req.generated):
                    t.token_times.append(vt)
                if t.req.phase is Phase.DONE:
                    t.done_v = vt
                    done_count += 1
                    in_flight[t.session] = None
                    ready_v[t.session] = vt + rcfg.think_time_s
        else:
            # idle: jump the clock to the next session arrival
            nxt = min((ready_v[i] for i in range(n_sess) if pending(i)),
                      default=vt)
            vt = max(vt, nxt)
        # mid-replay membership events (fleet-completion triggered)
        if (fail_after is not None and not failed_once
                and done_count >= fail_after and cluster.n_replicas > 1):
            failed_once = True
            if fail_name is not None:
                victim = fail_name
            else:
                # default victim: the replica with the most live work
                # (ties by name) — failing an idle replica would make
                # the failover tax trivially zero
                victim = max(
                    sorted(cluster.engines),
                    key=lambda n:
                        cluster.engines[n].scheduler.live_count())
            n_lost = cluster.fail_replica(victim)
            for rid, _frm, to in cluster.redispatch_log[-n_lost:]:
                t = tracked[rid]
                # generation restarts on the successor: drop the stale
                # token timestamps but keep submit_v, so TTFT carries
                # the full failover re-prefill tax
                t.token_times.clear()
                t.replica = to
                t.redispatches += 1
        if (add_after is not None and not added_once
                and done_count >= add_after):
            added_once = True
            join_name = cluster.add_replica(warmup=warmup_on_add)
            join_v = vt
        if steps >= rcfg.max_steps:
            break
    cluster.shutdown()

    done = [t for t in tracked.values() if t.done_v is not None]
    return _ReplayCore(cluster=cluster, tracked=tracked,
                       seen_total=sum(t.seen_blocks for t in done),
                       virtual_time=vt, steps=steps,
                       wall_s=time.time() - t_wall, sessions=n_sess,
                       join_name=join_name if added_once else "",
                       join_v=join_v if added_once else 0.0)


def _latency_rollup(core: _ReplayCore) -> dict:
    done = [t for t in core.tracked.values() if t.done_v is not None]
    ttfts = [t.token_times[0] - t.submit_v for t in done if t.token_times]
    tbts = [b - a for t in done
            for a, b in zip(t.token_times, t.token_times[1:])]
    gen = sum(len(t.req.generated) for t in done)
    vt = core.virtual_time
    return dict(
        requests_done=len(done), generated_tokens=gen,
        ttft_p50=_percentile(ttfts, 0.50), ttft_p95=_percentile(ttfts, 0.95),
        ttft_p99=_percentile(ttfts, 0.99),
        tbt_p50=_percentile(tbts, 0.50), tbt_p95=_percentile(tbts, 0.95),
        throughput_tok_s=gen / vt if vt > 0 else 0.0,
        virtual_time_s=vt, steps=core.steps, wall_s=core.wall_s)


def run_serving_replay(rcfg: ServingReplayConfig,
                       turn_log: Optional[List[dict]] = None
                       ) -> ServingReplayResult:
    """Replay one workload x policy through one live engine (the
    1-replica case of the shared loop).

    ``turn_log`` (optional) receives one dict per submitted turn
    (session, turn index, request id, virtual submit time, replica) —
    the determinism / ordering tests assert on it.
    """
    core = _run_replay_core(rcfg, n_replicas=1, turn_log=turn_log)
    eng = next(iter(core.cluster.engines.values()))
    done = [t for t in core.tracked.values() if t.done_v is not None]
    seen_total = core.seen_total
    hot = sum(min(t.req.hot_hit_blocks, t.seen_blocks) for t in done)
    # any-tier cache-served: contiguous prefix blocks plus mid-prompt
    # segment-resumed blocks (disjoint by construction — segments start
    # past the materialized prefix)
    served = sum(min(t.req.prefix_hit_blocks + t.req.segment_hit_blocks,
                     t.seen_blocks) for t in done)
    seg = sum(min(t.req.segment_hit_blocks, t.seen_blocks) for t in done)
    eng.manager.sync_fault_stats()
    mst = eng.manager.stats
    hy = eng.manager.hierarchy
    lat = _latency_rollup(core)
    return ServingReplayResult(
        workload=rcfg.workload, policy=rcfg.policy,
        engine_hit_rate=hot / seen_total if seen_total else 0.0,
        reuse_rate=served / seen_total if seen_total else 0.0,
        seen_blocks=seen_total,
        manager_hit_rate=mst.hit_rate,
        manager_replay_hit_rate=mst.replay_hit_rate,
        hot_hits_t0=mst.hot_hits_t0, hot_hits_t1=mst.hot_hits_t1,
        cow_share_hits=eng.cow_share_hits, inject_hits=eng.inject_hits,
        promotions=mst.promotions, demotions=mst.demotions,
        sessions=core.sessions,
        segment_hit_blocks=seg,
        segment_share_hits=eng.segment_share_hits,
        segment_inject_hits=eng.segment_inject_hits,
        segment_lookups=mst.segment_lookups,
        segment_lookup_s=mst.segment_lookup_time,
        turns_submitted=len(core.tracked),
        retries=mst.retries, io_errors=mst.io_errors,
        integrity_failures=mst.integrity_failures,
        fetch_recomputes=mst.fetch_recomputes,
        retry_delay_s=hy.counters.retry_delay_s,
        tier_health=dict(mst.tier_health),
        injected=(hy.fault_injector.stats()
                  if hy.fault_injector is not None else {}), **lat)


def run_cluster_replay(rcfg: ClusterReplayConfig,
                       turn_log: Optional[List[dict]] = None
                       ) -> ClusterReplayResult:
    """Replay one workload x policy through an ``n_replicas`` cluster
    under the configured routing policy (plus optional mid-replay
    ``fail_replica`` / ``add_replica`` events); reports per-replica and
    fleet-level hit rates against the fleet-wide previously-seen ground
    truth, plus the failover redispatch / re-prefill tax."""
    core = _run_replay_core(
        rcfg, n_replicas=rcfg.n_replicas, routing=rcfg.routing,
        ring_salt=rcfg.ring_salt,
        fail_after=rcfg.fail_replica_after_turns,
        fail_name=rcfg.fail_replica_name,
        add_after=rcfg.add_replica_after_turns,
        shared_tier=rcfg.shared_tier,
        warmup_on_add=rcfg.warmup_on_add,
        turn_log=turn_log)
    cluster = core.cluster
    done = [t for t in core.tracked.values() if t.done_v is not None]
    seen_total = core.seen_total
    hot = sum(min(t.req.hot_hit_blocks, t.seen_blocks) for t in done)
    served = sum(min(t.req.prefix_hit_blocks, t.seen_blocks) for t in done)
    # a shared-tier import is a tier-4 fetch instead of a re-prefill:
    # count it toward the fleet-level hit (capped, like hot, at the
    # request's previously-seen ground truth)
    shared = sum(min(t.req.shared_hit_blocks, t.seen_blocks) for t in done)
    incl = sum(min(t.req.hot_hit_blocks + t.req.shared_hit_blocks,
                   t.seen_blocks) for t in done)

    per_replica: List[ReplicaReplayStats] = []
    mgr_stats = cluster.manager_stats()
    names = sorted(mgr_stats)
    for name in names:
        mine = [t for t in done if t.replica == name]
        s_seen = sum(t.seen_blocks for t in mine)
        s_hot = sum(min(t.req.hot_hit_blocks, t.seen_blocks) for t in mine)
        s_served = sum(min(t.req.prefix_hit_blocks, t.seen_blocks)
                       for t in mine)
        ms = mgr_stats[name]
        per_replica.append(ReplicaReplayStats(
            name=name, failed=name in cluster.failed_stats,
            requests_done=len(mine), seen_blocks=s_seen,
            hot_hit_blocks=s_hot,
            hit_rate=s_hot / s_seen if s_seen else 0.0,
            reuse_rate=s_served / s_seen if s_seen else 0.0,
            manager_hit_rate=ms.hit_rate,
            promotions=ms.promotions, demotions=ms.demotions,
            shared_hit_blocks=sum(t.req.shared_hit_blocks for t in mine)))
    lat = _latency_rollup(core)
    # scale-out warm-up: TTFT of turns the joiner served vs steady-state
    # turns (elsewhere, never redispatched) — the post-join spike metric
    postjoin = steady = 0.0
    if core.join_name:
        j_ttfts = [t.token_times[0] - t.submit_v for t in done
                   if t.replica == core.join_name and t.token_times]
        s_ttfts = [t.token_times[0] - t.submit_v for t in done
                   if t.replica != core.join_name and t.token_times
                   and t.redispatches == 0]
        postjoin = _percentile(j_ttfts, 0.95)
        steady = _percentile(s_ttfts, 0.95)
    return ClusterReplayResult(
        workload=rcfg.workload, policy=rcfg.policy, routing=rcfg.routing,
        n_replicas=len(names),
        fleet_hit_rate=hot / seen_total if seen_total else 0.0,
        fleet_reuse_rate=served / seen_total if seen_total else 0.0,
        seen_blocks=seen_total, per_replica=per_replica,
        redispatched=cluster.redispatched,
        reprefill_tokens=cluster.reprefill_tokens,
        failed_replicas=sorted(cluster.failed_stats),
        sessions=core.sessions,
        shared_tier=rcfg.shared_tier,
        shared_hit_blocks=shared,
        shared_hit_rate=shared / seen_total if seen_total else 0.0,
        fleet_hit_rate_incl_shared=incl / seen_total if seen_total else 0.0,
        joined_replica=core.join_name,
        postjoin_ttft_p95=postjoin, steady_ttft_p95=steady,
        warmed_blocks=cluster.warmed_blocks,
        warmed_sessions=cluster.warmed_sessions, **lat)


def run_replay_serving_table(
        workloads: Sequence[str] = ("sharegpt", "lmsys", "agentic"),
        policies: Sequence[str] = ("lru", "ema", "bayesian"), *,
        n_sessions: int = 12, seed: int = 0, max_turns: int = 6,
        kernel_backend: Optional[str] = None,
        ) -> List[ServingReplayResult]:
    """Table-V-style sweep through the live engine (one seed: the live
    replay is ~100x the cost of the block-level replay per run; the
    block-level ``run_table_v`` remains the multi-seed statistics)."""
    out = []
    for wl in workloads:
        for policy in policies:
            out.append(run_serving_replay(ServingReplayConfig(
                workload=wl, policy=policy, n_sessions=n_sessions,
                seed=seed, max_turns=max_turns,
                kernel_backend=kernel_backend)))
    return out


def run_cluster_table(
        workload: str = "lmsys", policy: str = "bayesian", *,
        n_replicas: Sequence[int] = (1, 2, 4),
        routings: Sequence[str] = ("affine", "round_robin"),
        n_sessions: int = 12, seed: int = 0, max_turns: int = 6,
        kernel_backend: Optional[str] = None,
        shared_tier: bool = False,
        ) -> List[ClusterReplayResult]:
    """The fleet-level sweep behind ``benchmarks/run.py --table
    cluster``: ``n_replicas x routing_policy`` on one workload.  The
    headline question: does session-affine routing recover the
    single-engine hit rate that session-blind routing fragments?  With
    ``shared_tier=True`` every cell binds the fleet-shared tier 4, and
    the incl-shared hit rate shows how many of the fragmented points a
    cross-replica tier-4 fetch recovers."""
    out = []
    for n in n_replicas:
        for routing in routings:
            if n == 1 and routing != "affine":
                continue            # routing is moot on one replica
            out.append(run_cluster_replay(ClusterReplayConfig(
                workload=workload, policy=policy, n_sessions=n_sessions,
                seed=seed, max_turns=max_turns, n_replicas=n,
                routing=routing, kernel_backend=kernel_backend,
                shared_tier=shared_tier)))
    return out
