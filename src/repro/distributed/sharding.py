"""Logical-axis sharding rules: DP / TP / SP / EP mapping onto the mesh.

Mesh axes:  ("data", "model") single-pod, ("pod", "data", "model")
multi-pod.  The "pod" axis is an outer data-parallel axis (gradient
all-reduce crosses the pod boundary once per step; everything else stays
pod-local).

Logical activation/param axes -> mesh axes (baseline rules):

    batch     -> ("pod", "data")      DP
    vocab     -> "model"              TP embedding / logits
    heads     -> "model"              TP attention (q heads)
    kv_heads  -> "model"              TP KV projections (GSPMD pads when
                                      h_kv < model-axis size)
    mlp       -> "model"              TP FFN
    inner     -> "model"              TP Mamba2 d_inner / SSM heads
    experts   -> None (weights)       experts live on every TP shard;
                                      per-expert hidden dim is TP-sharded
    kv_seq    -> "model"              decode KV caches: sequence-sharded
                                      (flash-decoding; see DESIGN.md)
    layers    -> None                 scan axis, never sharded
    embed     -> None                 activations replicated over model

``long_500k`` (batch=1) overrides kv_seq -> ("data", "model") so a single
sequence's state spreads over all chips.

ZeRO-1 (optimizer state sharding over the data axis) is applied on top of
the param rules by ``zero1_spec``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.common import PSpec

Rules = Dict[str, Any]

BASE_RULES: Rules = {
    "batch": ("pod", "data"),
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "inner": "model",
    "experts": None,
    "kv_seq": "model",
    "moe_groups": ("pod", "data"),
    "seq": None,
    "layers": None,
    "embed": None,
    "embed_out": None,
    "latent": None,
    "int": None,
}

BASE_RULES["seq_res"] = None      # residual-stream seq dim (SP when set)

LONG_CONTEXT_RULES: Rules = dict(BASE_RULES, kv_seq=("data", "model"))

# Expert parallelism: experts sharded over the model axis (per-expert
# hidden dim whole per shard).  MoE fwd/bwd cross-shard reductions then
# move token-space [G,t,d] tensors instead of slot-space [G,E,C,d]
# (top_k * capacity_factor ~= 10x smaller for granite).
EP_RULES: Rules = dict(BASE_RULES, experts="model", mlp=None)

# Sequence parallelism: the residual stream between blocks is sharded on
# seq over the model axis — GSPMD turns per-layer activation all-reduces
# into reduce-scatter + all-gather pairs (half the wire) and remat-saved
# layer inputs shrink by the TP degree.
SP_RULES: Rules = dict(BASE_RULES, seq_res="model")


def _filter_axes(rules: Rules, mesh: Mesh) -> Rules:
    """Drop mesh axes that don't exist (e.g. 'pod' on the single-pod
    mesh, or everything on a 1-device test mesh)."""
    names = set(mesh.axis_names)
    out: Rules = {}
    for k, v in rules.items():
        if v is None:
            out[k] = None
        elif isinstance(v, tuple):
            kept = tuple(a for a in v if a in names)
            out[k] = kept if kept else None
        else:
            out[k] = v if v in names else None
    return out


def logical_to_pspec(axes: Tuple[Optional[str], ...], rules: Rules) -> P:
    parts = []
    for a in axes:
        r = rules.get(a) if a is not None else None
        parts.append(r)
    # trim trailing Nones for tidiness
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def _evenly_shardable(pspec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """pjit *arguments* need exact divisibility (internal constraints may
    pad, args may not): replicate any dim that doesn't divide evenly."""
    parts = list(pspec) + [None] * (len(shape) - len(pspec))
    out = []
    for p, s in zip(parts, shape):
        if p is None:
            out.append(None)
            continue
        axes = p if isinstance(p, tuple) else (p,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        out.append(p if (s % n == 0 and s >= n) else None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_pspecs(spec_tree: Any, rules: Rules) -> Any:
    return jax.tree.map(
        lambda p: logical_to_pspec(p.axes, rules), spec_tree,
        is_leaf=lambda x: isinstance(x, PSpec))


def tree_shardings(spec_tree: Any, mesh: Mesh,
                   rules: Optional[Rules] = None) -> Any:
    rules = _filter_axes(rules or BASE_RULES, mesh)

    def f(p: PSpec):
        ps = logical_to_pspec(p.axes, rules)
        return NamedSharding(mesh, _evenly_shardable(ps, p.shape, mesh))

    return jax.tree.map(f, spec_tree,
                        is_leaf=lambda x: isinstance(x, PSpec))


def batch_shardings(struct_tree: Any, mesh: Mesh,
                    rules: Optional[Rules] = None) -> Any:
    """Shard the leading (batch) dim of each ShapeDtypeStruct leaf,
    replicating when the batch doesn't divide the dp axes."""
    frules = _filter_axes(rules or BASE_RULES, mesh)
    b_axes = frules.get("batch")

    def f(s):
        ps = P(*((b_axes,) + (None,) * (len(s.shape) - 1)))
        return NamedSharding(mesh, _evenly_shardable(ps, s.shape, mesh))

    return jax.tree.map(f, struct_tree)


# ---------------------------------------------------------------------------
# activation sharding hook (passed into models as `shd`)
# ---------------------------------------------------------------------------
class MeshSharding:
    """Callable applied to activations inside model code:
    ``shd(x, "batch", "seq", "heads", None)``."""

    def __init__(self, mesh: Mesh, rules: Optional[Rules] = None):
        self.mesh = mesh
        self.rules = _filter_axes(rules or BASE_RULES, mesh)

    def __call__(self, x, *axes):
        if self.mesh.empty or np.prod(self.mesh.devices.shape) == 1:
            return x
        ps = logical_to_pspec(tuple(axes[:x.ndim]), self.rules)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, ps))

    def dp_size(self) -> int:
        n = 1
        for a in ("pod", "data"):
            if a in self.mesh.axis_names:
                n *= self.mesh.shape[a]
        return n

    def pspec(self, axes: Tuple[Optional[str], ...]) -> P:
        return logical_to_pspec(axes, self.rules)

    def named(self, *axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(tuple(axes)))

    # -- embedding lookup against a vocab-sharded table -----------------
    def embed_lookup(self, emb, tokens):
        """Decode-path embedding gather: a plain gather against a vocab-
        sharded table makes XLA all-gather the entire table (~1 GB wire
        per decode step).  Instead: shard_map'd local gather + mask +
        psum over the vocab shards — O(B*D) wire."""
        v_axis = self.rules.get("vocab")
        if (self.mesh.empty or v_axis is None
                or emb.shape[0] % self.mesh.shape[v_axis] != 0):
            return emb[tokens]
        b_axes = self.rules.get("batch")
        tok_ps = _evenly_shardable(P(b_axes), tokens.shape, self.mesh)

        def lookup(e, tok):
            vshard = e.shape[0]
            lo = jax.lax.axis_index(v_axis) * vshard
            local = jnp.clip(tok - lo, 0, vshard - 1)
            x = e[local]
            mask = ((tok >= lo) & (tok < lo + vshard))[:, None]
            return jax.lax.psum(jnp.where(mask, x, jnp.zeros_like(x)),
                                v_axis)

        out_ps = P(*(tuple(tok_ps) + (None,)))
        return jax.shard_map(
            lookup, mesh=self.mesh,
            in_specs=(P(v_axis, None), tok_ps),
            out_specs=out_ps, check_vma=False)(emb, tokens)


# ---------------------------------------------------------------------------
# ZeRO-1: optimizer state sharded over the data axis on top of TP
# ---------------------------------------------------------------------------
def zero1_spec(pspec: P, shape: Tuple[int, ...], mesh: Mesh,
               axis: str = "data") -> P:
    """Additionally shard the largest currently-unsharded dim of an
    optimizer-state tensor over the data axis (divisibility required)."""
    if axis not in mesh.axis_names:
        return pspec
    n = mesh.shape[axis]
    parts = list(pspec) + [None] * (len(shape) - len(pspec))
    best, best_size = None, 0
    for i, (p, s) in enumerate(zip(parts, shape)):
        if p is None and s % n == 0 and s >= n and s > best_size:
            best, best_size = i, s
    if best is None:
        return pspec
    parts[best] = axis
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def zero1_shardings(spec_tree: Any, mesh: Mesh,
                    rules: Optional[Rules] = None) -> Any:
    rules = _filter_axes(rules or BASE_RULES, mesh)
    pspecs = tree_pspecs(spec_tree, rules)

    def f(p: PSpec, ps: P):
        ps = _evenly_shardable(ps, p.shape, mesh)
        return NamedSharding(mesh, zero1_spec(ps, p.shape, mesh))

    return jax.tree.map(f, spec_tree, pspecs,
                        is_leaf=lambda x: isinstance(x, (PSpec, P)))
