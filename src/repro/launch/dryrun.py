"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: every cell
must lower, SPMD-partition and compile for the 16x16 single-pod mesh and
the 2x16x16 multi-pod mesh; ``memory_analysis()`` proves per-chip fit and
``cost_analysis()`` + HLO collective parsing feed the roofline table
(EXPERIMENTS.md §Roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape decode_32k [--multi-pod] [--all] [--out results.json]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
from typing import Any, Dict, Optional, Tuple   # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.config import (KIND_DECODE, KIND_PREFILL, KIND_TRAIN, SHAPES,
                          ModelConfig, ShapeConfig, shape_applicable)
from repro.configs import REGISTRY, get_config
from repro.distributed import sharding as shlib
from repro.launch import hlocost
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models.model import build_model
from repro.training import train as train_mod
from repro.training.optimizer import AdamWConfig

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "u32": 4, "s32": 4,
                "u64": 8, "s64": 8, "u16": 2, "s16": 2, "u8": 1, "s8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+(?:\([^)]*\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_TUPLE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> float:
    b = _DTYPE_BYTES.get(dtype, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return float(n * b)


def parse_collectives(hlo: str) -> Dict[str, Dict[str, float]]:
    """Per-device wire-byte estimate per collective kind.

    Result shapes in SPMD HLO are per-device shards.  Wire factors (ring
    algorithms over a group of g): all-gather moves (g-1)/g of the result;
    all-reduce 2(g-1)/g of the tensor; reduce-scatter (g-1)/g of the
    input (~= result*(g-1)); all-to-all / collective-permute ~= result.
    """
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        if "-done" in line.split("=")[1][:40]:
            continue
        # result bytes (sum over tuple elements if tuple-shaped)
        lhs = line.split(" = ", 1)[1]
        head = lhs.split("(", 1)[0]
        rbytes = sum(_shape_bytes(d, s) for d, s in _TUPLE_RE.findall(head))
        g = 2
        gm = _GROUPS_RE.search(line)
        if gm:
            g = max(2, len(gm.group(1).split(",")))
        if kind == "all-gather":
            wire = rbytes * (g - 1) / g
        elif kind == "all-reduce":
            wire = 2.0 * rbytes * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = rbytes * (g - 1)
        else:
            wire = rbytes
        slot = out.setdefault(kind, {"count": 0, "bytes": 0.0, "wire": 0.0})
        slot["count"] += 1
        slot["bytes"] += rbytes
        slot["wire"] += wire
    return out


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------
OPTS = {"sp": False, "defer_grad": False, "bf16_scores": False,
        "bf16_grads": False, "unroll": 1, "ep": False,
        "kv_dtype": "bfloat16"}


def _rules_for(shape: ShapeConfig, cfg: Optional[ModelConfig] = None):
    if OPTS["ep"] and cfg is not None and cfg.n_experts > 0:
        return shlib.EP_RULES          # MoE archs only
    if shape.name.startswith("long"):
        return shlib.LONG_CONTEXT_RULES
    if OPTS["sp"] and shape.kind == KIND_TRAIN:
        return shlib.SP_RULES
    return shlib.BASE_RULES


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               *, donate: bool = True, pad_heads: bool = False):
    """Returns (lowered, aux) for one (arch x shape) on `mesh`."""
    if pad_heads:
        from dataclasses import replace as _replace
        from repro.config import padded_head_layout
        padded = padded_head_layout(cfg, mesh.shape.get("model", 1))
        if padded:
            cfg = _replace(cfg, internal_pad_q_heads=padded)
    if OPTS["ep"] and cfg.n_experts:
        from dataclasses import replace as _replace
        tp = mesh.shape.get("model", 1)
        pe = ((cfg.n_experts + tp - 1) // tp) * tp
        cfg = _replace(cfg, internal_pad_experts=pe)
    rules = _rules_for(shape, cfg)
    shd = shlib.MeshSharding(mesh, rules)
    # steady-state decode benchmark: position-aligned batch (the ragged
    # path is exercised by the live engine + tests; TPU ragged fast path
    # is the paged-attention Pallas kernel)
    model = build_model(cfg, shd, aligned_decode=True,
                        scan_unroll=OPTS["unroll"],
                        kv_dtype=OPTS["kv_dtype"])
    p_sh = shlib.tree_shardings(model.specs, mesh, rules)
    aparams = model.abstract_params()
    ins = model.input_specs(shape)
    in_batch_sh = shlib.batch_shardings(ins, mesh, rules)

    if shape.kind == KIND_TRAIN:
        n_micro = train_mod.pick_n_microbatches(
            cfg, shape, mesh.shape.get("data", 1)
            * mesh.shape.get("pod", 1),
            sp_degree=mesh.shape.get("model", 1) if OPTS["sp"] else 1)
        step = train_mod.make_train_step(
            model, n_micro=n_micro, defer_grad_sync=OPTS["defer_grad"],
            bf16_grad_sync=OPTS["bf16_grads"])
        opt_sh = train_mod.train_shardings(model, mesh, ins,
                                           rules=rules).opt
        aopt = train_mod.abstract_opt_state(model)
        with jax.set_mesh(mesh):
            lowered = jax.jit(
                step,
                in_shardings=(p_sh, opt_sh, in_batch_sh),
                out_shardings=(p_sh, opt_sh, None),
                donate_argnums=(0, 1) if donate else (),
            ).lower(aparams, aopt, ins)
        return lowered, {"n_micro": n_micro, "entry": "train_step"}

    if shape.kind == KIND_PREFILL:
        state_sh = shlib.tree_shardings(
            model.decode_state_specs(shape.global_batch, shape.seq_len),
            mesh, rules)
        with jax.set_mesh(mesh):
            lowered = jax.jit(
                model.prefill,
                in_shardings=(p_sh, in_batch_sh),
                out_shardings=(None, state_sh),
            ).lower(aparams, ins)
        return lowered, {"entry": "prefill"}

    # decode
    astate = model.abstract_decode_state(shape.global_batch, shape.seq_len)
    state_sh = shlib.tree_shardings(
        model.decode_state_specs(shape.global_batch, shape.seq_len),
        mesh, rules)
    tok_sh = shlib.batch_shardings(
        jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32), mesh, rules)
    with jax.set_mesh(mesh):
        lowered = jax.jit(
            model.decode_step,
            in_shardings=(p_sh, state_sh, tok_sh),
            out_shardings=(None, state_sh),
            donate_argnums=(1,) if donate else (),
        ).lower(aparams, astate,
                jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32))
    return lowered, {"entry": "serve_step(decode)"}


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------
def roofline(cfg: ModelConfig, shape: ShapeConfig, compiled,
             summary, n_devices: int) -> Dict[str, Any]:
    ca = compiled.cost_analysis() or {}
    flops = summary.flops                        # per-device, trip-adjusted
    byts = summary.bytes_native                  # TPU-native bf16 widths
    wire = summary.wire_bytes
    colls = summary.collectives
    t_comp = flops / PEAK_FLOPS_BF16
    t_mem = byts / HBM_BW
    t_coll = wire / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    n = cfg.active_param_count()
    if shape.kind == KIND_TRAIN:
        model_flops = 6.0 * n * shape.tokens
    elif shape.kind == KIND_PREFILL:
        model_flops = 2.0 * n * shape.tokens
    else:
        model_flops = 2.0 * n * shape.global_batch
    model_flops_dev = model_flops / n_devices
    return {
        "flops_per_dev": flops,
        "bytes_per_dev": byts,
        "bytes_per_dev_raw": summary.bytes_accessed,
        "wire_bytes_per_dev": wire,
        "t_compute": t_comp, "t_memory": t_mem, "t_collective": t_coll,
        "bottleneck": bottleneck,
        "model_flops_per_dev": model_flops_dev,
        "useful_flops_frac": (model_flops_dev / flops) if flops else 0.0,
        "step_time_bound": max(t_comp, t_mem, t_coll),
        "roofline_frac": (min(1.0, model_flops_dev / PEAK_FLOPS_BF16
                              / max(t_comp, t_mem, t_coll))
                          if max(t_comp, t_mem, t_coll) > 0 else 0.0),
        "collectives": colls,
        "trip_counts": summary.trip_counts,
        "xla_cost_analysis": {"flops": float(ca.get("flops", 0.0)),
                              "bytes": float(ca.get("bytes accessed", 0.0))},
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True,
             pad_heads: bool = False) -> Optional[Dict[str, Any]]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        if verbose:
            print(f"SKIP {arch} x {shape_name}: {why}")
        return None
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()
    lowered, aux = lower_cell(cfg, shape, mesh, pad_heads=pad_heads)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    summary = hlocost.analyze(hlo)
    rf = roofline(cfg, shape, compiled, summary, n_dev)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "entry": aux.get("entry"),
        "n_micro": aux.get("n_micro"),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "mem": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_hint": mem.argument_size_in_bytes
                         + mem.temp_size_in_bytes
                         + mem.output_size_in_bytes
                         - mem.alias_size_in_bytes,
        },
        **rf,
    }
    if verbose:
        print(f"OK {arch} x {shape_name} [{result['mesh']}] "
              f"compile={t_compile:.1f}s "
              f"flops/dev={rf['flops_per_dev']:.3e} "
              f"bytes/dev={rf['bytes_per_dev']:.3e} "
              f"wire/dev={rf['wire_bytes_per_dev']:.3e} "
              f"bottleneck={rf['bottleneck']} "
              f"roofline={rf['roofline_frac']:.2%}")
        print(f"   mem/dev: args={mem.argument_size_in_bytes/1e9:.2f}GB "
              f"temp={mem.temp_size_in_bytes/1e9:.2f}GB "
              f"aliased={mem.alias_size_in_bytes/1e9:.2f}GB")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--pad-heads", action="store_true",
                    help="pad q heads per GQA group to divide TP "
                         "(perf optimization, §Perf)")
    ap.add_argument("--sp", action="store_true",
                    help="sequence-parallel residual stream (train)")
    ap.add_argument("--defer-grad", action="store_true",
                    help="single deferred grad all-reduce per step")
    ap.add_argument("--bf16-scores", action="store_true",
                    help="bf16 attention score buffers")
    ap.add_argument("--bf16-grads", action="store_true",
                    help="bf16 gradient all-reduce (half grad wire)")
    ap.add_argument("--int8-kv", action="store_true",
                    help="int8 KV cache with per-token-head scales "
                         "(paper §VI quantization compatibility)")
    ap.add_argument("--static-causal", action="store_true",
                    help="unrolled causal q-chunks (halves attention "
                         "flops vs masked rectangle)")
    ap.add_argument("--ep", action="store_true",
                    help="expert parallelism: shard (padded) experts "
                         "over the model axis")
    ap.add_argument("--unroll", type=int, default=1,
                    help="layer-scan unroll factor (reduces in-loop "
                         "collective count)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    OPTS["sp"] = args.sp
    OPTS["defer_grad"] = args.defer_grad
    OPTS["bf16_grads"] = args.bf16_grads
    OPTS["unroll"] = args.unroll
    OPTS["ep"] = args.ep
    if args.int8_kv:
        OPTS["kv_dtype"] = "int8"
    if args.bf16_scores:
        from repro.models import attention as _attn
        _attn.SCORES_BF16 = True
    if args.static_causal:
        from repro.models import attention as _attn
        _attn.STATIC_CAUSAL = True
    archs = list(REGISTRY) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results, failures = [], []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    r = run_cell(arch, shape, multi_pod=mp,
                                 pad_heads=args.pad_heads)
                    if r:
                        results.append(r)
                except Exception as e:   # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)[:500]))
                    print(f"FAIL {arch} x {shape} multi_pod={mp}: "
                          f"{repr(e)[:300]}", file=sys.stderr)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"results": results,
                       "failures": failures}, f, indent=1)
        print(f"wrote {args.out} ({len(results)} cells, "
              f"{len(failures)} failures)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
