"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs real steps on the available devices (CPU smoke / small mesh) with
checkpoint/restart: kill it at any step and re-launch with the same
--ckpt-dir — it resumes from the latest manifest bit-exactly (the data
pipeline is a pure function of the step counter).
"""
from __future__ import annotations

import argparse
import os
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SHAPES, ShapeConfig, reduce_config
from repro.configs import get_config
from repro.distributed import sharding as shlib
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.training import checkpoint as ckpt_mod
from repro.training import data as data_mod
from repro.training import optimizer as opt_mod
from repro.training import train as train_mod
from repro.training.optimizer import AdamWConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--crash-at", type=int, default=-1,
                    help="fault-injection: exit(17) at this step")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    mesh = make_host_mesh()
    shd = shlib.MeshSharding(mesh)
    model = build_model(cfg, shd)
    adamw = AdamWConfig(lr=args.lr, total_steps=max(args.steps, 100),
                        warmup_steps=max(5, args.steps // 20))
    step_fn = jax.jit(train_mod.make_train_step(
        model, adamw=adamw, n_micro=args.n_micro,
        grad_compress=args.grad_compress))

    data = data_mod.SyntheticLM(data_mod.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch))

    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = opt_mod.init_state(params)
    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = ckpt_mod.CheckpointManager(args.ckpt_dir)
        latest = mgr.latest_step()
        if latest is not None:
            (params, opt_state), _ = mgr.restore((params, opt_state))
            start_step = latest
            print(f"resumed from step {start_step}")

    def make_batch(step):
        raw = data.batch(step)
        out = {"tokens": jnp.asarray(raw["tokens"]),
               "labels": jnp.asarray(raw["labels"])}
        if cfg.family == "vlm":
            out["patches"] = jnp.zeros(
                (args.global_batch, cfg.n_patches, cfg.d_model),
                jnp.bfloat16)
        if cfg.family == "encdec":
            out["frames"] = jnp.zeros(
                (args.global_batch, cfg.enc_len, cfg.d_model), jnp.bfloat16)
        return out

    t0 = time.time()
    for step in range(start_step, args.steps):
        if step == args.crash_at:
            print(f"fault injection: crashing at step {step}")
            raise SystemExit(17)
        params, opt_state, metrics = step_fn(params, opt_state,
                                             make_batch(step))
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            print(f"step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({time.time() - t0:.1f}s)")
        if mgr and ((step + 1) % args.ckpt_every == 0
                    or step == args.steps - 1):
            m = mgr.save(step + 1, (params, opt_state))
            print(f"  ckpt @{step + 1}: "
                  f"new {m['delta']['new_bytes'] / 1e6:.1f}MB "
                  f"reused {m['delta']['reused_bytes'] / 1e6:.1f}MB")
    print("final loss:", float(metrics["loss"]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
