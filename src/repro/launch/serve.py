"""Serving launcher CLI: single engine or a multi-replica cluster.

``python -m repro.launch.serve --arch llama3.2-1b --reduced --requests 32``

The cluster machinery lives in ``repro/serving/cluster.py``
(``ReplicaCluster`` + pluggable routing policies); this module is a thin
command line over it and demonstrates the large-scale serving
properties:

  * session affinity via the same consistent-hash ring as the RDMA tier
    (sessions stick to replicas -> prefix caches stay warm) — or
    round-robin / least-loaded routing via ``--routing``;
  * replica failure (``--fail-replica``): the router drops the node,
    in-flight requests re-dispatch to a successor replica and lost KV
    blocks are re-prefilled — the paper's graceful-degradation story;
  * elastic scale-out (``--add-replica``): a replica joins mid-run,
    remapping ~1/n of sessions;
  * wall-clock serving (``--frontend``): the thread-pumped
    ``ServingFrontend`` under open-loop Poisson load from
    ``traces/loadgen.py``, with SLO-aware admission
    (``--qps``/``--duration``/``--ttft-budget-ms``/``--slo-action``)
    and a goodput/shed/TTFT report.

See ``docs/SERVING.md`` for the operations guide.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.config import reduce_config
from repro.configs import get_config
from repro.serving import EngineConfig, SamplingParams, ServingEngine
from repro.serving.cluster import (ROUTERS, ReplicaCluster)  # noqa: F401
#                                  (ReplicaCluster re-exported here for
#                                   backward compatibility with callers
#                                   of the pre-promotion location)


def _serve_frontend(args) -> int:
    """Real-clock open-loop serving: background pump thread + Poisson
    schedule, SLO admission, goodput/TTFT report."""
    from repro.serving.frontend import ServingFrontend, SLOConfig
    from repro.traces.loadgen import offered_summary, trace_load
    from repro.traces.serving_replay import ServingReplayConfig, build_engine

    rcfg = ServingReplayConfig(workload=args.workload, seed=args.seed,
                               policy=args.policy, async_transfers=False)
    engine = build_engine(rcfg)
    budget = (args.ttft_budget_ms / 1e3 if args.ttft_budget_ms > 0
              else float("inf"))
    fe = ServingFrontend(engine,
                         slo=SLOConfig(ttft_budget_s=budget,
                                       action=args.slo_action))
    arrivals = trace_load(args.workload, args.qps,
                          duration_s=args.duration, seed=args.seed)
    print(f"offered: {offered_summary(arrivals)}")
    fe.start()
    t0 = time.monotonic()
    for a in arrivals:
        dt = (t0 + a.t) - time.monotonic()
        if dt > 0:
            time.sleep(dt)
        fe.submit(list(a.prompt),
                  params=SamplingParams(max_new_tokens=a.max_new),
                  session_id=a.session_id, arrival_t=t0 + a.t,
                  block_types=list(a.block_types), tool=a.tool,
                  retain_blocks=not a.last_turn)
    fe.stop(drain=True)
    fe.check_ledger()
    st = fe.stats()
    print(f"served {st['done']}/{st['offered']} requests "
          f"({st['shed']} shed, goodput {st['goodput']}) in "
          f"{time.monotonic() - t0:.1f}s")
    print(f"ttft p50/p99: {st['ttft_p50'] * 1e3:.1f}/"
          f"{st['ttft_p99'] * 1e3:.1f} ms  "
          f"tbt p50/p99: {st['tbt_p50'] * 1e3:.1f}/"
          f"{st['tbt_p99'] * 1e3:.1f} ms  "
          f"est step: {st['est_step_s'] * 1e3:.2f} ms")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--routing", default="affine", choices=sorted(ROUTERS),
                    help="cluster request routing policy")
    ap.add_argument("--fail-replica", action="store_true",
                    help="kill a replica mid-run (fault-tolerance demo)")
    ap.add_argument("--add-replica", action="store_true",
                    help="scale out by one replica mid-run")
    ap.add_argument("--policy", default="bayesian",
                    choices=["bayesian", "ema", "lru"])
    ap.add_argument("--frontend", action="store_true",
                    help="wall-clock ServingFrontend under open-loop "
                         "Poisson load (real threads, real clock)")
    ap.add_argument("--workload", default="lmsys",
                    help="loadgen workload (sharegpt/lmsys/agentic/"
                         "file:<path>)")
    ap.add_argument("--qps", type=float, default=8.0,
                    help="offered Poisson rate for --frontend")
    ap.add_argument("--duration", type=float, default=5.0,
                    help="load duration in seconds for --frontend")
    ap.add_argument("--ttft-budget-ms", type=float, default=0.0,
                    help="SLO TTFT budget (0 = no admission control)")
    ap.add_argument("--slo-action", default="shed",
                    choices=["shed", "queue"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.frontend:
        return _serve_frontend(args)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    ecfg = EngineConfig(max_len=512, kv_budget_bytes=64e6,
                        policy=args.policy)
    rng = np.random.default_rng(0)
    system = [int(t) for t in rng.integers(0, cfg.vocab_size, size=256)]

    t0 = time.time()
    if args.replicas == 1:
        eng = ServingEngine(cfg, ecfg)
        for i in range(args.requests):
            user = [int(t) for t in rng.integers(0, cfg.vocab_size,
                                                 size=32)]
            eng.submit(system + user,
                       params=SamplingParams(max_new_tokens=args.max_new),
                       session_id=f"s{i % 4}", block_type="system_prompt")
        stats = eng.run()
        eng.shutdown()
    else:
        cluster = ReplicaCluster(cfg, ecfg, n_replicas=args.replicas,
                                 routing=args.routing)
        for i in range(args.requests):
            user = [int(t) for t in rng.integers(0, cfg.vocab_size,
                                                 size=32)]
            cluster.submit(system + user, session_id=f"s{i % 4}",
                           params=SamplingParams(max_new_tokens=args.max_new),
                           block_type="system_prompt")
            if args.fail_replica and i == args.requests // 2:
                cluster.step()
                victim = sorted(cluster.engines)[0]
                lost = cluster.fail_replica(victim)
                print(f"killed {victim}, re-dispatched {lost} requests")
            if args.add_replica and i == args.requests // 2:
                name = cluster.add_replica()
                print(f"scaled out: {name} joined "
                      f"({cluster.n_replicas} replicas)")
        stats = cluster.run()
        cluster.shutdown()
    dt = time.time() - t0
    done = (stats["scheduler"]["done"] if args.replicas == 1
            else stats["done"])
    print(f"served {done} requests in {dt:.1f}s")
    if args.replicas == 1:
        s = stats["scheduler"]
        c = stats["cache"]
        print(f"ttft p50/p99: {s['ttft_p50'] * 1e3:.0f}/"
              f"{s['ttft_p99'] * 1e3:.0f} ms  "
              f"prefix-hit blocks: {s['prefix_hit_blocks']}  "
              f"hot hit-rate: {c['hit_rate_hot']:.2%}")
    else:
        print(f"routing: {stats['routing']}  "
              f"fleet hot hit-rate: {stats['fleet']['hit_rate_hot']:.2%}")
        print(f"re-dispatched after failure: {stats['redispatched']}  "
              f"re-prefilled tokens: {stats['reprefill_tokens']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
