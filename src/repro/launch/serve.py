"""Serving launcher: single engine or a simulated multi-replica cluster.

``python -m repro.launch.serve --arch llama3.2-1b --reduced --requests 32``

The cluster dispatcher demonstrates the large-scale serving properties:
  * session affinity via the same consistent-hash ring as the RDMA tier
    (sessions stick to replicas -> prefix caches stay warm);
  * replica failure: the ring drops the node, in-flight requests
    re-dispatch to the successor replica (lost KV blocks are re-prefilled
    — exactly the paper's graceful-degradation story);
  * elastic scale-out: adding a replica remaps ~1/n of sessions.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

import numpy as np

from repro.config import reduce_config
from repro.configs import get_config
from repro.core.tiers import ConsistentHashRing
from repro.serving import EngineConfig, SamplingParams, ServingEngine
from repro.serving.request import Request


class ReplicaCluster:
    """N engine replicas + consistent-hash session dispatch."""

    def __init__(self, cfg, engine_cfg: EngineConfig, n_replicas: int = 2):
        self.engines: Dict[str, ServingEngine] = {}
        self.ring = ConsistentHashRing()
        self.cfg = cfg
        self.ecfg = engine_cfg
        for i in range(n_replicas):
            self.add_replica(f"replica{i}")
        self.redispatched = 0

    def add_replica(self, name: str) -> None:
        # replicas share nothing; params re-init deterministically
        self.engines[name] = ServingEngine(self.cfg, self.ecfg)
        self.ring.add_node(name)

    def fail_replica(self, name: str) -> int:
        """Kill a replica; requeue its unfinished requests elsewhere."""
        eng = self.engines.pop(name)
        self.ring.remove_node(name)
        lost: List[Request] = list(eng.scheduler.waiting) \
            + list(eng.scheduler.running.values()) \
            + list(eng.scheduler.preempted)
        for req in lost:
            req.phase = req.phase.WAITING
            req.generated.clear()
            req.slot = -1
            req.block_ids = []
            target = self.ring.lookup(req.session_id or str(req.request_id))
            self.engines[target].scheduler.submit(req)
            self.redispatched += 1
        eng.shutdown()
        return len(lost)

    def submit(self, prompt, *, session_id: str, **kw) -> Request:
        target = self.ring.lookup(session_id)
        return self.engines[target].submit(prompt, session_id=session_id,
                                           **kw)

    def run(self, max_steps: int = 10_000) -> dict:
        steps = 0
        while steps < max_steps and any(e.scheduler.has_work()
                                        for e in self.engines.values()):
            for e in self.engines.values():
                if e.scheduler.has_work():
                    e.step()
            steps += 1
        agg = {"replicas": {n: e.stats() for n, e in self.engines.items()},
               "redispatched": self.redispatched}
        agg["done"] = sum(s["scheduler"]["done"]
                          for s in agg["replicas"].values())
        return agg


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--fail-replica", action="store_true",
                    help="kill replica0 mid-run (fault-tolerance demo)")
    ap.add_argument("--policy", default="bayesian",
                    choices=["bayesian", "ema", "lru"])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    ecfg = EngineConfig(max_len=512, kv_budget_bytes=64e6,
                        policy=args.policy)
    rng = np.random.default_rng(0)
    system = [int(t) for t in rng.integers(0, cfg.vocab_size, size=256)]

    t0 = time.time()
    if args.replicas == 1:
        eng = ServingEngine(cfg, ecfg)
        for i in range(args.requests):
            user = [int(t) for t in rng.integers(0, cfg.vocab_size,
                                                 size=32)]
            eng.submit(system + user,
                       params=SamplingParams(max_new_tokens=args.max_new),
                       session_id=f"s{i % 4}", block_type="system_prompt")
        stats = eng.run()
    else:
        cluster = ReplicaCluster(cfg, ecfg, n_replicas=args.replicas)
        for i in range(args.requests):
            user = [int(t) for t in rng.integers(0, cfg.vocab_size,
                                                 size=32)]
            cluster.submit(system + user, session_id=f"s{i % 4}",
                           params=SamplingParams(max_new_tokens=args.max_new),
                           block_type="system_prompt")
            if args.fail_replica and i == args.requests // 2:
                for e in cluster.engines.values():
                    e.step()
                lost = cluster.fail_replica(sorted(cluster.engines)[0])
                print(f"killed replica, re-dispatched {lost} requests")
        stats = cluster.run()
    dt = time.time() - t0
    done = (stats["scheduler"]["done"] if args.replicas == 1
            else stats["done"])
    print(f"served {done} requests in {dt:.1f}s")
    if args.replicas == 1:
        s = stats["scheduler"]
        c = stats["cache"]
        print(f"ttft p50/p99: {s['ttft_p50'] * 1e3:.0f}/"
              f"{s['ttft_p99'] * 1e3:.0f} ms  "
              f"prefix-hit blocks: {s['prefix_hit_blocks']}  "
              f"hot hit-rate: {c['hit_rate_hot']:.2%}")
    else:
        print(f"re-dispatched after failure: {stats['redispatched']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
