"""Post-optimization HLO cost model with while-loop trip-count recovery.

XLA's built-in ``compiled.cost_analysis()`` visits every computation once —
a ``lax.scan`` over 40 layers contributes its body cost a single time, so
flops / bytes / collective counts are understated by the trip count.  This
module parses ``compiled.as_text()`` (the optimized, SPMD-partitioned,
fused HLO) and:

  * recovers each while loop's static trip count from its condition
    computation (scan conditions compare the induction variable against a
    constant);
  * attributes every op to its computation and multiplies by the product
    of enclosing-loop trip counts;
  * models HBM traffic as (operand bytes + result bytes) of each
    *top-level* op per computation — post-fusion, this approximates what
    actually moves through HBM (fusions count their boundary buffers,
    not their internals);
  * counts matmul flops from dot shapes (2 * result_elems * contraction)
    and elementwise flops as result_elems;
  * tallies collective wire bytes with ring-algorithm factors.

All numbers are per-device: SPMD HLO shapes are the per-device shards.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "u64": 8, "s64": 8, "u32": 4, "s32": 4,
                "u16": 2, "s16": 2, "u8": 1, "s8": 1, "pred": 1,
                "c64": 8, "c128": 16, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(%[\w.\-]+|ROOT\s+%[\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%[\w.\-]+")
_WHILE_ATTR_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id",
               "iota", "custom-call"}
_MOVE_OPS = {"copy", "convert", "transpose", "broadcast", "reshape",
             "slice", "dynamic-slice", "dynamic-update-slice", "scatter",
             "gather", "reverse", "concatenate", "pad", "select",
             "reduce-scatter", "all-gather", "all-reduce", "all-to-all",
             "collective-permute"}
_CONTROL = {"while", "conditional", "call"}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes_of(text: str, native: bool = False) -> float:
    """Byte size of all shapes in `text`.  native=True charges floating
    types at most 2 bytes/elem: XLA:CPU promotes bf16 dots to f32 and
    inserts convert/transpose shims a native-bf16 TPU pipeline would not
    emit, so inference-path traffic is modelled at bf16 width."""
    tot = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        w = _DTYPE_BYTES[dt]
        if native and dt in ("f32", "f64"):
            w = 2
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        tot += n * w
    return tot


def _result_elems(text: str) -> float:
    m = _SHAPE_RE.search(text)
    if not m:
        return 0.0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return float(n)


@dataclass
class Op:
    name: str
    kind: str
    result_text: str            # shapes on the lhs
    operands: List[str]
    line: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # op name -> lhs


@dataclass
class CostSummary:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    wire_bytes: float = 0.0
    bytes_native: float = 0.0       # floats charged at bf16 width
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)
    trip_counts: Dict[str, int] = field(default_factory=dict)
    detail: Optional[List] = None


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if line and not line[0].isspace():
            hdr = _COMP_HDR_RE.match(line)
            if hdr:
                cur = Computation(hdr.group(2))
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name = m.group(1).replace("ROOT", "").strip().lstrip("%")
        rhs = m.group(2)
        # split lhs shapes from op kind: "<shape(s)> <kind>(operands...)"
        km = re.search(r"\)?\s*([a-z][\w\-]*)\(", rhs)
        kind = km.group(1) if km else "unknown"
        lhs = rhs[:km.start()] if km else rhs
        paren = rhs[km.end():] if km else ""
        depth, args = 1, []
        buf = ""
        for ch in paren:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args.append(buf)
                    break
            if depth >= 1:
                buf += ch
        operands = _OPERAND_RE.findall(args[0] if args else "")
        op = Op(name=name, kind=kind, result_text=lhs,
                operands=[o.lstrip("%") for o in operands], line=line)
        cur.ops.append(op)
        cur.shapes[name] = lhs
    return comps


def _trip_count(cond: Computation) -> int:
    """Scan conditions: compare(induction, constant(N)), direction=LT."""
    const_vals = []
    for op in cond.ops:
        if op.kind == "constant":
            m = re.search(r"constant\((\d+)\)", op.line)
            if m:
                const_vals.append(int(m.group(1)))
    for op in cond.ops:
        if op.kind == "compare" and "direction=LT" in op.line and const_vals:
            return max(1, max(const_vals))
    return max(1, max(const_vals)) if const_vals else 1


def _collective_wire(op: Op) -> Tuple[str, float, float]:
    """Wire bytes at TPU-native widths (f32 charged 2B: XLA:CPU promotes
    bf16 math to f32 and the promoted collectives with it)."""
    kind = op.kind.replace("-start", "")
    shapes = [(min(_DTYPE_BYTES.get(dt, 4), 2)
               if dt in ("f32", "f64", "bf16", "f16")
               else _DTYPE_BYTES.get(dt, 4), dims)
              for dt, dims in _SHAPE_RE.findall(op.result_text)]
    sizes = []
    for b, dims in shapes:
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        sizes.append(float(n * b))
    if not sizes:
        return kind, 0.0, 0.0
    if len(sizes) == 1:
        rbytes = sizes[0]
    elif kind == "all-gather":
        rbytes = max(sizes)          # (input, output) tuple of -start ops
    elif kind == "reduce-scatter":
        rbytes = min(sizes)
    else:
        rbytes = sum(sizes) / 2.0
    g = 2
    gm = _GROUPS_RE.search(op.line)
    if gm:
        g = max(2, len(gm.group(1).split(",")))
    else:
        gi = _GROUPS_IOTA_RE.search(op.line)
        if gi:
            g = max(2, int(gi.group(2)))
    if kind == "all-gather":
        wire = rbytes * (g - 1) / g
    elif kind == "all-reduce":
        wire = 2.0 * rbytes * (g - 1) / g
    elif kind == "reduce-scatter":
        wire = rbytes * (g - 1)
    else:
        wire = rbytes
    return kind, rbytes, wire


def analyze(hlo: str, debug: bool = False) -> CostSummary:
    comps = parse_module(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line)
            if m:
                entry = m.group(2)
            break
    if entry is None or entry not in comps:
        # fall back: the computation with a while op, else the largest
        entry = max(comps, key=lambda c: len(comps[c].ops)) if comps else ""

    summary = CostSummary()
    if debug:
        summary.detail = []
    visited_stack: List[str] = []

    # ops whose HBM traffic is slice-sized, not full-operand-sized: an
    # in-place dynamic-update-slice on a donated KV cache moves only the
    # update; gathers/dynamic-slices read only the selected rows.
    SLICE_OPS = {"dynamic-update-slice": "update",
                 "dynamic-slice": "result",
                 "gather": "result",
                 "scatter": "update"}

    def _slice_traffic(op: Op, comp: Computation) -> Optional[float]:
        """2x the moved-slice bytes for slice-like ops, else None."""
        kind = op.kind
        if kind not in SLICE_OPS:
            return None
        if SLICE_OPS[kind] == "result":
            return 2.0 * _shape_bytes_of(op.result_text)
        # update operand: dus -> operand 1; scatter -> last operand
        idx = 1 if kind == "dynamic-update-slice" else len(op.operands) - 1
        if idx < len(op.operands):
            return 2.0 * _shape_bytes_of(comp.shapes.get(op.operands[idx],
                                                         ""))
        return 2.0 * _shape_bytes_of(op.result_text)

    # layout/precision shims: XLA:CPU materializes f32 converts, masking
    # selects and transposed copies around bf16 dots; a TPU pipeline fuses
    # these into the consumer (which already counts its operand reads).
    # A fusion is a shim iff it performs no arithmetic.  Excluded from the
    # native byte count only.
    _ARITH = {"dot", "add", "subtract", "multiply", "divide", "exponential",
              "exponential-minus-one", "log", "log-plus-one", "rsqrt",
              "sqrt", "cbrt", "tanh", "logistic", "power", "reduce",
              "reduce-window", "convolution", "maximum", "minimum", "abs",
              "negate", "sign", "cosine", "sine", "atan2", "remainder",
              "floor", "ceil", "round-nearest-afz", "clamp", "map", "sort",
              "rng", "rng-bit-generator", "scatter"}

    def _is_pure_move(op: Op, callee: Optional[Computation]) -> bool:
        if op.kind in ("copy", "convert", "transpose", "reshape",
                       "broadcast", "bitcast-convert"):
            return True
        if op.kind == "fusion" and callee is not None:
            return not any(i.kind in _ARITH for i in callee.ops)
        return False

    def _fusion_traffic(op: Op, comp: Computation,
                        callee: Optional[Computation],
                        native: bool) -> float:
        """Boundary traffic of a fusion, discounting in-place whole-buffer
        pass-throughs: a dus inside the fusion whose target is as large as
        the fusion result means the big buffer is carried through (donated
        / loop-carried) and only the update slice actually moves."""
        result = _shape_bytes_of(op.result_text, native)
        total = result + sum(
            _shape_bytes_of(comp.shapes.get(o, ""), native)
            for o in op.operands)
        if callee is not None:
            for iop in callee.ops:
                if iop.kind == "dynamic-update-slice" \
                        and len(iop.operands) >= 2:
                    buf = _shape_bytes_of(
                        callee.shapes.get(iop.operands[0],
                                          iop.result_text), native)
                    upd = _shape_bytes_of(
                        callee.shapes.get(iop.operands[1], ""), native)
                    if buf >= 0.5 * result and buf > 4 * upd:
                        total -= 2 * buf - 2 * upd
                elif iop.kind == "dynamic-slice" and iop.operands:
                    # a big buffer feeding the fusion from which only a
                    # slice is read (e.g. one layer of a scanned stack)
                    buf = _shape_bytes_of(
                        callee.shapes.get(iop.operands[0], ""), native)
                    sl = _shape_bytes_of(iop.result_text, native)
                    if buf > 4 * sl and buf > result:
                        total -= buf - sl
        return max(total, 0.0)

    def op_flops(op: Op, comp: Computation) -> float:
        if op.kind == "dot":
            relems = _result_elems(op.result_text)
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
            contr = 1.0
            if m and op.operands:
                lhs_shape = comp.shapes.get(op.operands[0], "")
                sm = _SHAPE_RE.search(lhs_shape)
                if sm:
                    dims = [int(d) for d in sm.group(2).split(",") if d]
                    for ci in m.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            contr *= dims[int(ci)]
            return 2.0 * relems * contr
        if op.kind in ("fusion",):
            # dots/arithmetic inside fusions are counted when walking the
            # fusion computation; the fusion op itself moves data
            return 0.0
        if (op.kind in _NO_TRAFFIC or op.kind in _CONTROL
                or op.kind in _MOVE_OPS
                or op.kind.replace("-start", "") in _MOVE_OPS):
            return 0.0
        return _result_elems(op.result_text)

    def walk(comp_name: str, mult: float, *, fusion_internal: bool = False):
        comp = comps.get(comp_name)
        if comp is None or comp_name in visited_stack:
            return
        visited_stack.append(comp_name)
        for op in comp.ops:
            kind = op.kind
            base = kind.replace("-start", "")
            if base in COLLECTIVES and "-done" not in kind:
                ckind, rbytes, wire = _collective_wire(op)
                slot = summary.collectives.setdefault(
                    ckind, {"count": 0, "bytes": 0.0, "wire": 0.0})
                slot["count"] += mult
                slot["bytes"] += rbytes * mult
                slot["wire"] += wire * mult
                summary.wire_bytes += wire * mult
            if kind == "while":
                m = _WHILE_ATTR_RE.search(op.line)
                if m:
                    cond, body = m.group(1), m.group(2)
                    tm = _TRIP_RE.search(op.line)
                    if tm:
                        trips = int(tm.group(1))
                    else:
                        trips = (_trip_count(comps[cond])
                                 if cond in comps else 1)
                    summary.trip_counts[body] = trips
                    walk(body, mult * trips)
                continue
            if kind in ("call", "conditional", "async-start"):
                m = _CALL_ATTR_RE.search(op.line)
                if m:
                    walk(m.group(1), mult)
                continue
            if kind == "fusion":
                m = _CALL_ATTR_RE.search(op.line)
                callee = comps.get(m.group(1)) if m else None
                if not fusion_internal:
                    traffic = _fusion_traffic(op, comp, callee, False)
                    summary.bytes_accessed += traffic * mult
                    native = 0.0
                    if not _is_pure_move(op, callee):
                        native = _fusion_traffic(op, comp, callee, True) \
                            * mult
                        summary.bytes_native += native
                    if summary.detail is not None and native > 1e8:
                        summary.detail.append(
                            (native, "fusion", op.line.strip()[:120]))
                if m:
                    walk(m.group(1), mult, fusion_internal=True)
                continue
            if kind in _NO_TRAFFIC:
                continue
            if not fusion_internal:
                traffic = _slice_traffic(op, comp)
                if traffic is not None:
                    native_traffic = traffic   # slice bytes already small
                else:
                    traffic = (_shape_bytes_of(op.result_text)
                               + sum(_shape_bytes_of(comp.shapes.get(o, ""))
                                     for o in op.operands))
                    native_traffic = (
                        _shape_bytes_of(op.result_text, True)
                        + sum(_shape_bytes_of(comp.shapes.get(o, ""), True)
                              for o in op.operands))
                if _is_pure_move(op, None):
                    native_traffic = 0.0
                summary.bytes_accessed += traffic * mult
                summary.bytes_native += native_traffic * mult
                if summary.detail is not None and \
                        native_traffic * mult > 1e8:
                    summary.detail.append(
                        (native_traffic * mult, op.kind,
                         op.line.strip()[:120]))
            summary.flops += op_flops(op, comp) * mult
        visited_stack.pop()

    # fusion computations contain the real dots: walk them for flops only
    walk(entry, 1.0)
    # dots living inside fusion computations: count flops with the
    # multiplier of the fusion's parent — handled above via recursion with
    # fusion_internal=True (bytes skipped, flops counted).
    return summary
