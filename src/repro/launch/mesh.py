"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is outer data parallelism across the ICI/DCN boundary.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Tiny mesh for CPU smoke runs (1 device)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh(
        (n // model, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware constants used by the roofline analysis (EXPERIMENTS.md)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
