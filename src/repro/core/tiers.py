"""Six-tier memory hierarchy for KV cache blocks (paper §III-B, Table II).

TPU adaptation (DESIGN.md §Hardware-adaptation): the paper's tiers are
GPU-centric (HBM3 / pinned DRAM via CUDA streams / CXL mmap / cuFile GDS /
ibverbs RDMA / Lustre).  On a TPU pod the same hierarchy maps to:

    Tier 0  device HBM     (jax arrays, donated in-place updates)
    Tier 1  host DRAM      (numpy, pinned-host analogue; async D2H/H2D)
    Tier 2  CXL pool       (mmap-backed store; on v5e hosts this models a
                            CXL 3.0 expander attached to the host)
    Tier 3  NVMe           (file-backed store, O_DIRECT-aligned records)
    Tier 4  remote pool    (consistent-hash ring over ICI/DCN peers —
                            one-sided RDMA read ~ remote host fetch)
    Tier 5  parallel FS    (content-addressed files, dedup via SHA-256)

Every tier implements the uniform ``TierManager`` interface with
thread-safe Allocate / Read / Write / Evict / Stats (paper §IV).  Since
this container has no CXL/NVMe/IB hardware, non-host tiers are backed by
in-memory or file stores and *account* transfer time against the published
bandwidth/latency specs — that accounting is what the trace replay and the
analytical projections consume (paper §V-B methodology).
"""
from __future__ import annotations

import bisect
import hashlib
import itertools
import os
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Published hardware specifications (paper Table II)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TierSpec:
    tier_id: int
    name: str
    bandwidth: float          # bytes / s
    latency: float            # seconds (GPU-observed)
    cost_per_gb_hour: float   # $ / GB / h
    capacity: float           # bytes

    def transfer_time(self, nbytes: float) -> float:
        return self.latency + nbytes / self.bandwidth


GB = 1024 ** 3
TB = 1024 ** 4

# Capacities follow Table IV's cumulative column: 40 GB -> 200 -> 712 ->
# 4.7 TB -> 38+ TB.
PAPER_TIER_SPECS: Tuple[TierSpec, ...] = (
    TierSpec(0, "gpu_hbm", 3.35e12, 100e-9, 0.500, 40 * GB),
    TierSpec(1, "cpu_dram", 204e9, 3e-6, 0.050, 160 * GB),
    TierSpec(2, "cxl_mem", 64e9, 500e-9, 0.030, 512 * GB),
    TierSpec(3, "nvme_gds", 12e9, 10e-6, 0.020, 4 * TB),
    TierSpec(4, "rdma_pool", 50e9, 5e-6, 0.005, 34 * TB),
    TierSpec(5, "parallel_fs", 2e9, 1e-3, 0.001, 1000 * TB),
)

# TPU v5e single-host flavour (DESIGN.md): HBM 16 GB/chip, PCIe host link.
TPU_V5E_TIER_SPECS: Tuple[TierSpec, ...] = (
    TierSpec(0, "tpu_hbm", 819e9, 100e-9, 0.500, 16 * GB),
    TierSpec(1, "host_dram", 128e9, 3e-6, 0.050, 128 * GB),
    TierSpec(2, "cxl_mem", 64e9, 500e-9, 0.030, 512 * GB),
    TierSpec(3, "nvme", 8e9, 20e-6, 0.020, 4 * TB),
    TierSpec(4, "ici_remote", 50e9, 5e-6, 0.005, 34 * TB),
    TierSpec(5, "parallel_fs", 2e9, 1e-3, 0.001, 1000 * TB),
)


# ---------------------------------------------------------------------------
# Stats
# ---------------------------------------------------------------------------
@dataclass
class TierStats:
    reads: int = 0
    writes: int = 0
    evictions: int = 0
    bytes_read: float = 0.0
    bytes_written: float = 0.0
    sim_time: float = 0.0            # accumulated modelled transfer time
    byte_hours: float = 0.0          # for $/Mtok accounting

    def as_dict(self) -> dict:
        return dataclasses_asdict(self)


def dataclasses_asdict(obj) -> dict:
    import dataclasses
    return dataclasses.asdict(obj)


# ---------------------------------------------------------------------------
# TierManager — uniform interface (paper §IV)
# ---------------------------------------------------------------------------
class CapacityError(RuntimeError):
    pass


class TierManager:
    """One memory tier: a block store with capacity + transfer accounting."""

    def __init__(self, spec: TierSpec, *, backing_dir: Optional[str] = None):
        self.spec = spec
        self._store: Dict[str, Optional[np.ndarray]] = {}
        self._sizes: Dict[str, float] = {}
        self._used = 0.0
        self._lock = threading.RLock()
        self.stats = TierStats()
        self.available = True
        self._dir = backing_dir
        if backing_dir:
            os.makedirs(backing_dir, exist_ok=True)

    # -- helpers ------------------------------------------------------------
    def _path(self, block_id: str) -> str:
        assert self._dir
        return os.path.join(self._dir, hashlib.sha256(
            block_id.encode()).hexdigest())

    def _charge(self, nbytes: float, *, read: bool) -> float:
        t = self.spec.transfer_time(nbytes)
        self.stats.sim_time += t
        if read:
            self.stats.reads += 1
            self.stats.bytes_read += nbytes
        else:
            self.stats.writes += 1
            self.stats.bytes_written += nbytes
        return t

    # -- interface ------------------------------------------------------------
    @property
    def used(self) -> float:
        return self._used

    @property
    def free(self) -> float:
        return self.spec.capacity - self._used

    def contains(self, block_id: str) -> bool:
        with self._lock:
            return block_id in self._sizes

    def allocate(self, block_id: str, nbytes: float) -> None:
        with self._lock:
            if not self.available:
                raise CapacityError(f"tier {self.spec.name} unavailable")
            if block_id in self._sizes:
                return
            if self._used + nbytes > self.spec.capacity:
                raise CapacityError(
                    f"tier {self.spec.name}: {nbytes:.0f}B over capacity "
                    f"({self._used:.0f}/{self.spec.capacity:.0f})")
            self._sizes[block_id] = nbytes
            self._store[block_id] = None
            self._used += nbytes

    def write(self, block_id: str, payload: Optional[np.ndarray],
              nbytes: Optional[float] = None) -> float:
        """Returns modelled transfer time (seconds)."""
        with self._lock:
            if block_id not in self._sizes:
                size = float(nbytes if nbytes is not None
                             else (payload.nbytes if payload is not None else 0))
                self.allocate(block_id, size)
            size = self._sizes[block_id]
            if self._dir is not None and payload is not None:
                np.save(self._path(block_id) + ".npy", payload)
                self._store[block_id] = None
            else:
                self._store[block_id] = payload
            return self._charge(size, read=False)

    def read(self, block_id: str) -> Tuple[Optional[np.ndarray], float]:
        """Returns (payload, modelled transfer time)."""
        with self._lock:
            if not self.available:
                raise CapacityError(f"tier {self.spec.name} unavailable")
            if block_id not in self._sizes:
                raise KeyError(block_id)
            size = self._sizes[block_id]
            payload = self._store.get(block_id)
            if payload is None and self._dir is not None:
                path = self._path(block_id) + ".npy"
                if os.path.exists(path):
                    payload = np.load(path)
            return payload, self._charge(size, read=True)

    def evict(self, block_id: str) -> None:
        with self._lock:
            if block_id not in self._sizes:
                return
            self._used -= self._sizes.pop(block_id)
            self._store.pop(block_id, None)
            self.stats.evictions += 1
            if self._dir is not None:
                path = self._path(block_id) + ".npy"
                if os.path.exists(path):
                    os.remove(path)

    def blocks(self) -> List[str]:
        with self._lock:
            return list(self._sizes)

    def size_of(self, block_id: str) -> float:
        return self._sizes[block_id]

    def accrue_byte_hours(self, hours: float) -> None:
        with self._lock:
            self.stats.byte_hours += self._used * hours

    def stats_dict(self) -> dict:
        d = dataclasses_asdict(self.stats)
        d.update(tier=self.spec.name, used=self._used,
                 capacity=self.spec.capacity, available=self.available)
        return d


# ---------------------------------------------------------------------------
# Tier 4: consistent-hash RDMA pool (paper §III-B / §IV / §VII scaling)
# ---------------------------------------------------------------------------
class ConsistentHashRing:
    """Consistent hashing with virtual nodes; O(log n) lookup via bisect.

    Node join/leave remaps only ~1/n of the key space — the property the
    paper leans on for 1024+-node scaling and graceful failure handling.
    """

    def __init__(self, nodes: Sequence[str] = (), vnodes: int = 64):
        self.vnodes = vnodes
        self._ring: List[Tuple[int, str]] = []
        self._nodes: set = set()
        for n in nodes:
            self.add_node(n)

    @staticmethod
    def _hash(key: str) -> int:
        return int.from_bytes(
            hashlib.sha256(key.encode()).digest()[:8], "big")

    def add_node(self, node: str) -> None:
        if node in self._nodes:
            return
        self._nodes.add(node)
        for v in range(self.vnodes):
            h = self._hash(f"{node}#{v}")
            bisect.insort(self._ring, (h, node))

    def remove_node(self, node: str) -> None:
        if node not in self._nodes:
            return
        self._nodes.discard(node)
        self._ring = [(h, n) for h, n in self._ring if n != node]

    def lookup(self, key: str) -> str:
        if not self._ring:
            raise RuntimeError("hash ring empty")
        h = self._hash(key)
        idx = bisect.bisect_right(self._ring, (h, chr(0x10FFFF)))
        if idx == len(self._ring):
            idx = 0
        return self._ring[idx][1]

    @property
    def nodes(self) -> List[str]:
        return sorted(self._nodes)


class RDMATier(TierManager):
    """Distributed block pool across the fabric using a consistent hash
    ring.  Each peer holds a shard; one-sided reads fetch remote blocks.
    Node failure: the ring drops the peer and its displaced blocks are
    re-homed onto the surviving ring (a modelled re-replication write
    per block); blocks are lost only when no peer survives — graceful
    degradation."""

    def __init__(self, spec: TierSpec, nodes: Sequence[str] = ("node0",),
                 vnodes: int = 64):
        super().__init__(spec)
        self.ring = ConsistentHashRing(nodes, vnodes=vnodes)
        self._node_store: Dict[str, Dict[str, float]] = {n: {} for n in nodes}
        self.rehomed_blocks = 0        # fail_node re-replications

    def placement(self, block_id: str) -> str:
        return self.ring.lookup(block_id)

    def allocate(self, block_id: str, nbytes: float) -> None:
        super().allocate(block_id, nbytes)
        node = self.placement(block_id)
        self._node_store.setdefault(node, {})[block_id] = nbytes

    def evict(self, block_id: str) -> None:
        for store in self._node_store.values():
            store.pop(block_id, None)
        super().evict(block_id)

    def add_node(self, node: str) -> None:
        self.ring.add_node(node)
        self._node_store.setdefault(node, {})

    def fail_node(self, node: str) -> List[str]:
        """Drop a peer and re-home its displaced blocks through the ring
        onto the survivors (each re-insertion charges one re-replication
        write).  Returns the block ids actually lost — non-empty only
        when the failed peer was the last one."""
        with self._lock:
            self.ring.remove_node(node)
            displaced = list(self._node_store.pop(node, {}))
            lost: List[str] = []
            for bid in displaced:
                if not self.contains(bid):
                    continue
                if not self.ring.nodes:
                    TierManager.evict(self, bid)
                    lost.append(bid)
                    continue
                nbytes = self._sizes[bid]
                survivor = self.ring.lookup(bid)
                self._node_store.setdefault(survivor, {})[bid] = nbytes
                self._charge(nbytes, read=False)   # re-replication write
                self.rehomed_blocks += 1
            return lost

    def node_load(self) -> Dict[str, float]:
        return {n: sum(s.values()) for n, s in self._node_store.items()}


# ---------------------------------------------------------------------------
# Fleet-shared tier-4 namespace (one RDMA pool for every replica)
# ---------------------------------------------------------------------------
class FleetKVStore:
    """One fleet-wide, content-addressed tier-4 namespace.

    The paper treats the RDMA/fabric tier as a *fleet* resource, not a
    per-node spillway: every replica's ``TierHierarchy`` binds a
    ``SharedTierView`` over this store, and blocks are keyed by content
    hash — a popular template's blocks occupy fabric bytes once no
    matter how many replicas registered them.

    Reference counting is per (owner, local block id) mapping: a view's
    allocate acquires one reference, its evict releases it.  A key whose
    refcount reaches zero STAYS resident — it is exactly the cross-
    replica prefix cache — and is reclaimed lazily, oldest-first, only
    under capacity pressure (``_make_room``).  Eviction never touches a
    key with live references, so one replica's teardown can never strand
    or free another replica's blocks.
    """

    def __init__(self, spec: Optional[TierSpec] = None,
                 nodes: Sequence[str] = ("node0", "node1", "node2", "node3"),
                 vnodes: int = 64):
        spec = PAPER_TIER_SPECS[4] if spec is None else spec
        self.tier = RDMATier(spec, nodes=nodes, vnodes=vnodes)
        self._refs: Dict[str, int] = {}
        self._lock = threading.RLock()
        self.publishes = 0             # writes that added new bytes
        self.dedup_publishes = 0       # ref bumps on already-resident keys
        self.fetches = 0               # demand payload reads
        self.evicted_cold = 0          # zero-ref keys reclaimed for room

    # -- key namespace ------------------------------------------------------
    def ref_count(self, key: str) -> int:
        with self._lock:
            return self._refs.get(key, 0)

    def contains_key(self, key: str) -> bool:
        return self.tier.contains(key)

    def has_payload(self, key: str) -> bool:
        with self._lock:
            return (self.tier.contains(key)
                    and self.tier._store.get(key) is not None)

    # -- reference lifecycle ------------------------------------------------
    def acquire(self, key: str, payload: Optional[np.ndarray],
                nbytes: float) -> bool:
        """One owner reference on ``key``; bytes are written only if the
        content is not already resident.  Returns True when new bytes
        were written (False: dedup — the fleet already had it)."""
        with self._lock:
            if self.tier.contains(key):
                self._refs[key] = self._refs.get(key, 0) + 1
                if payload is not None and self.tier._store.get(key) is None:
                    self.tier._store[key] = payload
                self.dedup_publishes += 1
                return False
            self._make_room(nbytes)
            self.tier.write(key, payload, nbytes=nbytes)
            self._refs[key] = self._refs.get(key, 0) + 1
            self.publishes += 1
            return True

    def put_payload(self, key: str, payload: np.ndarray) -> None:
        with self._lock:
            if self.tier.contains(key) and \
                    self.tier._store.get(key) is None:
                self.tier._store[key] = payload

    def release(self, key: str) -> None:
        """Drop one owner reference.  Zero-ref keys stay resident (the
        shared prefix cache) until capacity pressure reclaims them."""
        with self._lock:
            n = self._refs.get(key, 0) - 1
            if n <= 0:
                self._refs.pop(key, None)
            else:
                self._refs[key] = n

    def _make_room(self, nbytes: float) -> None:
        """Reclaim zero-ref keys, oldest-first.  Keys with live owner
        references are never evicted — the no-stranded-reference
        invariant the shared-tier tests pin down."""
        if self.tier.free >= nbytes:
            return
        for key in list(self.tier._sizes):
            if self._refs.get(key, 0) == 0:
                self.tier.evict(key)
                self.evicted_cold += 1
                if self.tier.free >= nbytes:
                    return

    # -- data path ----------------------------------------------------------
    def fetch(self, key: str) -> Tuple[Optional[np.ndarray], float]:
        """Demand read of a shared block: (payload, modelled transfer
        seconds), or (None, 0.0) when the key is not resident."""
        with self._lock:
            if not self.tier.contains(key):
                return None, 0.0
            payload, t = self.tier.read(key)
            self.fetches += 1
            return payload, t

    def peek(self, key: str) -> Optional[np.ndarray]:
        """Payload without transfer accounting (intra-owner reads the
        per-view stats already charge)."""
        with self._lock:
            return self.tier._store.get(key)

    def fail_node(self, node: str) -> List[str]:
        return self.tier.fail_node(node)

    def stats(self) -> dict:
        with self._lock:
            return {"keys": len(self.tier._sizes),
                    "used": self.tier.used,
                    "capacity": self.tier.spec.capacity,
                    "live_refs": sum(self._refs.values()),
                    "publishes": self.publishes,
                    "dedup_publishes": self.dedup_publishes,
                    "fetches": self.fetches,
                    "evicted_cold": self.evicted_cold,
                    "rehomed_blocks": self.tier.rehomed_blocks}


class SharedTierView(TierManager):
    """One replica's tier-4 adapter over the ``FleetKVStore``.

    Local block ids translate to fleet keys — the block's content hash
    when the resolver knows it, an owner-scoped fallback otherwise — so
    colliding local ids (every manager names blocks ``blk0, blk1, …``)
    never alias across replicas, while identical *content* always does.

    ``used``/``blocks``/``stats`` are owner-scoped (this replica's
    mappings only): teardown of one replica zeroes ITS view without
    touching bytes other owners still reference.  ``free`` is fleet-wide
    — capacity genuinely is shared — so the demotion cascade sees the
    real pool headroom.
    """

    def __init__(self, store: FleetKVStore, owner: str,
                 resolve_key: Optional[Callable[[str],
                                               Optional[str]]] = None):
        super().__init__(store.tier.spec)
        self.fleet = store
        self.owner = owner
        self._resolve = resolve_key
        self._map: Dict[str, str] = {}     # local bid -> fleet key

    def _key(self, block_id: str) -> str:
        key = self._resolve(block_id) if self._resolve is not None else None
        return key if key else f"{self.owner}:{block_id}"

    @property
    def free(self) -> float:
        return self.fleet.tier.free

    def contains(self, block_id: str) -> bool:
        with self._lock:
            key = self._map.get(block_id)
            return key is not None and self.fleet.contains_key(key)

    def fleet_key(self, block_id: str) -> Optional[str]:
        with self._lock:
            return self._map.get(block_id)

    def allocate(self, block_id: str, nbytes: float) -> None:
        with self._lock:
            if not self.available:
                raise CapacityError(f"tier {self.spec.name} unavailable")
            if block_id in self._map:
                return
            key = self._key(block_id)
            self.fleet.acquire(key, None, nbytes)     # may raise Capacity
            self._map[block_id] = key
            self._sizes[block_id] = nbytes
            self._used += nbytes

    def write(self, block_id: str, payload: Optional[np.ndarray],
              nbytes: Optional[float] = None) -> float:
        with self._lock:
            key = self._map.get(block_id)
            if key is not None and not self.fleet.contains_key(key):
                # the fleet copy died (total node loss): drop the stale
                # mapping and re-acquire below
                self.evict(block_id)
                key = None
            if key is None:
                size = float(nbytes if nbytes is not None
                             else (payload.nbytes if payload is not None
                                   else 0))
                self.allocate(block_id, size)
                key = self._map[block_id]
            if payload is not None:
                self.fleet.put_payload(key, payload)
            return self._charge(self._sizes[block_id], read=False)

    def read(self, block_id: str) -> Tuple[Optional[np.ndarray], float]:
        with self._lock:
            if not self.available:
                raise CapacityError(f"tier {self.spec.name} unavailable")
            key = self._map.get(block_id)
            if key is None or not self.fleet.contains_key(key):
                raise KeyError(block_id)
            payload = self.fleet.peek(key)
            return payload, self._charge(self._sizes[block_id], read=True)

    def evict(self, block_id: str) -> None:
        with self._lock:
            key = self._map.pop(block_id, None)
            if key is None:
                return
            self._used -= self._sizes.pop(block_id)
            self._store.pop(block_id, None)
            self.stats.evictions += 1
            self.fleet.release(key)


# ---------------------------------------------------------------------------
# The hierarchy
# ---------------------------------------------------------------------------
class TierHierarchy:
    """Ordered tier stack with promote/demote and failure handling."""

    def __init__(self, specs: Sequence[TierSpec] = PAPER_TIER_SPECS,
                 *, backing_root: Optional[str] = None,
                 rdma_nodes: Sequence[str] = ("node0", "node1", "node2",
                                              "node3")):
        self.tiers: List[TierManager] = []
        for spec in specs:
            if spec.tier_id == 4:
                self.tiers.append(RDMATier(spec, nodes=rdma_nodes))
            else:
                backing = (os.path.join(backing_root, spec.name)
                           if backing_root and spec.tier_id >= 3 else None)
                self.tiers.append(TierManager(spec, backing_dir=backing))
        self._lock = threading.RLock()

    def __getitem__(self, tier_id: int) -> TierManager:
        return self.tiers[tier_id]

    @property
    def n_tiers(self) -> int:
        return len(self.tiers)

    def active_tiers(self) -> List[TierManager]:
        return [t for t in self.tiers if t.available]

    def locate(self, block_id: str) -> Optional[int]:
        """Fastest tier currently holding the block."""
        for t in self.tiers:
            if t.available and t.contains(block_id):
                return t.spec.tier_id
        return None

    def move(self, block_id: str, src: int, dst: int,
             payload: Optional[np.ndarray] = None) -> float:
        """Promote (dst < src) or demote (dst > src); returns modelled
        transfer time (read from src + write to dst)."""
        with self._lock:
            s, d = self.tiers[src], self.tiers[dst]
            if not s.contains(block_id):
                raise KeyError(f"{block_id} not in tier {src}")
            data, t_read = s.read(block_id)
            nbytes = s.size_of(block_id)
            t_write = d.write(block_id, payload if payload is not None
                              else data, nbytes=nbytes)
            s.evict(block_id)
            return t_read + t_write

    def fail_tier(self, tier_id: int) -> List[str]:
        """Paper §VII: on tier failure, remove it from the promotion/
        demotion graph and redistribute its blocks to adjacent tiers."""
        with self._lock:
            t = self.tiers[tier_id]
            blocks = t.blocks()
            moved, lost = [], []
            for bid in blocks:
                nbytes = t.size_of(bid)
                payload = t._store.get(bid)
                placed = False
                for adj in self._adjacent(tier_id):
                    try:
                        self.tiers[adj].write(bid, payload, nbytes=nbytes)
                        placed = True
                        moved.append(bid)
                        break
                    except CapacityError:
                        continue
                if not placed:
                    lost.append(bid)
                t.evict(bid)
            t.available = False
            return lost

    def restore_tier(self, tier_id: int) -> None:
        self.tiers[tier_id].available = True

    def _adjacent(self, tier_id: int) -> List[int]:
        order = []
        for delta in (1, -1, 2, -2, 3, -3, 4, -4, 5, -5):
            j = tier_id + delta
            if 0 <= j < len(self.tiers) and self.tiers[j].available:
                order.append(j)
        return order

    # -- accounting ---------------------------------------------------------
    def total_cost_dollars(self) -> float:
        return sum(t.stats.byte_hours / GB * t.spec.cost_per_gb_hour
                   for t in self.tiers)

    def capacity_through(self, tier_id: int) -> float:
        """Cumulative capacity of tiers 0..tier_id (paper Table IV col 2)."""
        return sum(t.spec.capacity for t in self.tiers[:tier_id + 1])

    def stats(self) -> List[dict]:
        return [t.stats_dict() for t in self.tiers]


# ---------------------------------------------------------------------------
# Async tier transfers (paper §IV: "transfers overlap compute")
# ---------------------------------------------------------------------------
@dataclass
class TransferRequest:
    """One demotion/promotion/fetch to run off the engine step loop."""
    block_id: str
    src: int
    dst: int
    kind: str = "demote"          # demote | fetch | promote | custom
    payload: Optional[np.ndarray] = None
    nbytes: Optional[float] = None
    tag: str = ""                 # caller correlation key (e.g. request id)
    evict_src: bool = False       # fetch: drop the source copy after reading
    # custom: callable(hierarchy) -> (sim_time, payload | None)
    execute: Optional[Callable] = None
    ticket: int = 0


@dataclass
class TransferEvent:
    request: TransferRequest
    ok: bool
    sim_time: float = 0.0         # modelled transfer seconds (tier specs)
    wall_ms: float = 0.0          # host wall time on the worker thread
    payload: Optional[np.ndarray] = None
    error: Optional[str] = None


class AsyncTierTransferWorker:
    """Background transfer engine: the scheduler submits demotions /
    promotions / fetches and polls completion events, so tier traffic
    never blocks the decode step loop.

    Double-buffered submission: callers append to a staging buffer under
    a light lock; the worker swaps staging <-> active when it goes to
    execute, so submitters never contend with an in-progress transfer.
    A preempted request's payload therefore stays valid in the caller's
    staging copy until the demotion write completes — restores that
    arrive before the write finishes are served from the buffer for free.
    """

    def __init__(self, hierarchy: TierHierarchy, name: str = "kv-transfer"):
        self.hierarchy = hierarchy
        self._staging: List[TransferRequest] = []
        self._completed: Deque[TransferEvent] = deque()
        self._cv = threading.Condition()
        self._stop = False
        self._inflight = 0
        self._tickets = itertools.count(1)
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.max_inflight = 0
        self.sim_time_total = 0.0
        self.wall_ms_total = 0.0
        self._thread = threading.Thread(target=self._run, name=name,
                                        daemon=True)
        self._thread.start()

    # -- producer side ------------------------------------------------------
    def submit(self, req: TransferRequest) -> int:
        with self._cv:
            if self._stop:
                raise RuntimeError("transfer worker closed")
            req.ticket = next(self._tickets)
            self._staging.append(req)
            self.submitted += 1
            self._inflight += 1
            self.max_inflight = max(self.max_inflight, self._inflight)
            self._cv.notify_all()
        return req.ticket

    def poll(self) -> List[TransferEvent]:
        """Completion events since the last poll (non-blocking)."""
        with self._cv:
            out = list(self._completed)
            self._completed.clear()
        return out

    def drain(self, timeout: float = 10.0) -> bool:
        """Block until every submitted transfer has completed."""
        deadline = time.monotonic() + timeout
        with self._cv:
            while self._inflight > 0:
                remaining = deadline - time.monotonic()
                if remaining <= 0 or not self._cv.wait(remaining):
                    return self._inflight == 0
            return True

    def close(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout=5.0)

    def stats(self) -> dict:
        with self._cv:
            return {"submitted": self.submitted,
                    "completed": self.completed,
                    "failed": self.failed,
                    "in_flight": self._inflight,
                    "max_inflight": self.max_inflight,
                    "sim_time_total": self.sim_time_total,
                    "wall_ms_total": self.wall_ms_total}

    # -- worker side --------------------------------------------------------
    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._staging and not self._stop:
                    self._cv.wait()
                if self._stop and not self._staging:
                    return
                active, self._staging = self._staging, []   # buffer swap
            for req in active:
                ev = self._execute(req)
                with self._cv:
                    self._completed.append(ev)
                    self._inflight -= 1
                    self.completed += 1
                    if not ev.ok:
                        self.failed += 1
                    self.sim_time_total += ev.sim_time
                    self.wall_ms_total += ev.wall_ms
                    self._cv.notify_all()

    def _execute(self, req: TransferRequest) -> TransferEvent:
        t0 = time.monotonic()
        sim, payload = 0.0, None
        try:
            if req.execute is not None:
                sim, payload = req.execute(self.hierarchy)
            elif req.kind == "demote":
                if self.hierarchy[req.src].contains(req.block_id):
                    sim = self.hierarchy.move(req.block_id, req.src, req.dst,
                                              payload=req.payload)
                else:
                    sim = self.hierarchy[req.dst].write(
                        req.block_id, req.payload, nbytes=req.nbytes)
            elif req.kind == "fetch":
                payload, sim = self.hierarchy[req.src].read(req.block_id)
                if req.evict_src:
                    self.hierarchy[req.src].evict(req.block_id)
            elif req.kind == "promote":
                sim = self.hierarchy.move(req.block_id, req.src, req.dst)
            else:
                raise ValueError(f"unknown transfer kind {req.kind!r}")
            return TransferEvent(req, True, sim,
                                 (time.monotonic() - t0) * 1e3, payload)
        except Exception as e:                      # noqa: BLE001
            return TransferEvent(req, False, sim,
                                 (time.monotonic() - t0) * 1e3, None, str(e))
