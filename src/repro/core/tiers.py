"""Six-tier memory hierarchy for KV cache blocks (paper §III-B, Table II).

TPU adaptation (DESIGN.md §Hardware-adaptation): the paper's tiers are
GPU-centric (HBM3 / pinned DRAM via CUDA streams / CXL mmap / cuFile GDS /
ibverbs RDMA / Lustre).  On a TPU pod the same hierarchy maps to:

    Tier 0  device HBM     (jax arrays, donated in-place updates)
    Tier 1  host DRAM      (numpy, pinned-host analogue; async D2H/H2D)
    Tier 2  CXL pool       (mmap-backed store; on v5e hosts this models a
                            CXL 3.0 expander attached to the host)
    Tier 3  NVMe           (file-backed store, O_DIRECT-aligned records)
    Tier 4  remote pool    (consistent-hash ring over ICI/DCN peers —
                            one-sided RDMA read ~ remote host fetch)
    Tier 5  parallel FS    (content-addressed files, dedup via SHA-256)

Every tier implements the uniform ``TierManager`` interface with
thread-safe Allocate / Read / Write / Evict / Stats (paper §IV).  Since
this container has no CXL/NVMe/IB hardware, non-host tiers are backed by
in-memory or file stores and *account* transfer time against the published
bandwidth/latency specs — that accounting is what the trace replay and the
analytical projections consume (paper §V-B methodology).
"""
from __future__ import annotations

import bisect
import hashlib
import itertools
import os
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.faults import (FaultCounters, FaultInjector, HealthConfig,
                               HEALTHY, QUARANTINED, RetryPolicy,
                               TierHealthMonitor, TierIntegrityError,
                               TierIOError, payload_crc)


# ---------------------------------------------------------------------------
# Published hardware specifications (paper Table II)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TierSpec:
    tier_id: int
    name: str
    bandwidth: float          # bytes / s
    latency: float            # seconds (GPU-observed)
    cost_per_gb_hour: float   # $ / GB / h
    capacity: float           # bytes

    def transfer_time(self, nbytes: float) -> float:
        return self.latency + nbytes / self.bandwidth


GB = 1024 ** 3
TB = 1024 ** 4

# Capacities follow Table IV's cumulative column: 40 GB -> 200 -> 712 ->
# 4.7 TB -> 38+ TB.
PAPER_TIER_SPECS: Tuple[TierSpec, ...] = (
    TierSpec(0, "gpu_hbm", 3.35e12, 100e-9, 0.500, 40 * GB),
    TierSpec(1, "cpu_dram", 204e9, 3e-6, 0.050, 160 * GB),
    TierSpec(2, "cxl_mem", 64e9, 500e-9, 0.030, 512 * GB),
    TierSpec(3, "nvme_gds", 12e9, 10e-6, 0.020, 4 * TB),
    TierSpec(4, "rdma_pool", 50e9, 5e-6, 0.005, 34 * TB),
    TierSpec(5, "parallel_fs", 2e9, 1e-3, 0.001, 1000 * TB),
)

# TPU v5e single-host flavour (DESIGN.md): HBM 16 GB/chip, PCIe host link.
TPU_V5E_TIER_SPECS: Tuple[TierSpec, ...] = (
    TierSpec(0, "tpu_hbm", 819e9, 100e-9, 0.500, 16 * GB),
    TierSpec(1, "host_dram", 128e9, 3e-6, 0.050, 128 * GB),
    TierSpec(2, "cxl_mem", 64e9, 500e-9, 0.030, 512 * GB),
    TierSpec(3, "nvme", 8e9, 20e-6, 0.020, 4 * TB),
    TierSpec(4, "ici_remote", 50e9, 5e-6, 0.005, 34 * TB),
    TierSpec(5, "parallel_fs", 2e9, 1e-3, 0.001, 1000 * TB),
)


# ---------------------------------------------------------------------------
# Stats
# ---------------------------------------------------------------------------
@dataclass
class TierStats:
    reads: int = 0
    writes: int = 0
    evictions: int = 0
    bytes_read: float = 0.0
    bytes_written: float = 0.0
    sim_time: float = 0.0            # accumulated modelled transfer time
    byte_hours: float = 0.0          # for $/Mtok accounting
    integrity_failures: int = 0      # crc mismatches caught on read

    def as_dict(self) -> dict:
        return dataclasses_asdict(self)


def dataclasses_asdict(obj) -> dict:
    import dataclasses
    return dataclasses.asdict(obj)


# ---------------------------------------------------------------------------
# TierManager — uniform interface (paper §IV)
# ---------------------------------------------------------------------------
class CapacityError(RuntimeError):
    pass


class TierManager:
    """One memory tier: a block store with capacity + transfer accounting."""

    def __init__(self, spec: TierSpec, *, backing_dir: Optional[str] = None):
        self.spec = spec
        self._store: Dict[str, Optional[np.ndarray]] = {}
        self._sizes: Dict[str, float] = {}
        self._used = 0.0
        self._lock = threading.RLock()
        self.stats = TierStats()
        self.available = True
        self._dir = backing_dir
        # fault tolerance: the hierarchy attaches one injector to every
        # tier; None means the fault hooks below are skipped entirely
        self.fault_injector: Optional[FaultInjector] = None
        self._crc: Dict[str, int] = {}
        if backing_dir:
            os.makedirs(backing_dir, exist_ok=True)

    # -- helpers ------------------------------------------------------------
    def _path(self, block_id: str) -> str:
        assert self._dir
        return os.path.join(self._dir, hashlib.sha256(
            block_id.encode()).hexdigest())

    def _charge(self, nbytes: float, *, read: bool,
                mult: float = 1.0) -> float:
        t = self.spec.transfer_time(nbytes) * mult
        self.stats.sim_time += t
        if read:
            self.stats.reads += 1
            self.stats.bytes_read += nbytes
        else:
            self.stats.writes += 1
            self.stats.bytes_written += nbytes
        return t

    # -- interface ------------------------------------------------------------
    @property
    def used(self) -> float:
        return self._used

    @property
    def free(self) -> float:
        return self.spec.capacity - self._used

    def contains(self, block_id: str) -> bool:
        with self._lock:
            return block_id in self._sizes

    def allocate(self, block_id: str, nbytes: float) -> None:
        with self._lock:
            if not self.available:
                raise CapacityError(f"tier {self.spec.name} unavailable")
            if block_id in self._sizes:
                return
            if self._used + nbytes > self.spec.capacity:
                raise CapacityError(
                    f"tier {self.spec.name}: {nbytes:.0f}B over capacity "
                    f"({self._used:.0f}/{self.spec.capacity:.0f})")
            self._sizes[block_id] = nbytes
            self._store[block_id] = None
            self._used += nbytes

    def _verify(self, block_id: str, payload: Optional[np.ndarray],
                crc: Optional[int]) -> None:
        """Checksum gate on the read path: a payload whose crc32 does not
        match what was recorded at write time is corrupt and must never
        reach a decode — raise instead of returning it."""
        if crc is None or payload is None:
            return
        if payload_crc(payload) != crc:
            self.stats.integrity_failures += 1
            raise TierIntegrityError(self.spec.tier_id, "read", block_id)

    def write(self, block_id: str, payload: Optional[np.ndarray],
              nbytes: Optional[float] = None) -> float:
        """Returns modelled transfer time (seconds).  Raises
        ``TierIOError`` on an injected transient write fault (before any
        state mutates)."""
        with self._lock:
            inj, mult = self.fault_injector, 1.0
            if inj is not None:
                mult = inj.check_write(self.spec.tier_id, block_id)
            if block_id not in self._sizes:
                size = float(nbytes if nbytes is not None
                             else (payload.nbytes if payload is not None else 0))
                self.allocate(block_id, size)
            size = self._sizes[block_id]
            if self._dir is not None and payload is not None:
                np.save(self._path(block_id) + ".npy", payload)
                self._store[block_id] = None
            else:
                self._store[block_id] = payload
            if inj is not None and payload is not None:
                self._crc[block_id] = payload_crc(payload)
            return self._charge(size, read=False, mult=mult)

    def read(self, block_id: str) -> Tuple[Optional[np.ndarray], float]:
        """Returns (payload, modelled transfer time).  Raises
        ``TierIOError`` on an injected transient fault and
        ``TierIntegrityError`` when the payload fails its checksum."""
        with self._lock:
            if not self.available:
                raise CapacityError(f"tier {self.spec.name} unavailable")
            if block_id not in self._sizes:
                raise KeyError(block_id)
            inj, mult = self.fault_injector, 1.0
            if inj is not None:
                mult = inj.check_read(self.spec.tier_id, block_id)
            size = self._sizes[block_id]
            payload = self._store.get(block_id)
            if payload is None and self._dir is not None:
                path = self._path(block_id) + ".npy"
                if os.path.exists(path):
                    payload = np.load(path)
            if inj is not None and payload is not None:
                payload = inj.maybe_corrupt(self.spec.tier_id, block_id,
                                            payload)
                self._verify(block_id, payload, self._crc.get(block_id))
            return payload, self._charge(size, read=True, mult=mult)

    def attach_payload(self, block_id: str,
                       payload: Optional[np.ndarray]) -> None:
        """Backfill stored bytes for a block that was allocated
        metadata-first (prompt blocks register before the engine extracts
        their KV arrays from the pool).  Not a modelled I/O: no transfer
        time is charged and no fault is drawn — it only makes later
        demotions/promotions carry (and checksum-gate) real payloads."""
        if payload is None:
            return
        with self._lock:
            if block_id not in self._sizes \
                    or self._store.get(block_id) is not None:
                return
            if self._dir is not None:
                np.save(self._path(block_id) + ".npy", payload)
            else:
                self._store[block_id] = payload
            if self.fault_injector is not None:
                self._crc[block_id] = payload_crc(payload)

    def evict(self, block_id: str) -> None:
        with self._lock:
            if block_id not in self._sizes:
                return
            self._used -= self._sizes.pop(block_id)
            self._store.pop(block_id, None)
            self._crc.pop(block_id, None)
            self.stats.evictions += 1
            if self._dir is not None:
                path = self._path(block_id) + ".npy"
                if os.path.exists(path):
                    os.remove(path)

    def blocks(self) -> List[str]:
        with self._lock:
            return list(self._sizes)

    def size_of(self, block_id: str) -> float:
        return self._sizes[block_id]

    def accrue_byte_hours(self, hours: float) -> None:
        with self._lock:
            self.stats.byte_hours += self._used * hours

    def stats_dict(self) -> dict:
        d = dataclasses_asdict(self.stats)
        d.update(tier=self.spec.name, used=self._used,
                 capacity=self.spec.capacity, available=self.available)
        return d


# ---------------------------------------------------------------------------
# Tier 4: consistent-hash RDMA pool (paper §III-B / §IV / §VII scaling)
# ---------------------------------------------------------------------------
class ConsistentHashRing:
    """Consistent hashing with virtual nodes; O(log n) lookup via bisect.

    Node join/leave remaps only ~1/n of the key space — the property the
    paper leans on for 1024+-node scaling and graceful failure handling.
    """

    def __init__(self, nodes: Sequence[str] = (), vnodes: int = 64):
        self.vnodes = vnodes
        self._ring: List[Tuple[int, str]] = []
        self._nodes: set = set()
        for n in nodes:
            self.add_node(n)

    @staticmethod
    def _hash(key: str) -> int:
        return int.from_bytes(
            hashlib.sha256(key.encode()).digest()[:8], "big")

    def add_node(self, node: str) -> None:
        if node in self._nodes:
            return
        self._nodes.add(node)
        for v in range(self.vnodes):
            h = self._hash(f"{node}#{v}")
            bisect.insort(self._ring, (h, node))

    def remove_node(self, node: str) -> None:
        if node not in self._nodes:
            return
        self._nodes.discard(node)
        self._ring = [(h, n) for h, n in self._ring if n != node]

    def lookup(self, key: str) -> str:
        if not self._ring:
            raise RuntimeError("hash ring empty")
        h = self._hash(key)
        idx = bisect.bisect_right(self._ring, (h, chr(0x10FFFF)))
        if idx == len(self._ring):
            idx = 0
        return self._ring[idx][1]

    @property
    def nodes(self) -> List[str]:
        return sorted(self._nodes)


class RDMATier(TierManager):
    """Distributed block pool across the fabric using a consistent hash
    ring.  Each peer holds a shard; one-sided reads fetch remote blocks.
    Node failure: the ring drops the peer and its displaced blocks are
    re-homed onto the surviving ring (a modelled re-replication write
    per block); blocks are lost only when no peer survives — graceful
    degradation."""

    def __init__(self, spec: TierSpec, nodes: Sequence[str] = ("node0",),
                 vnodes: int = 64):
        super().__init__(spec)
        self.ring = ConsistentHashRing(nodes, vnodes=vnodes)
        self._node_store: Dict[str, Dict[str, float]] = {n: {} for n in nodes}
        self.rehomed_blocks = 0        # fail_node re-replications

    def placement(self, block_id: str) -> str:
        return self.ring.lookup(block_id)

    def read(self, block_id: str) -> Tuple[Optional[np.ndarray], float]:
        inj = self.fault_injector
        if inj is not None:
            inj.maybe_flap(self, "read", block_id)
        return super().read(block_id)

    def write(self, block_id: str, payload: Optional[np.ndarray],
              nbytes: Optional[float] = None) -> float:
        inj = self.fault_injector
        if inj is not None:
            inj.maybe_flap(self, "write", block_id)
        return super().write(block_id, payload, nbytes=nbytes)

    def allocate(self, block_id: str, nbytes: float) -> None:
        super().allocate(block_id, nbytes)
        node = self.placement(block_id)
        self._node_store.setdefault(node, {})[block_id] = nbytes

    def evict(self, block_id: str) -> None:
        for store in self._node_store.values():
            store.pop(block_id, None)
        super().evict(block_id)

    def add_node(self, node: str) -> None:
        self.ring.add_node(node)
        self._node_store.setdefault(node, {})

    def fail_node(self, node: str) -> List[str]:
        """Drop a peer and re-home its displaced blocks through the ring
        onto the survivors (each re-insertion charges one re-replication
        write).  Returns the block ids actually lost — non-empty only
        when the failed peer was the last one."""
        with self._lock:
            self.ring.remove_node(node)
            displaced = list(self._node_store.pop(node, {}))
            lost: List[str] = []
            for bid in displaced:
                if not self.contains(bid):
                    continue
                if not self.ring.nodes:
                    TierManager.evict(self, bid)
                    lost.append(bid)
                    continue
                nbytes = self._sizes[bid]
                survivor = self.ring.lookup(bid)
                self._node_store.setdefault(survivor, {})[bid] = nbytes
                self._charge(nbytes, read=False)   # re-replication write
                self.rehomed_blocks += 1
            return lost

    def node_load(self) -> Dict[str, float]:
        return {n: sum(s.values()) for n, s in self._node_store.items()}


# ---------------------------------------------------------------------------
# Fleet-shared tier-4 namespace (one RDMA pool for every replica)
# ---------------------------------------------------------------------------
class FleetKVStore:
    """One fleet-wide, content-addressed tier-4 namespace.

    The paper treats the RDMA/fabric tier as a *fleet* resource, not a
    per-node spillway: every replica's ``TierHierarchy`` binds a
    ``SharedTierView`` over this store, and blocks are keyed by content
    hash — a popular template's blocks occupy fabric bytes once no
    matter how many replicas registered them.

    Reference counting is per (owner, local block id) mapping: a view's
    allocate acquires one reference, its evict releases it.  A key whose
    refcount reaches zero STAYS resident — it is exactly the cross-
    replica prefix cache — and is reclaimed lazily, oldest-first, only
    under capacity pressure (``_make_room``).  Eviction never touches a
    key with live references, so one replica's teardown can never strand
    or free another replica's blocks.
    """

    def __init__(self, spec: Optional[TierSpec] = None,
                 nodes: Sequence[str] = ("node0", "node1", "node2", "node3"),
                 vnodes: int = 64,
                 fault_injector: Optional[FaultInjector] = None):
        spec = PAPER_TIER_SPECS[4] if spec is None else spec
        self.tier = RDMATier(spec, nodes=nodes, vnodes=vnodes)
        self.tier.fault_injector = fault_injector
        self._refs: Dict[str, int] = {}
        self._lock = threading.RLock()
        self.publishes = 0             # writes that added new bytes
        self.dedup_publishes = 0       # ref bumps on already-resident keys
        self.fetches = 0               # demand payload reads
        self.evicted_cold = 0          # zero-ref keys reclaimed for room

    # -- key namespace ------------------------------------------------------
    def ref_count(self, key: str) -> int:
        with self._lock:
            return self._refs.get(key, 0)

    def contains_key(self, key: str) -> bool:
        return self.tier.contains(key)

    def has_payload(self, key: str) -> bool:
        with self._lock:
            return (self.tier.contains(key)
                    and self.tier._store.get(key) is not None)

    # -- reference lifecycle ------------------------------------------------
    def acquire(self, key: str, payload: Optional[np.ndarray],
                nbytes: float) -> bool:
        """One owner reference on ``key``; bytes are written only if the
        content is not already resident.  Returns True when new bytes
        were written (False: dedup — the fleet already had it)."""
        with self._lock:
            if self.tier.contains(key):
                self._refs[key] = self._refs.get(key, 0) + 1
                if payload is not None and self.tier._store.get(key) is None:
                    self.tier._store[key] = payload
                    if self.tier.fault_injector is not None:
                        self.tier._crc[key] = payload_crc(payload)
                self.dedup_publishes += 1
                return False
            self._make_room(nbytes)
            self.tier.write(key, payload, nbytes=nbytes)
            self._refs[key] = self._refs.get(key, 0) + 1
            self.publishes += 1
            return True

    def put_payload(self, key: str, payload: np.ndarray) -> None:
        with self._lock:
            if self.tier.contains(key) and \
                    self.tier._store.get(key) is None:
                self.tier._store[key] = payload
                if self.tier.fault_injector is not None:
                    self.tier._crc[key] = payload_crc(payload)

    def release(self, key: str) -> None:
        """Drop one owner reference.  Zero-ref keys stay resident (the
        shared prefix cache) until capacity pressure reclaims them."""
        with self._lock:
            n = self._refs.get(key, 0) - 1
            if n <= 0:
                self._refs.pop(key, None)
            else:
                self._refs[key] = n

    def _make_room(self, nbytes: float) -> None:
        """Reclaim zero-ref keys, oldest-first.  Keys with live owner
        references are never evicted — the no-stranded-reference
        invariant the shared-tier tests pin down."""
        if self.tier.free >= nbytes:
            return
        for key in list(self.tier._sizes):
            if self._refs.get(key, 0) == 0:
                self.tier.evict(key)
                self.evicted_cold += 1
                if self.tier.free >= nbytes:
                    return

    # -- data path ----------------------------------------------------------
    def fetch(self, key: str) -> Tuple[Optional[np.ndarray], float]:
        """Demand read of a shared block: (payload, modelled transfer
        seconds), or (None, 0.0) when the key is not resident."""
        with self._lock:
            if not self.tier.contains(key):
                return None, 0.0
            payload, t = self.tier.read(key)
            self.fetches += 1
            return payload, t

    def peek(self, key: str) -> Optional[np.ndarray]:
        """Payload without transfer accounting (intra-owner reads the
        per-view stats already charge)."""
        with self._lock:
            return self.tier._store.get(key)

    def fail_node(self, node: str) -> List[str]:
        return self.tier.fail_node(node)

    def stats(self) -> dict:
        with self._lock:
            return {"keys": len(self.tier._sizes),
                    "used": self.tier.used,
                    "capacity": self.tier.spec.capacity,
                    "live_refs": sum(self._refs.values()),
                    "publishes": self.publishes,
                    "dedup_publishes": self.dedup_publishes,
                    "fetches": self.fetches,
                    "evicted_cold": self.evicted_cold,
                    "rehomed_blocks": self.tier.rehomed_blocks}


class SharedTierView(TierManager):
    """One replica's tier-4 adapter over the ``FleetKVStore``.

    Local block ids translate to fleet keys — the block's content hash
    when the resolver knows it, an owner-scoped fallback otherwise — so
    colliding local ids (every manager names blocks ``blk0, blk1, …``)
    never alias across replicas, while identical *content* always does.

    ``used``/``blocks``/``stats`` are owner-scoped (this replica's
    mappings only): teardown of one replica zeroes ITS view without
    touching bytes other owners still reference.  ``free`` is fleet-wide
    — capacity genuinely is shared — so the demotion cascade sees the
    real pool headroom.
    """

    def __init__(self, store: FleetKVStore, owner: str,
                 resolve_key: Optional[Callable[[str],
                                               Optional[str]]] = None):
        super().__init__(store.tier.spec)
        self.fleet = store
        self.owner = owner
        self._resolve = resolve_key
        self._map: Dict[str, str] = {}     # local bid -> fleet key

    def _key(self, block_id: str) -> str:
        key = self._resolve(block_id) if self._resolve is not None else None
        return key if key else f"{self.owner}:{block_id}"

    @property
    def free(self) -> float:
        return self.fleet.tier.free

    def contains(self, block_id: str) -> bool:
        with self._lock:
            key = self._map.get(block_id)
            return key is not None and self.fleet.contains_key(key)

    def fleet_key(self, block_id: str) -> Optional[str]:
        with self._lock:
            return self._map.get(block_id)

    def allocate(self, block_id: str, nbytes: float) -> None:
        with self._lock:
            if not self.available:
                raise CapacityError(f"tier {self.spec.name} unavailable")
            if block_id in self._map:
                return
            key = self._key(block_id)
            self.fleet.acquire(key, None, nbytes)     # may raise Capacity
            self._map[block_id] = key
            self._sizes[block_id] = nbytes
            self._used += nbytes

    def write(self, block_id: str, payload: Optional[np.ndarray],
              nbytes: Optional[float] = None) -> float:
        with self._lock:
            inj, mult = self.fault_injector, 1.0
            if inj is not None:
                mult = inj.check_write(self.spec.tier_id, block_id)
            key = self._map.get(block_id)
            if key is not None and not self.fleet.contains_key(key):
                # the fleet copy died (total node loss): drop the stale
                # mapping and re-acquire below
                self.evict(block_id)
                key = None
            if key is None:
                size = float(nbytes if nbytes is not None
                             else (payload.nbytes if payload is not None
                                   else 0))
                self.allocate(block_id, size)
                key = self._map[block_id]
            if payload is not None:
                self.fleet.put_payload(key, payload)
            return self._charge(self._sizes[block_id], read=False, mult=mult)

    def read(self, block_id: str) -> Tuple[Optional[np.ndarray], float]:
        with self._lock:
            if not self.available:
                raise CapacityError(f"tier {self.spec.name} unavailable")
            key = self._map.get(block_id)
            if key is None or not self.fleet.contains_key(key):
                raise KeyError(block_id)
            inj, mult = self.fault_injector, 1.0
            if inj is not None:
                mult = inj.check_read(self.spec.tier_id, block_id)
            payload = self.fleet.peek(key)
            if inj is not None and payload is not None:
                payload = inj.maybe_corrupt(self.spec.tier_id, block_id,
                                            payload)
                self._verify(block_id, payload,
                             self.fleet.tier._crc.get(key))
            return payload, self._charge(self._sizes[block_id], read=True,
                                         mult=mult)

    def evict(self, block_id: str) -> None:
        with self._lock:
            key = self._map.pop(block_id, None)
            if key is None:
                return
            self._used -= self._sizes.pop(block_id)
            self._store.pop(block_id, None)
            self.stats.evictions += 1
            self.fleet.release(key)


# ---------------------------------------------------------------------------
# The hierarchy
# ---------------------------------------------------------------------------
class TierHierarchy:
    """Ordered tier stack with promote/demote and failure handling.

    With a ``fault_injector`` attached, every tier's read/write can
    raise ``TierIOError``; the hierarchy wraps its own transfer paths
    (``move`` / ``read_tier`` / ``write_tier``) in the ``RetryPolicy``
    and feeds per-op outcomes to a per-tier health state machine that
    quarantines repeatedly-failing tiers (routing demotions around them
    via the same ``available`` flag ``fail_tier`` uses) and probes them
    back to health.  Without an injector none of this runs — the fault
    layer is completely inert."""

    def __init__(self, specs: Sequence[TierSpec] = PAPER_TIER_SPECS,
                 *, backing_root: Optional[str] = None,
                 rdma_nodes: Sequence[str] = ("node0", "node1", "node2",
                                              "node3"),
                 fault_injector: Optional[FaultInjector] = None,
                 retry_policy: Optional[RetryPolicy] = None,
                 health_config: Optional[HealthConfig] = None):
        self.tiers: List[TierManager] = []
        for spec in specs:
            if spec.tier_id == 4:
                self.tiers.append(RDMATier(spec, nodes=rdma_nodes))
            else:
                backing = (os.path.join(backing_root, spec.name)
                           if backing_root and spec.tier_id >= 3 else None)
                self.tiers.append(TierManager(spec, backing_dir=backing))
        self._lock = threading.RLock()
        self.fault_injector = fault_injector
        for t in self.tiers:
            t.fault_injector = fault_injector
        if retry_policy is None and fault_injector is not None:
            retry_policy = RetryPolicy()
        self.retry_policy = retry_policy
        self._retry_rng = np.random.default_rng(
            retry_policy.seed if retry_policy is not None else 0)
        self.health = TierHealthMonitor(len(self.tiers), health_config)
        self.counters = FaultCounters()
        self.clock = 0.0

    # -- fault-tolerant I/O -------------------------------------------------
    def run_io(self, tier_id: int, fn):
        """Run one tier I/O op under the retry policy + health tracking.

        Transient ``TierIOError``s are retried with modelled backoff
        (virtual seconds accumulated in ``counters.retry_delay_s`` — no
        wall sleeps); integrity errors escalate immediately (the copy is
        corrupt, re-reading cannot help the caller decode it safely);
        exhaustion of the attempt/deadline budget re-raises the last
        error.  ``KeyError``/``CapacityError`` pass through untouched.
        Fast path: with no injector attached this is just ``fn()``."""
        if self.fault_injector is None:
            return fn()
        policy = self.retry_policy
        attempt, cum = 0, 0.0
        while True:
            attempt += 1
            try:
                out = fn()
            except TierIntegrityError:
                with self._lock:
                    self.counters.integrity_failures += 1
                    self._record_health(tier_id, ok=False)
                raise
            except TierIOError:
                with self._lock:
                    self._record_health(tier_id, ok=False)
                    if policy is None or attempt >= policy.max_attempts:
                        self.counters.io_errors += 1
                        raise
                    d = policy.delay(attempt, self._retry_rng)
                    if cum + d > policy.deadline_s:
                        self.counters.io_errors += 1
                        raise
                    cum += d
                    self.counters.retries += 1
                    self.counters.retry_delay_s += d
            else:
                with self._lock:
                    self._record_health(tier_id, ok=True)
                return out

    def _record_health(self, tier_id: int, *, ok: bool) -> None:
        before = self.health.state(tier_id)
        rec = (self.health.record_success if ok
               else self.health.record_failure)
        after = rec(tier_id, self.clock)
        if after == QUARANTINED and before != QUARANTINED:
            # reuse the fail_tier routing: an unavailable tier drops out
            # of locate() and the demotion graph until a probe recovers it
            self.tiers[tier_id].available = False
            self.counters.quarantines += 1

    def read_tier(self, tier_id: int,
                  block_id: str) -> Tuple[Optional[np.ndarray], float]:
        t = self.tiers[tier_id]
        return self.run_io(tier_id, lambda: t.read(block_id))

    def write_tier(self, tier_id: int, block_id: str,
                   payload: Optional[np.ndarray],
                   nbytes: Optional[float] = None) -> float:
        t = self.tiers[tier_id]
        return self.run_io(
            tier_id, lambda: t.write(block_id, payload, nbytes=nbytes))

    def attach_payload(self, block_id: str,
                       payload: Optional[np.ndarray]) -> None:
        """Backfill bytes for a metadata-first block wherever it lives
        (free: no fault draw, no time charged — see TierManager)."""
        tid = self.locate(block_id)
        if tid is not None:
            self.tiers[tid].attach_payload(block_id, payload)

    def tick(self, dt: float = 1.0) -> None:
        """Advance the hierarchy's virtual clock (drives health probes)."""
        self.clock += dt
        if self.fault_injector is not None:
            self.probe_quarantined()

    def probe_quarantined(self) -> None:
        """Issue recovery probes for quarantined tiers whose probe
        interval has elapsed; a successful probe restores routing."""
        with self._lock:
            for t in self.tiers:
                tid = t.spec.tier_id
                if not self.health.due_probe(tid, self.clock):
                    continue
                ok = self._probe_tier(tid)
                self.counters.probes += 1
                st = self.health.probe_result(tid, ok, self.clock)
                if st == HEALTHY:
                    self.restore_tier(tid)
                    self.counters.probe_recoveries += 1

    def _probe_tier(self, tier_id: int) -> bool:
        """One probe round-trip (write + read + evict of a sentinel)
        through the quarantined tier with faults live."""
        t = self.tiers[tier_id]
        probe_id = f"__probe_t{tier_id}__"
        t.available = True
        try:
            t.write(probe_id, None, nbytes=1.0)
            t.read(probe_id)
            return True
        except Exception:                     # noqa: BLE001
            return False
        finally:
            try:
                t.evict(probe_id)
            except Exception:                 # noqa: BLE001
                pass
            t.available = False

    def __getitem__(self, tier_id: int) -> TierManager:
        return self.tiers[tier_id]

    @property
    def n_tiers(self) -> int:
        return len(self.tiers)

    def active_tiers(self) -> List[TierManager]:
        return [t for t in self.tiers if t.available]

    def locate(self, block_id: str) -> Optional[int]:
        """Fastest tier currently holding the block."""
        for t in self.tiers:
            if t.available and t.contains(block_id):
                return t.spec.tier_id
        return None

    def move(self, block_id: str, src: int, dst: int,
             payload: Optional[np.ndarray] = None) -> float:
        """Promote (dst < src) or demote (dst > src); returns modelled
        transfer time (read from src + write to dst)."""
        with self._lock:
            s, d = self.tiers[src], self.tiers[dst]
            if not s.contains(block_id):
                raise KeyError(f"{block_id} not in tier {src}")
            data, t_read = self.run_io(src, lambda: s.read(block_id))
            nbytes = s.size_of(block_id)
            t_write = self.run_io(
                dst, lambda: d.write(block_id, payload if payload is not None
                                     else data, nbytes=nbytes))
            s.evict(block_id)
            return t_read + t_write

    def fail_tier(self, tier_id: int) -> List[str]:
        """Paper §VII: on tier failure, remove it from the promotion/
        demotion graph and redistribute its blocks to adjacent tiers."""
        with self._lock:
            t = self.tiers[tier_id]
            blocks = t.blocks()
            moved, lost = [], []
            for bid in blocks:
                nbytes = t.size_of(bid)
                payload = t._store.get(bid)
                placed = False
                for adj in self._adjacent(tier_id):
                    try:
                        self.tiers[adj].write(bid, payload, nbytes=nbytes)
                        placed = True
                        moved.append(bid)
                        break
                    except CapacityError:
                        continue
                if not placed:
                    lost.append(bid)
                t.evict(bid)
            t.available = False
            return lost

    def restore_tier(self, tier_id: int) -> None:
        self.tiers[tier_id].available = True

    def _adjacent(self, tier_id: int) -> List[int]:
        order = []
        for delta in (1, -1, 2, -2, 3, -3, 4, -4, 5, -5):
            j = tier_id + delta
            if 0 <= j < len(self.tiers) and self.tiers[j].available:
                order.append(j)
        return order

    # -- accounting ---------------------------------------------------------
    def total_cost_dollars(self) -> float:
        return sum(t.stats.byte_hours / GB * t.spec.cost_per_gb_hour
                   for t in self.tiers)

    def capacity_through(self, tier_id: int) -> float:
        """Cumulative capacity of tiers 0..tier_id (paper Table IV col 2)."""
        return sum(t.spec.capacity for t in self.tiers[:tier_id + 1])

    def fault_stats(self) -> dict:
        """Fault-tolerance accounting + injected-fault counts + health."""
        out = dataclasses_asdict(self.counters)
        out["tier_health"] = {t.spec.tier_id:
                              self.health.state(t.spec.tier_id)
                              for t in self.tiers}
        if self.fault_injector is not None:
            out["injected"] = self.fault_injector.stats()
        return out

    def stats(self) -> List[dict]:
        out = []
        for t in self.tiers:
            d = t.stats_dict()
            d["health"] = self.health.state(t.spec.tier_id)
            out.append(d)
        return out


# ---------------------------------------------------------------------------
# Async tier transfers (paper §IV: "transfers overlap compute")
# ---------------------------------------------------------------------------
@dataclass
class TransferRequest:
    """One demotion/promotion/fetch to run off the engine step loop."""
    block_id: str
    src: int
    dst: int
    kind: str = "demote"          # demote | fetch | promote | custom
    payload: Optional[np.ndarray] = None
    nbytes: Optional[float] = None
    tag: str = ""                 # caller correlation key (e.g. request id)
    evict_src: bool = False       # fetch: drop the source copy after reading
    # custom: callable(hierarchy) -> (sim_time, payload | None)
    execute: Optional[Callable] = None
    ticket: int = 0
    timeout_s: Optional[float] = None   # per-transfer wall deadline
    #                                     (None -> worker default)


@dataclass
class TransferEvent:
    request: TransferRequest
    ok: bool
    sim_time: float = 0.0         # modelled transfer seconds (tier specs)
    wall_ms: float = 0.0          # host wall time on the worker thread
    payload: Optional[np.ndarray] = None
    error: Optional[str] = None


class AsyncTierTransferWorker:
    """Background transfer engine: the scheduler submits demotions /
    promotions / fetches and polls completion events, so tier traffic
    never blocks the decode step loop.

    Double-buffered submission: callers append to a staging buffer under
    a light lock; the worker swaps staging <-> active when it goes to
    execute, so submitters never contend with an in-progress transfer.
    A preempted request's payload therefore stays valid in the caller's
    staging copy until the demotion write completes — restores that
    arrive before the write finishes are served from the buffer for free.
    """

    def __init__(self, hierarchy: TierHierarchy, name: str = "kv-transfer",
                 *, fault_injector: Optional[FaultInjector] = None,
                 default_timeout_s: Optional[float] = 30.0):
        self.hierarchy = hierarchy
        self.fault_injector = (fault_injector if fault_injector is not None
                               else hierarchy.fault_injector)
        self.default_timeout_s = default_timeout_s
        self._staging: List[TransferRequest] = []
        self._completed: Deque[TransferEvent] = deque()
        # ticket -> (request, t0_wall, deadline_wall | None): transfers an
        # injected fault stalled forever.  They still count as in-flight
        # until their deadline expires into a failed TransferEvent.
        self._stalled: Dict[int, Tuple[TransferRequest, float,
                                       Optional[float]]] = {}
        self._cv = threading.Condition()
        self._stop = False
        self._inflight = 0
        self._tickets = itertools.count(1)
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.timeouts = 0
        self.stalled_total = 0
        self.max_inflight = 0
        self.sim_time_total = 0.0
        self.wall_ms_total = 0.0
        self._thread = threading.Thread(target=self._run, name=name,
                                        daemon=True)
        self._thread.start()

    # -- producer side ------------------------------------------------------
    def submit(self, req: TransferRequest) -> int:
        with self._cv:
            if self._stop:
                raise RuntimeError("transfer worker closed")
            req.ticket = next(self._tickets)
            self._staging.append(req)
            self.submitted += 1
            self._inflight += 1
            self.max_inflight = max(self.max_inflight, self._inflight)
            self._cv.notify_all()
        return req.ticket

    def poll(self) -> List[TransferEvent]:
        """Completion events since the last poll (non-blocking).  Also
        sweeps stalled transfers past their deadline into failed
        events, so the step loop sees timeouts without a worker wakeup."""
        with self._cv:
            self._expire_stalled_locked()
            out = list(self._completed)
            self._completed.clear()
        return out

    def drain(self, timeout: float = 10.0, *, escalate: bool = False) -> bool:
        """Block until every submitted transfer has completed.  With
        ``escalate=True`` the drain deadline is enforced: transfers still
        stalled when it expires are shed as failed ``TransferEvent``s
        (error="transfer timeout") so shutdown can never hang on an
        injected stall.  Returns True when nothing is left in flight."""
        deadline = time.monotonic() + timeout
        with self._cv:
            while self._inflight > 0:
                self._expire_stalled_locked()
                if self._inflight == 0:
                    break
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    if escalate:
                        self._expire_stalled_locked(force=True)
                    return self._inflight == 0
                self._cv.wait(min(remaining, 0.05))
            return True

    def _expire_stalled_locked(self, force: bool = False) -> None:
        """Turn stalled transfers whose deadline passed (or all of them,
        with ``force``) into failed completion events.  Caller holds
        ``_cv``."""
        if not self._stalled:
            return
        now = time.monotonic()
        for ticket in list(self._stalled):
            req, t0, dl = self._stalled[ticket]
            if not force and (dl is None or now - t0 < dl):
                continue
            del self._stalled[ticket]
            ev = TransferEvent(req, False, 0.0, (now - t0) * 1e3, None,
                               "transfer timeout")
            self._completed.append(ev)
            self._inflight -= 1
            self.completed += 1
            self.failed += 1
            self.timeouts += 1
        self._cv.notify_all()

    def close(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout=5.0)

    def stats(self) -> dict:
        with self._cv:
            return {"submitted": self.submitted,
                    "completed": self.completed,
                    "failed": self.failed,
                    "timeouts": self.timeouts,
                    "stalled": len(self._stalled),
                    "stalled_total": self.stalled_total,
                    "in_flight": self._inflight,
                    "max_inflight": self.max_inflight,
                    "sim_time_total": self.sim_time_total,
                    "wall_ms_total": self.wall_ms_total}

    # -- worker side --------------------------------------------------------
    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._staging and not self._stop:
                    if self._stalled:
                        # wake periodically to expire stalled transfers
                        self._cv.wait(0.05)
                        self._expire_stalled_locked()
                    else:
                        self._cv.wait()
                if self._stop and not self._staging:
                    return
                active, self._staging = self._staging, []   # buffer swap
            for req in active:
                inj = self.fault_injector
                if inj is not None and inj.should_stall(
                        req.src, req.block_id, req.kind):
                    with self._cv:
                        dl = (req.timeout_s if req.timeout_s is not None
                              else self.default_timeout_s)
                        self._stalled[req.ticket] = (req, time.monotonic(),
                                                     dl)
                        self.stalled_total += 1
                    continue
                ev = self._execute(req)
                with self._cv:
                    self._completed.append(ev)
                    self._inflight -= 1
                    self.completed += 1
                    if not ev.ok:
                        self.failed += 1
                    self.sim_time_total += ev.sim_time
                    self.wall_ms_total += ev.wall_ms
                    self._cv.notify_all()

    def _execute(self, req: TransferRequest) -> TransferEvent:
        t0 = time.monotonic()
        sim, payload = 0.0, None
        try:
            if req.execute is not None:
                sim, payload = req.execute(self.hierarchy)
            elif req.kind == "demote":
                if self.hierarchy[req.src].contains(req.block_id):
                    sim = self.hierarchy.move(req.block_id, req.src, req.dst,
                                              payload=req.payload)
                else:
                    sim = self.hierarchy.write_tier(
                        req.dst, req.block_id, req.payload,
                        nbytes=req.nbytes)
            elif req.kind == "fetch":
                payload, sim = self.hierarchy.read_tier(req.src,
                                                        req.block_id)
                if req.evict_src:
                    self.hierarchy[req.src].evict(req.block_id)
            elif req.kind == "promote":
                sim = self.hierarchy.move(req.block_id, req.src, req.dst)
            else:
                raise ValueError(f"unknown transfer kind {req.kind!r}")
            return TransferEvent(req, True, sim,
                                 (time.monotonic() - t0) * 1e3, payload)
        except Exception as e:                      # noqa: BLE001
            return TransferEvent(req, False, sim,
                                 (time.monotonic() - t0) * 1e3, None, str(e))
