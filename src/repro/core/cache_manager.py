"""The predictive multi-tier KV cache manager — the paper's contribution
wired together (Fig. 1).

Orchestrates:
  * architecture-variant-aware sizing          (core/sizing.py,   §III-A)
  * the six-tier hierarchy                     (core/tiers.py,    §III-B)
  * Bayesian reuse prediction                  (core/bayesian.py, §III-C)
  * head-granular EMA eviction                 (core/eviction.py, §III-D)
  * RoPE-aware prefetching                     (core/prefetch.py, §III-E)
  * content-addressable dedup + radix tree     (core/dedup.py,    §III-F)
  * agentic task-transition prediction         (core/agentic.py,  §III-G)

The manager is model-compute-agnostic: it tracks block *metadata* and tier
residency, so the same object drives both the live serving engine
(serving/engine.py, payload = real KV arrays) and the trace-replay
evaluation (traces/replay.py, metadata only) — matching the paper's §V
methodology.
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config import ModelConfig
from repro.core import sizing
from repro.core.agentic import MarkovToolPredictor, SessionFeatures, classify_session
from repro.core.bayesian import BayesianReusePredictor
from repro.core.dedup import ContentStore, RadixTree, content_hash
from repro.core.eviction import (BayesianPolicy, BlockMeta, EMAPolicy,
                                 EvictionPolicy, HeadImportanceTracker,
                                 LRUPolicy)
from repro.core.policy import PlacementPolicy
from repro.core.prefetch import RoPEPrefetcher
from repro.core.tiers import (PAPER_TIER_SPECS, CapacityError, TierHierarchy,
                              TierSpec)


@dataclass
class AccessResult:
    block_id: str
    hit: bool                    # resident in the hot set (tiers 0-1)?
    tier: Optional[int]          # tier found in (None = cold miss)
    fetch_time: float            # modelled transfer seconds (0 for t0 hit)
    recomputed: bool = False


@dataclass
class ManagerStats:
    accesses: int = 0
    hot_hits: int = 0            # tier 0+1 (paper Table V definition)
    hot_hits_t0: int = 0         # ... served straight from the tier-0 pool
    hot_hits_t1: int = 0         # ... resident in tier 1 (DRAM payload copy)
    tier_hits: Dict[int, int] = field(default_factory=dict)
    cold_misses: int = 0
    promotions: int = 0
    demotions: int = 0
    prefetch_issued: int = 0
    dedup_hits: int = 0
    reregistrations: int = 0     # known content re-registered after a drop
    #                              (a cold miss the radix path cannot see)
    fetch_time: float = 0.0
    recompute_time: float = 0.0

    @property
    def hit_rate(self) -> float:
        return self.hot_hits / self.accesses if self.accesses else 0.0

    @property
    def replay_hit_rate(self) -> float:
        """Table-V hit rate with dropped-then-reregistered blocks counted
        as cold misses (the live engine never issues a lookup for them —
        the radix prefix is gone — so plain ``hit_rate`` overstates)."""
        denom = self.accesses + self.reregistrations
        return self.hot_hits / denom if denom else 0.0


class PredictiveCacheManager:
    """Paper Fig. 1: the unified system."""

    def __init__(self, cfg: ModelConfig, *,
                 specs: Sequence[TierSpec] = PAPER_TIER_SPECS,
                 policy: str = "bayesian",
                 enable_dedup: bool = True,
                 enable_prefetch: bool = True,
                 enable_head_eviction: bool = True,
                 enable_multi_tier: bool = True,
                 hot_tiers: Tuple[int, ...] = (0, 1),
                 backing_root: Optional[str] = None):
        self.cfg = cfg
        self.block_tokens = sizing.block_tokens(cfg)
        self.block_bytes = sizing.block_bytes(cfg)
        self.hierarchy = TierHierarchy(
            specs if enable_multi_tier else specs[:2],
            backing_root=backing_root)
        self.predictor = BayesianReusePredictor()
        self.head_tracker = (HeadImportanceTracker(cfg)
                             if enable_head_eviction else None)
        self.policy_name = policy
        if policy == "lru":
            self.evictor: EvictionPolicy = LRUPolicy()
        elif policy == "ema":
            self.evictor = EMAPolicy()
        else:
            self.evictor = BayesianPolicy(self.head_tracker)
        self.placement = PlacementPolicy(self.hierarchy)
        self.store = ContentStore() if enable_dedup else None
        self.radix = RadixTree(self.block_tokens)
        self.prefetcher = (RoPEPrefetcher(self.block_tokens, cfg.n_layers)
                           if enable_prefetch else None)
        self.agentic = MarkovToolPredictor()
        self.hot_tiers = hot_tiers
        self.metas: Dict[str, BlockMeta] = {}
        self.stats = ManagerStats()
        self._clock = 0.0
        self._ids = itertools.count()
        self._lock = threading.RLock()
        self._payloads: Dict[str, np.ndarray] = {}

    # ------------------------------------------------------------------
    # time base (trace replay advances a virtual clock)
    # ------------------------------------------------------------------
    def tick(self, dt: float = 1.0) -> float:
        self._clock += dt
        return self._clock

    @property
    def now(self) -> float:
        return self._clock

    # ------------------------------------------------------------------
    # block registration (prefill path)
    # ------------------------------------------------------------------
    def _new_block_id(self) -> str:
        return f"blk{next(self._ids)}"

    def register_block(self, tokens: Sequence[int], *,
                       block_type: str = "user_context",
                       payload: Optional[np.ndarray] = None,
                       recompute_cost: float = 0.05,
                       positions: Tuple[int, int] = (0, 0)) -> Tuple[str, bool]:
        """Allocate (or dedup) one KV block; returns (block_id, was_dedup).

        Dedup (§III-F): identical content -> refcount bump, no new bytes.
        """
        with self._lock:
            h = content_hash(tokens, salt=self.cfg.name)
            if self.store is not None:
                canonical, dup = self.store.intern(h, self._new_block_id())
                if dup and canonical in self.metas:
                    self.stats.dedup_hits += 1
                    return canonical, True
                if dup:
                    # content seen before but its block was evicted from
                    # every tier: the caller recomputes — a cold miss
                    self.stats.reregistrations += 1
                bid = canonical
            else:
                bid = self._new_block_id()
            meta = BlockMeta(block_id=bid, nbytes=self.block_bytes,
                             block_type=block_type, last_access=self._clock,
                             access_count=1, recompute_cost=recompute_cost,
                             positions=positions)
            meta.content_hash = h          # type: ignore[attr-defined]
            meta.reuse_prob = self.predictor.reuse_probability(
                block_type, "reasoning_step")
            self.metas[bid] = meta
            if payload is not None:
                self._payloads[bid] = payload
            self._admit(meta, payload)
            return bid, False

    def register_sequence(self, tokens: Sequence[int], *,
                          block_type: str = "user_context",
                          block_types: Optional[Sequence[str]] = None,
                          recompute_cost_per_block: float = 0.05) -> List[str]:
        """Split a token sequence into blocks, dedup each, register the
        prefix in the radix tree, return the block ids.  ``block_types``
        optionally gives a per-block semantic type (index = block number;
        a multi-turn prompt mixes system/context/input blocks, and the
        Bayesian posteriors are keyed on the type)."""
        bt = self.block_tokens
        ids: List[str] = []
        n = (len(tokens) // bt) * bt
        for i in range(0, n, bt):
            btype = block_type
            if block_types is not None and i // bt < len(block_types):
                btype = block_types[i // bt]
            bid, _ = self.register_block(
                tokens[i:i + bt], block_type=btype,
                recompute_cost=recompute_cost_per_block,
                positions=(i, i + bt))
            ids.append(bid)
        if ids:
            self.radix.insert(tokens[:n], ids)
        return ids

    def match_prefix(self, tokens: Sequence[int]) -> List[str]:
        """Radix longest-prefix match -> reusable block ids (skipped
        prefill compute for the caller)."""
        return [bid for bid in self.radix.match(tokens) if bid in self.metas]

    # ------------------------------------------------------------------
    # admission & eviction
    # ------------------------------------------------------------------
    def _admit(self, meta: BlockMeta, payload: Optional[np.ndarray],
               tier_id: int = 0) -> None:
        self._make_room(tier_id, meta.nbytes)
        try:
            self.hierarchy[tier_id].write(meta.block_id, payload,
                                          nbytes=meta.nbytes)
        except CapacityError:
            # tier saturated with unevictable blocks -> place lower
            for t in self.hierarchy.active_tiers():
                if t.spec.tier_id > tier_id and t.free >= meta.nbytes:
                    t.write(meta.block_id, payload, nbytes=meta.nbytes)
                    return

    def _make_room(self, tier_id: int, nbytes: float,
                   _depth: int = 0) -> None:
        """Recursive demotion cascade: tier t's victims demote INTO tier
        t+1, which first makes room by pushing its own victims further
        down.  Without the cascade a full lower tier freezes forever and
        the hierarchy degenerates to a single hot tier.  Victim selection
        is batched (one policy scan frees several blocks) so replay stays
        O(accesses)."""
        if _depth >= self.hierarchy.n_tiers:
            return
        tier = self.hierarchy[tier_id]
        if not tier.available or tier.free >= nbytes:
            return
        need = int((nbytes - tier.free) // max(1.0, self.block_bytes)) + 2
        metas = [self.metas[b] for b in tier.blocks() if b in self.metas]
        victims = self.evictor.select_victims(metas, self._clock, need)
        nxt = None
        for t in self.hierarchy.tiers[tier_id + 1:]:
            if t.available:
                nxt = t.spec.tier_id
                break
        hot_exit = (tier_id in self.hot_tiers
                    and (nxt is None or nxt not in self.hot_tiers))
        for victim in victims:
            if hot_exit:
                self._observe_drop(victim)
            if nxt is None:
                tier.evict(victim.block_id)
                self.radix.remove_block(victim.block_id)
                self._payloads.pop(victim.block_id, None)
                self.metas.pop(victim.block_id, None)
            else:
                self._make_room(nxt, victim.nbytes, _depth + 1)
                try:
                    self.hierarchy.move(victim.block_id, tier_id, nxt)
                    self.stats.demotions += 1
                except CapacityError:
                    tier.evict(victim.block_id)
                    self.radix.remove_block(victim.block_id)
                    self._payloads.pop(victim.block_id, None)
                    self.metas.pop(victim.block_id, None)

    def _evict_one(self, tier_id: int) -> bool:
        free_before = self.hierarchy[tier_id].free
        self._make_room(tier_id, free_before + self.block_bytes)
        return self.hierarchy[tier_id].free > free_before

    def _observe_drop(self, meta: BlockMeta) -> None:
        """Bayesian miss signal: a block leaving the hot set that was
        never re-looked-up since registration counts one miss for its
        (type, transition) pair (observed once per block)."""
        if meta.access_count <= 1 and \
                not getattr(meta, "miss_observed", False):
            self.predictor.observe(meta.block_type, "reasoning_step", False)
            meta.miss_observed = True          # type: ignore[attr-defined]

    def _next_tier(self, tier_id: int, nbytes: float) -> Optional[int]:
        for t in self.hierarchy.tiers[tier_id + 1:]:
            if t.available and t.free >= nbytes:
                return t.spec.tier_id
        return None

    # ------------------------------------------------------------------
    # the access path (decode / lookup)
    # ------------------------------------------------------------------
    def access(self, block_id: str, *, transition: str = "reasoning_step",
               update_predictor: bool = True) -> AccessResult:
        """One cache lookup.  Hit definition follows the paper's Table V:
        resident in tiers 0-1.  Lower-tier residency counts as a miss but
        costs a (modelled) fetch instead of a full recompute."""
        with self._lock:
            self.stats.accesses += 1
            meta = self.metas.get(block_id)
            loc = self.hierarchy.locate(block_id)
            hit = loc is not None and loc in self.hot_tiers
            fetch_time = 0.0
            recomputed = False
            if meta is None:
                # unknown block: cold path, caller recomputes
                self.stats.cold_misses += 1
                return AccessResult(block_id, False, None, 0.0, True)
            if update_predictor:
                # a re-lookup IS a reuse event for this (type, transition)
                # pair, regardless of which tier currently holds the block
                self.predictor.observe(meta.block_type, transition, True)
                meta.miss_observed = True      # type: ignore[attr-defined]
            meta.reuse_prob = self.predictor.reuse_probability(
                meta.block_type, transition)
            meta.last_access = self._clock
            meta.access_count += 1
            if isinstance(self.evictor, EMAPolicy):
                self.evictor.touch(meta)
            if loc is None:
                # dropped entirely -> recompute
                self.stats.cold_misses += 1
                self.stats.recompute_time += meta.recompute_cost
                recomputed = True
                self._admit(meta, self._payloads.get(block_id))
            elif not hit:
                self.stats.tier_hits[loc] = self.stats.tier_hits.get(loc, 0) + 1
                fetch_time = self.hierarchy[loc].spec.transfer_time(meta.nbytes)
                self.stats.fetch_time += fetch_time
                # promote into the hot set
                self._promote(block_id, loc, 0)
            else:
                self.stats.hot_hits += 1
                if loc == 0:
                    self.stats.hot_hits_t0 += 1
                else:
                    self.stats.hot_hits_t1 += 1
                self.stats.tier_hits[loc] = self.stats.tier_hits.get(loc, 0) + 1
            return AccessResult(block_id, hit, loc, fetch_time, recomputed)

    def _promote(self, block_id: str, src: int, dst: int) -> None:
        meta = self.metas[block_id]
        tier = self.hierarchy[dst]
        while tier.free < meta.nbytes:
            if not self._evict_one(dst):
                return
        self.hierarchy.move(block_id, src, dst)
        self.stats.promotions += 1

    # ------------------------------------------------------------------
    # prefetch + agentic hooks
    # ------------------------------------------------------------------
    def prefetch_for_position(self, seq_blocks: Sequence[str],
                              position: int) -> int:
        if self.prefetcher is None:
            return 0
        reqs = self.prefetcher.plan(
            seq_blocks, position,
            resident=lambda b: (self.hierarchy.locate(b) in self.hot_tiers))
        for r in reqs:
            loc = self.hierarchy.locate(r.block_id)
            if loc is not None and loc not in self.hot_tiers:
                self._promote(r.block_id, loc, 0)
        self.stats.prefetch_issued += len(reqs)
        return len(reqs)

    def plan_prefetch(self, seq_blocks: Sequence[str],
                      position: int) -> List[Tuple[str, int]]:
        """RoPE-window prefetch candidates as (block_id, src_tier).

        The async serving path hands these to the tier transfer worker
        instead of promoting inline; ``prefetch_for_position`` remains
        the synchronous fallback."""
        if self.prefetcher is None:
            return []
        with self._lock:
            reqs = self.prefetcher.plan(
                seq_blocks, position,
                resident=lambda b: (self.hierarchy.locate(b)
                                    in self.hot_tiers))
            out: List[Tuple[str, int]] = []
            for r in reqs:
                loc = self.hierarchy.locate(r.block_id)
                if loc is not None and loc not in self.hot_tiers:
                    out.append((r.block_id, loc))
            self.stats.prefetch_issued += len(reqs)
            return out

    def promote_async(self, block_id: str, src: int) -> float:
        """Executed on the transfer worker thread: promote into tier 0
        under the manager lock (metas + hierarchy stay consistent).
        Returns the modelled fetch time, 0.0 if the block already moved."""
        with self._lock:
            loc = self.hierarchy.locate(block_id)
            meta = self.metas.get(block_id)
            if loc is None or loc in self.hot_tiers or meta is None:
                return 0.0
            t = self.hierarchy[loc].spec.transfer_time(meta.nbytes)
            self._promote(block_id, loc, 0)
            return t

    def on_tool_switch(self, prev_tool: Optional[str], tool: str,
                       kv_bytes: float = 0.0) -> str:
        """§III-G: record the transition, return its transition type."""
        self.agentic.observe_transition(prev_tool, tool, kv_bytes)
        ttype = self.agentic.transition_type(prev_tool, tool)
        if self.head_tracker is not None and ttype in ("tool_switch",
                                                       "agent_handoff"):
            # bias eviction away from heads serving the outgoing task
            self.head_tracker.set_transition_multipliers(
                np.full(self.head_tracker.matrix.shape[1], 0.8))
        return ttype

    # ------------------------------------------------------------------
    def release_sequence(self, block_ids: Sequence[str], *,
                         retain: bool = False) -> None:
        """Drop refcounts when a request completes; free blocks that hit 0
        AND have low predicted reuse (others linger for cross-request
        reuse — that is the whole point of the paper).

        ``retain=True`` (session continuation: the next turn resubmits
        this prefix) balances the request's dedup reference without ever
        dropping the last one, so the blocks stay registered and
        matchable.  The first retained release of a block leaves one
        standing reference for the session chain; tier eviction ignores
        refcounts, so residency stays capacity-bounded either way."""
        for bid in block_ids:
            meta = self.metas.get(bid)
            if meta is None:
                continue
            if self.store is not None:
                h = getattr(meta, "content_hash", None)
                if h is not None:
                    if retain:
                        if self.store.refcount(bid) > 1:
                            self.store.release(h)
                        continue
                    freed = self.store.release(h)
                    if freed is None:
                        continue     # other references remain
            if retain:
                continue
            if meta.reuse_prob < 0.2:
                loc = self.hierarchy.locate(bid)
                if loc is not None:
                    self.hierarchy[loc].evict(bid)
                self.radix.remove_block(bid)
                self.metas.pop(bid, None)
                self._payloads.pop(bid, None)

    def release_all(self) -> None:
        """Drop every block registration and tier-resident copy (replica
        failover teardown): payloads, tier residency, block metadata,
        the radix prefix index and the dedup store are all cleared so
        nothing keeps the dead replica's KV alive.  ``self.stats`` is
        deliberately retained — the cluster aggregates it after the
        replica is gone."""
        with self._lock:
            for tier in self.hierarchy.tiers:
                for bid in tier.blocks():
                    tier.evict(bid)
            self.metas.clear()
            self._payloads.clear()
            self.radix = RadixTree(self.block_tokens)
            if self.store is not None:
                self.store = ContentStore()

    def age_all(self) -> None:
        if isinstance(self.evictor, EMAPolicy):
            for m in self.metas.values():
                self.evictor.age(m)

    # ------------------------------------------------------------------
    def metrics(self) -> dict:
        """Prometheus-style metrics (paper §IV Observability)."""
        return {
            "hit_rate_hot": self.stats.hit_rate,
            "hit_rate_replay": self.stats.replay_hit_rate,
            "accesses": self.stats.accesses,
            "hot_hits_t0": self.stats.hot_hits_t0,
            "hot_hits_t1": self.stats.hot_hits_t1,
            "reregistrations": self.stats.reregistrations,
            "promotions": self.stats.promotions,
            "demotions": self.stats.demotions,
            "cold_misses": self.stats.cold_misses,
            "dedup": self.store.stats() if self.store else {},
            "tiers": self.hierarchy.stats(),
            "predictor": self.predictor.snapshot(),
            "cost_dollars": self.hierarchy.total_cost_dollars(),
        }
