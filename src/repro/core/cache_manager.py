"""The predictive multi-tier KV cache manager — the paper's contribution
wired together (Fig. 1).

Orchestrates:
  * architecture-variant-aware sizing          (core/sizing.py,   §III-A)
  * the six-tier hierarchy                     (core/tiers.py,    §III-B)
  * Bayesian reuse prediction                  (core/bayesian.py, §III-C)
  * head-granular EMA eviction                 (core/eviction.py, §III-D)
  * RoPE-aware prefetching                     (core/prefetch.py, §III-E)
  * content-addressable dedup + radix tree     (core/dedup.py,    §III-F)
  * agentic task-transition prediction         (core/agentic.py,  §III-G)

The manager is model-compute-agnostic: it tracks block *metadata* and tier
residency, so the same object drives both the live serving engine
(serving/engine.py, payload = real KV arrays) and the trace-replay
evaluation (traces/replay.py, metadata only) — matching the paper's §V
methodology.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config import ModelConfig
from repro.core import sizing
from repro.core.agentic import MarkovToolPredictor, SessionFeatures, classify_session
from repro.core.bayesian import BayesianReusePredictor
from repro.core.dedup import (ContentStore, RadixTree, SegmentIndex,
                              SegmentMatch, content_hash)
from repro.core.eviction import (BayesianPolicy, BlockMeta, EMAPolicy,
                                 EvictionPolicy, HeadImportanceTracker,
                                 LRUPolicy)
from repro.core.faults import (FaultInjector, HealthConfig, RetryPolicy,
                               TierIOError)
from repro.core.policy import PlacementPolicy
from repro.core.prefetch import RoPEPrefetcher
from repro.core.tiers import (PAPER_TIER_SPECS, CapacityError, FleetKVStore,
                              SharedTierView, TierHierarchy, TierSpec)


@dataclass
class AccessResult:
    block_id: str
    hit: bool                    # resident in the hot set (tiers 0-1)?
    tier: Optional[int]          # tier found in (None = cold miss)
    fetch_time: float            # modelled transfer seconds (0 for t0 hit)
    recomputed: bool = False


@dataclass
class ManagerStats:
    accesses: int = 0
    hot_hits: int = 0            # tier 0+1 (paper Table V definition)
    hot_hits_t0: int = 0         # ... served straight from the tier-0 pool
    hot_hits_t1: int = 0         # ... resident in tier 1 (DRAM payload copy)
    tier_hits: Dict[int, int] = field(default_factory=dict)
    cold_misses: int = 0
    promotions: int = 0
    demotions: int = 0
    prefetch_issued: int = 0
    dedup_hits: int = 0
    reregistrations: int = 0     # known content re-registered after a drop
    #                              (a cold miss the radix path cannot see)
    shared_tier_hits: int = 0    # blocks imported from the fleet-shared
    #                              tier (content another replica published)
    shared_publishes: int = 0    # blocks this replica published fleet-wide
    segment_lookups: int = 0     # match_segments calls (one scan per admit)
    segment_hits: int = 0        # live blocks matched past a divergence
    segment_lookup_time: float = 0.0   # wall seconds spent in segment scans
    fetch_time: float = 0.0
    recompute_time: float = 0.0
    # fault tolerance (core/faults.py): all zero without an injector
    retries: int = 0             # transient tier I/O errors absorbed
    io_errors: int = 0           # ops that exhausted the retry budget
    integrity_failures: int = 0  # corrupt payloads caught by checksum
    fetch_recomputes: int = 0    # fetches degraded to recompute
    tier_health: Dict[int, str] = field(default_factory=dict)

    @property
    def hit_rate(self) -> float:
        return self.hot_hits / self.accesses if self.accesses else 0.0

    @property
    def replay_hit_rate(self) -> float:
        """Table-V hit rate with dropped-then-reregistered blocks counted
        as cold misses (the live engine never issues a lookup for them —
        the radix prefix is gone — so plain ``hit_rate`` overstates)."""
        denom = self.accesses + self.reregistrations
        return self.hot_hits / denom if denom else 0.0


class PredictiveCacheManager:
    """Paper Fig. 1: the unified system."""

    def __init__(self, cfg: ModelConfig, *,
                 specs: Sequence[TierSpec] = PAPER_TIER_SPECS,
                 policy: str = "bayesian",
                 enable_dedup: bool = True,
                 enable_prefetch: bool = True,
                 enable_head_eviction: bool = True,
                 enable_multi_tier: bool = True,
                 hot_tiers: Tuple[int, ...] = (0, 1),
                 backing_root: Optional[str] = None,
                 fault_injector: Optional[FaultInjector] = None,
                 retry_policy: Optional[RetryPolicy] = None,
                 health_config: Optional[HealthConfig] = None):
        self.cfg = cfg
        self.block_tokens = sizing.block_tokens(cfg)
        self.block_bytes = sizing.block_bytes(cfg)
        self.hierarchy = TierHierarchy(
            specs if enable_multi_tier else specs[:2],
            backing_root=backing_root,
            fault_injector=fault_injector,
            retry_policy=retry_policy,
            health_config=health_config)
        self.predictor = BayesianReusePredictor()
        self.head_tracker = (HeadImportanceTracker(cfg)
                             if enable_head_eviction else None)
        self.policy_name = policy
        if policy == "lru":
            self.evictor: EvictionPolicy = LRUPolicy()
        elif policy == "ema":
            self.evictor = EMAPolicy()
        else:
            self.evictor = BayesianPolicy(self.head_tracker)
        self.placement = PlacementPolicy(self.hierarchy)
        self.store = ContentStore() if enable_dedup else None
        self.radix = RadixTree(self.block_tokens)
        self.segments = SegmentIndex(self.block_tokens, salt=cfg.name)
        self.prefetcher = (RoPEPrefetcher(self.block_tokens, cfg.n_layers)
                           if enable_prefetch else None)
        self.agentic = MarkovToolPredictor()
        self.hot_tiers = hot_tiers
        self.metas: Dict[str, BlockMeta] = {}
        self.stats = ManagerStats()
        self._clock = 0.0
        self._ids = itertools.count()
        self._lock = threading.RLock()
        self._payloads: Dict[str, np.ndarray] = {}
        # fleet-shared tier 4 (bound post-construction by the cluster)
        self._fleet: Optional[FleetKVStore] = None
        self._fleet_owner = ""
        self._fleet_view: Optional[SharedTierView] = None

    # ------------------------------------------------------------------
    # fleet-shared tier binding (cluster-owned tier-4 namespace)
    # ------------------------------------------------------------------
    @property
    def fleet_bound(self) -> bool:
        return self._fleet is not None

    def bind_fleet_store(self, store: FleetKVStore, owner: str) -> bool:
        """Swap this hierarchy's tier 4 for a ``SharedTierView`` over the
        cluster's fleet store.  Must happen before traffic — blocks
        already resident in the private tier 4 do not migrate.  Returns
        False when the hierarchy has no tier 4 (reduced hierarchies)."""
        with self._lock:
            for i, t in enumerate(self.hierarchy.tiers):
                if t.spec.tier_id == 4:
                    view = SharedTierView(store, owner,
                                          resolve_key=self._content_key)
                    view.available = t.available
                    view.fault_injector = self.hierarchy.fault_injector
                    if store.tier.fault_injector is None:
                        # shared store inherits the first bound replica's
                        # fault model (crc written at publish time)
                        store.tier.fault_injector = \
                            self.hierarchy.fault_injector
                    self.hierarchy.tiers[i] = view
                    self._fleet, self._fleet_owner = store, owner
                    self._fleet_view = view
                    return True
            return False

    def _content_key(self, block_id: str) -> Optional[str]:
        """Local block id -> fleet content key (None when the content
        hash is unknown, e.g. preempt payload blobs — those fall back to
        an owner-scoped key and never dedup across replicas)."""
        meta = self.metas.get(block_id)
        h = getattr(meta, "content_hash", None) if meta is not None else None
        return f"c:{h}" if h else None

    def publish_block(self, block_id: str) -> bool:
        """Push one registered block into the fleet-shared tier (content
        key + payload), acquiring this owner's reference.  Idempotent
        per block; a no-op without a bound fleet store."""
        view = self._fleet_view
        if view is None or not view.available:
            return False
        with self._lock:
            meta = self.metas.get(block_id)
            if meta is None:
                return False
            try:
                new_mapping = block_id not in view._map
                self.hierarchy.run_io(
                    view.spec.tier_id,
                    lambda: view.write(block_id,
                                       self._payloads.get(block_id),
                                       nbytes=meta.nbytes))
            except CapacityError:
                return False           # fleet pool full of live refs
            except TierIOError:
                return False           # fabric sick: publish skipped
            if new_mapping:
                self.stats.shared_publishes += 1
            return True

    def import_shared_block(self, tokens: Sequence[int], *,
                            block_type: str = "user_context",
                            recompute_cost: float = 0.05,
                            positions: Tuple[int, int] = (0, 0)
                            ) -> Optional[Tuple[str, np.ndarray]]:
        """Probe the fleet-shared tier for a block of identical content
        published by ANOTHER replica.  On hit: fetch the payload, charge
        a tier-4 demand fetch (the replay stall model prices it from the
        ``tier_hits`` delta, same as a local lower-tier hit), register
        the block locally and publish this owner's reference.  Returns
        (block_id, payload), or None when the content is locally known
        already (not a cross-replica import) or not in the fleet."""
        if self._fleet is None:
            return None
        with self._lock:
            h = content_hash(tokens, salt=self.cfg.name)
            if self.store is not None:
                canonical = self.store.lookup(h)
                if canonical is not None and canonical in self.metas:
                    return None        # local content: not an import
            key = f"c:{h}"
            if not self._fleet.has_payload(key):
                return None
            tid = self._fleet.tier.spec.tier_id
            try:
                payload, _ = self.hierarchy.run_io(
                    tid, lambda: self._fleet.fetch(key))
            except TierIOError:
                # exhausted retries or corrupt shared copy: the caller
                # recomputes the block instead of importing garbage
                self.stats.fetch_recomputes += 1
                return None
            if payload is None:
                return None
            self.stats.shared_tier_hits += 1
            self.stats.tier_hits[tid] = self.stats.tier_hits.get(tid, 0) + 1
            self.stats.fetch_time += \
                self._fleet.tier.spec.transfer_time(self.block_bytes)
            # a fleet fetch is NOT a local recompute: keep the
            # reregistration counter (replay's cold-miss proxy) flat
            rereg = self.stats.reregistrations
            bid, _ = self.register_block(
                tokens, block_type=block_type, payload=payload,
                recompute_cost=recompute_cost, positions=positions)
            self.stats.reregistrations = rereg
            self.publish_block(bid)
            return bid, payload

    def adopt_sequence(self, tokens: Sequence[int],
                       payloads: Sequence[Optional[np.ndarray]], *,
                       block_type: str = "user_context") -> List[str]:
        """Scale-out warm-up: register a remapped session's prefix blocks
        (payloads pushed from the previous owner) and index the prefix,
        so the joining replica's first turn hits hot instead of
        re-prefilling."""
        bt = self.block_tokens
        ids: List[str] = []
        n = (len(tokens) // bt) * bt
        with self._lock:
            for j, i in enumerate(range(0, n, bt)):
                pl = payloads[j] if j < len(payloads) else None
                bid, _ = self.register_block(
                    list(tokens[i:i + bt]), block_type=block_type,
                    payload=pl, positions=(i, i + bt))
                if pl is not None and bid not in self._payloads:
                    self._payloads[bid] = pl
                ids.append(bid)
            if ids:
                self.radix.insert(list(tokens[:n]), ids)
        return ids

    # ------------------------------------------------------------------
    # time base (trace replay advances a virtual clock)
    # ------------------------------------------------------------------
    def tick(self, dt: float = 1.0) -> float:
        self._clock += dt
        self.hierarchy.tick(dt)      # drives health probes under faults
        if self.hierarchy.fault_injector is not None:
            self.sync_fault_stats()
        return self._clock

    def sync_fault_stats(self) -> None:
        """Copy the hierarchy's fault-tolerance counters into
        ``ManagerStats`` (absolute values, idempotent) so replay results
        and fleet aggregation see them without reaching into the
        hierarchy."""
        c = self.hierarchy.counters
        self.stats.retries = c.retries
        self.stats.io_errors = c.io_errors
        self.stats.integrity_failures = c.integrity_failures
        self.stats.tier_health = self.hierarchy.health.as_dict()

    @property
    def now(self) -> float:
        return self._clock

    # ------------------------------------------------------------------
    # block registration (prefill path)
    # ------------------------------------------------------------------
    def _new_block_id(self) -> str:
        return f"blk{next(self._ids)}"

    def register_block(self, tokens: Sequence[int], *,
                       block_type: str = "user_context",
                       payload: Optional[np.ndarray] = None,
                       recompute_cost: float = 0.05,
                       positions: Tuple[int, int] = (0, 0)) -> Tuple[str, bool]:
        """Allocate (or dedup) one KV block; returns (block_id, was_dedup).

        Dedup (§III-F): identical content -> refcount bump, no new bytes.
        """
        with self._lock:
            h = content_hash(tokens, salt=self.cfg.name)
            if self.store is not None:
                canonical, dup = self.store.intern(h, self._new_block_id())
                if dup and canonical in self.metas:
                    self.stats.dedup_hits += 1
                    return canonical, True
                if dup:
                    # content seen before but its block was evicted from
                    # every tier: the caller recomputes — a cold miss
                    self.stats.reregistrations += 1
                bid = canonical
            else:
                bid = self._new_block_id()
            meta = BlockMeta(block_id=bid, nbytes=self.block_bytes,
                             block_type=block_type, last_access=self._clock,
                             access_count=1, recompute_cost=recompute_cost,
                             positions=positions)
            meta.content_hash = h          # type: ignore[attr-defined]
            meta.reuse_prob = self.predictor.reuse_probability(
                block_type, "reasoning_step")
            self.metas[bid] = meta
            if payload is not None:
                self._payloads[bid] = payload
            if len(tokens) == self.block_tokens:
                # position-independent content key: a later prompt can
                # resume on this block after a divergent span
                self.segments.insert_block(tokens, bid, digest=h)
            self._admit(meta, payload)
            return bid, False

    def register_sequence(self, tokens: Sequence[int], *,
                          block_type: str = "user_context",
                          block_types: Optional[Sequence[str]] = None,
                          recompute_cost_per_block: float = 0.05) -> List[str]:
        """Split a token sequence into blocks, dedup each, register the
        prefix in the radix tree, return the block ids.  ``block_types``
        optionally gives a per-block semantic type (index = block number;
        a multi-turn prompt mixes system/context/input blocks, and the
        Bayesian posteriors are keyed on the type)."""
        bt = self.block_tokens
        ids: List[str] = []
        n = (len(tokens) // bt) * bt
        for i in range(0, n, bt):
            btype = block_type
            if block_types is not None and i // bt < len(block_types):
                btype = block_types[i // bt]
            bid, _ = self.register_block(
                tokens[i:i + bt], block_type=btype,
                recompute_cost=recompute_cost_per_block,
                positions=(i, i + bt))
            ids.append(bid)
        if ids:
            self.radix.insert(tokens[:n], ids)
        return ids

    def match_prefix(self, tokens: Sequence[int]) -> List[str]:
        """Radix longest-prefix match -> reusable block ids (skipped
        prefill compute for the caller)."""
        return [bid for bid in self.radix.match(tokens) if bid in self.metas]

    def peek_prefix_blocks(self, tokens: Sequence[int]) -> int:
        """Number of live radix-matched prefix blocks, WITHOUT bumping
        hit counters — the prefix-aware router probes every replica per
        routed request, and probing must not skew the hotness signal."""
        depth = 0
        for bid in self.radix.probe(tokens):
            if bid not in self.metas:
                break
            depth += 1
        return depth

    def match_segments(self, tokens: Sequence[int],
                       start_block: int = 0) -> List[SegmentMatch]:
        """Content-segment matches past a radix divergence: maximal runs
        of live registered blocks among the full blocks of ``tokens``
        from block index ``start_block``.  The scan cost is metered into
        ``stats.segment_lookup_time`` so the benchmark can price lookup
        overhead against the reuse it recovers."""
        t0 = time.perf_counter()
        raw = self.segments.match(tokens, start_block=start_block)
        out: List[SegmentMatch] = []
        with self._lock:
            for seg in raw:
                # split runs at blocks dropped from every tier since
                # they were indexed (meta gone -> nothing to resume on)
                s, ids = seg.start_block, []
                for j, bid in enumerate(seg.block_ids):
                    if bid in self.metas:
                        if not ids:
                            s = seg.start_block + j
                        ids.append(bid)
                    else:
                        if len(ids) >= self.segments.min_blocks:
                            out.append(SegmentMatch(s, ids))
                        ids = []
                if len(ids) >= self.segments.min_blocks:
                    out.append(SegmentMatch(s, ids))
            self.stats.segment_lookups += 1
            self.stats.segment_hits += sum(m.n_blocks for m in out)
            self.stats.segment_lookup_time += time.perf_counter() - t0
        return out

    # ------------------------------------------------------------------
    # admission & eviction
    # ------------------------------------------------------------------
    def _admit(self, meta: BlockMeta, payload: Optional[np.ndarray],
               tier_id: int = 0) -> None:
        self._make_room(tier_id, meta.nbytes)
        try:
            self.hierarchy.write_tier(tier_id, meta.block_id, payload,
                                      nbytes=meta.nbytes)
        except (CapacityError, TierIOError):
            # tier saturated with unevictable blocks (or sick despite
            # retries) -> place lower; skip tiers that fail too
            for t in self.hierarchy.active_tiers():
                if t.spec.tier_id > tier_id and t.free >= meta.nbytes:
                    try:
                        self.hierarchy.write_tier(
                            t.spec.tier_id, meta.block_id, payload,
                            nbytes=meta.nbytes)
                        return
                    except (CapacityError, TierIOError):
                        continue

    def _make_room(self, tier_id: int, nbytes: float,
                   _depth: int = 0) -> None:
        """Recursive demotion cascade: tier t's victims demote INTO tier
        t+1, which first makes room by pushing its own victims further
        down.  Without the cascade a full lower tier freezes forever and
        the hierarchy degenerates to a single hot tier.  Victim selection
        is batched (one policy scan frees several blocks) so replay stays
        O(accesses)."""
        if _depth >= self.hierarchy.n_tiers:
            return
        tier = self.hierarchy[tier_id]
        if not tier.available or tier.free >= nbytes:
            return
        need = int((nbytes - tier.free) // max(1.0, self.block_bytes)) + 2
        metas = [self.metas[b] for b in tier.blocks() if b in self.metas]
        victims = self.evictor.select_victims(metas, self._clock, need)
        nxt = None
        for t in self.hierarchy.tiers[tier_id + 1:]:
            if t.available:
                nxt = t.spec.tier_id
                break
        hot_exit = (tier_id in self.hot_tiers
                    and (nxt is None or nxt not in self.hot_tiers))
        for victim in victims:
            if hot_exit:
                self._observe_drop(victim)
            if nxt is None:
                self._drop_block(victim.block_id)
            else:
                self._make_room(nxt, victim.nbytes, _depth + 1)
                try:
                    self.hierarchy.move(victim.block_id, tier_id, nxt)
                    self.stats.demotions += 1
                except (CapacityError, TierIOError):
                    # destination full, or demotion I/O exhausted its
                    # retries — the victim was leaving anyway: drop it
                    self._drop_block(victim.block_id)

    def _drop_block(self, block_id: str) -> None:
        """Fully unregister one block: evicted from EVERY tier (a block
        published to the fleet-shared tier 4 is dual-resident, and an
        evict of only its fastest copy would strand the shared-tier
        reference), plus radix/payload/meta teardown."""
        for t in self.hierarchy.tiers:
            t.evict(block_id)
        self.radix.remove_block(block_id)
        self.segments.remove_block(block_id)
        self._payloads.pop(block_id, None)
        self.metas.pop(block_id, None)

    def _evict_one(self, tier_id: int) -> bool:
        free_before = self.hierarchy[tier_id].free
        self._make_room(tier_id, free_before + self.block_bytes)
        return self.hierarchy[tier_id].free > free_before

    def _observe_drop(self, meta: BlockMeta) -> None:
        """Bayesian miss signal: a block leaving the hot set that was
        never re-looked-up since registration counts one miss for its
        (type, transition) pair (observed once per block)."""
        if meta.access_count <= 1 and \
                not getattr(meta, "miss_observed", False):
            self.predictor.observe(meta.block_type, "reasoning_step", False)
            meta.miss_observed = True          # type: ignore[attr-defined]

    def _next_tier(self, tier_id: int, nbytes: float) -> Optional[int]:
        for t in self.hierarchy.tiers[tier_id + 1:]:
            if t.available and t.free >= nbytes:
                return t.spec.tier_id
        return None

    # ------------------------------------------------------------------
    # the access path (decode / lookup)
    # ------------------------------------------------------------------
    def access(self, block_id: str, *, transition: str = "reasoning_step",
               update_predictor: bool = True) -> AccessResult:
        """One cache lookup.  Hit definition follows the paper's Table V:
        resident in tiers 0-1.  Lower-tier residency counts as a miss but
        costs a (modelled) fetch instead of a full recompute."""
        with self._lock:
            self.stats.accesses += 1
            meta = self.metas.get(block_id)
            loc = self.hierarchy.locate(block_id)
            hit = loc is not None and loc in self.hot_tiers
            fetch_time = 0.0
            recomputed = False
            if meta is None:
                # unknown block: cold path, caller recomputes
                self.stats.cold_misses += 1
                return AccessResult(block_id, False, None, 0.0, True)
            if update_predictor:
                # a re-lookup IS a reuse event for this (type, transition)
                # pair, regardless of which tier currently holds the block
                self.predictor.observe(meta.block_type, transition, True)
                meta.miss_observed = True      # type: ignore[attr-defined]
            meta.reuse_prob = self.predictor.reuse_probability(
                meta.block_type, transition)
            meta.last_access = self._clock
            meta.access_count += 1
            if isinstance(self.evictor, EMAPolicy):
                self.evictor.touch(meta)
            if loc is None:
                # dropped entirely -> recompute
                self.stats.cold_misses += 1
                self.stats.recompute_time += meta.recompute_cost
                recomputed = True
                self._admit(meta, self._payloads.get(block_id))
            elif not hit:
                try:
                    # promote into the hot set
                    self._promote(block_id, loc, 0)
                except TierIOError:
                    # exhausted retries or a corrupt copy: the fetch
                    # degrades to a recompute — evict the suspect copy,
                    # count a miss, and rebuild into the hot set so the
                    # caller re-prefills instead of hanging or decoding
                    # garbage
                    self.hierarchy[loc].evict(block_id)
                    self.stats.fetch_recomputes += 1
                    self.stats.cold_misses += 1
                    self.stats.recompute_time += meta.recompute_cost
                    recomputed = True
                    loc = None
                    self._admit(meta, self._payloads.get(block_id))
                else:
                    self.stats.tier_hits[loc] = \
                        self.stats.tier_hits.get(loc, 0) + 1
                    fetch_time = \
                        self.hierarchy[loc].spec.transfer_time(meta.nbytes)
                    self.stats.fetch_time += fetch_time
            else:
                self.stats.hot_hits += 1
                if loc == 0:
                    self.stats.hot_hits_t0 += 1
                else:
                    self.stats.hot_hits_t1 += 1
                self.stats.tier_hits[loc] = self.stats.tier_hits.get(loc, 0) + 1
            return AccessResult(block_id, hit, loc, fetch_time, recomputed)

    def _promote(self, block_id: str, src: int, dst: int) -> None:
        meta = self.metas[block_id]
        tier = self.hierarchy[dst]
        while tier.free < meta.nbytes:
            if not self._evict_one(dst):
                return
        self.hierarchy.move(block_id, src, dst)
        self.stats.promotions += 1

    # ------------------------------------------------------------------
    # prefetch + agentic hooks
    # ------------------------------------------------------------------
    def prefetch_for_position(self, seq_blocks: Sequence[str],
                              position: int) -> int:
        if self.prefetcher is None:
            return 0
        reqs = self.prefetcher.plan(
            seq_blocks, position,
            resident=lambda b: (self.hierarchy.locate(b) in self.hot_tiers))
        for r in reqs:
            loc = self.hierarchy.locate(r.block_id)
            if loc is not None and loc not in self.hot_tiers:
                self._promote(r.block_id, loc, 0)
        self.stats.prefetch_issued += len(reqs)
        return len(reqs)

    def plan_prefetch(self, seq_blocks: Sequence[str],
                      position: int) -> List[Tuple[str, int]]:
        """RoPE-window prefetch candidates as (block_id, src_tier).

        The async serving path hands these to the tier transfer worker
        instead of promoting inline; ``prefetch_for_position`` remains
        the synchronous fallback."""
        if self.prefetcher is None:
            return []
        with self._lock:
            reqs = self.prefetcher.plan(
                seq_blocks, position,
                resident=lambda b: (self.hierarchy.locate(b)
                                    in self.hot_tiers))
            out: List[Tuple[str, int]] = []
            for r in reqs:
                loc = self.hierarchy.locate(r.block_id)
                if loc is not None and loc not in self.hot_tiers:
                    out.append((r.block_id, loc))
            self.stats.prefetch_issued += len(reqs)
            return out

    def plan_prefetch_many(self, items: Sequence[Tuple[Sequence[str], int]]
                           ) -> List[Tuple[str, int]]:
        """Batched ``plan_prefetch``: plan every decoding request's
        RoPE-window prefetch under ONE lock acquisition (the fused step
        loop plans once per step, not once per request).  Candidate
        order matches the sequential per-request calls."""
        if self.prefetcher is None or not items:
            return []
        with self._lock:
            out: List[Tuple[str, int]] = []
            resident = (lambda b: (self.hierarchy.locate(b)
                                   in self.hot_tiers))
            for seq_blocks, position in items:
                reqs = self.prefetcher.plan(seq_blocks, position,
                                            resident=resident)
                for r in reqs:
                    loc = self.hierarchy.locate(r.block_id)
                    if loc is not None and loc not in self.hot_tiers:
                        out.append((r.block_id, loc))
                self.stats.prefetch_issued += len(reqs)
            return out

    def promote_async(self, block_id: str, src: int) -> float:
        """Executed on the transfer worker thread: promote into tier 0
        under the manager lock (metas + hierarchy stay consistent).
        Returns the modelled fetch time, 0.0 if the block already moved."""
        with self._lock:
            loc = self.hierarchy.locate(block_id)
            meta = self.metas.get(block_id)
            if loc is None or loc in self.hot_tiers or meta is None:
                return 0.0
            t = self.hierarchy[loc].spec.transfer_time(meta.nbytes)
            self._promote(block_id, loc, 0)
            return t

    def on_tool_switch(self, prev_tool: Optional[str], tool: str,
                       kv_bytes: float = 0.0) -> str:
        """§III-G: record the transition, return its transition type."""
        self.agentic.observe_transition(prev_tool, tool, kv_bytes)
        ttype = self.agentic.transition_type(prev_tool, tool)
        if self.head_tracker is not None and ttype in ("tool_switch",
                                                       "agent_handoff"):
            # bias eviction away from heads serving the outgoing task
            self.head_tracker.set_transition_multipliers(
                np.full(self.head_tracker.matrix.shape[1], 0.8))
        return ttype

    # ------------------------------------------------------------------
    def release_sequence(self, block_ids: Sequence[str], *,
                         retain: bool = False) -> None:
        """Drop refcounts when a request completes; free blocks that hit 0
        AND have low predicted reuse (others linger for cross-request
        reuse — that is the whole point of the paper).

        ``retain=True`` (session continuation: the next turn resubmits
        this prefix) balances the request's dedup reference without ever
        dropping the last one, so the blocks stay registered and
        matchable.  The first retained release of a block leaves one
        standing reference for the session chain; tier eviction ignores
        refcounts, so residency stays capacity-bounded either way."""
        for bid in block_ids:
            meta = self.metas.get(bid)
            if meta is None:
                continue
            if self.store is not None:
                h = getattr(meta, "content_hash", None)
                if h is not None:
                    if retain:
                        if self.store.refcount(bid) > 1:
                            self.store.release(h)
                        continue
                    freed = self.store.release(h)
                    if freed is None:
                        continue     # other references remain
            if retain:
                continue
            if meta.reuse_prob < 0.2:
                self._drop_block(bid)

    def release_all(self) -> None:
        """Drop every block registration and tier-resident copy (replica
        failover teardown): payloads, tier residency, block metadata,
        the radix prefix index and the dedup store are all cleared so
        nothing keeps the dead replica's KV alive.  With a bound fleet
        store, evicting the shared-tier view releases every one of THIS
        owner's fleet references — bytes other replicas still reference
        stay resident (the cross-replica refcount invariant).
        ``self.stats`` is deliberately retained — the cluster aggregates
        it after the replica is gone."""
        with self._lock:
            for tier in self.hierarchy.tiers:
                for bid in tier.blocks():
                    tier.evict(bid)
            self.metas.clear()
            self._payloads.clear()
            self.radix = RadixTree(self.block_tokens)
            self.segments = SegmentIndex(self.block_tokens,
                                         salt=self.cfg.name)
            if self.store is not None:
                self.store = ContentStore()

    def age_all(self) -> None:
        if isinstance(self.evictor, EMAPolicy):
            for m in self.metas.values():
                self.evictor.age(m)

    # ------------------------------------------------------------------
    def metrics(self) -> dict:
        """Prometheus-style metrics (paper §IV Observability)."""
        self.sync_fault_stats()
        return {
            "hit_rate_hot": self.stats.hit_rate,
            "hit_rate_replay": self.stats.replay_hit_rate,
            "accesses": self.stats.accesses,
            "hot_hits_t0": self.stats.hot_hits_t0,
            "hot_hits_t1": self.stats.hot_hits_t1,
            "reregistrations": self.stats.reregistrations,
            "promotions": self.stats.promotions,
            "demotions": self.stats.demotions,
            "cold_misses": self.stats.cold_misses,
            "shared_tier_hits": self.stats.shared_tier_hits,
            "shared_publishes": self.stats.shared_publishes,
            "segment_lookups": self.stats.segment_lookups,
            "segment_hits": self.stats.segment_hits,
            "segment_lookup_time": self.stats.segment_lookup_time,
            "retries": self.stats.retries,
            "io_errors": self.stats.io_errors,
            "integrity_failures": self.stats.integrity_failures,
            "fetch_recomputes": self.stats.fetch_recomputes,
            "tier_health": dict(self.stats.tier_health),
            "faults": self.hierarchy.fault_stats(),
            "segment_index": self.segments.stats(),
            "fleet": self._fleet.stats() if self._fleet else {},
            "dedup": self.store.stats() if self.store else {},
            "tiers": self.hierarchy.stats(),
            "predictor": self.predictor.snapshot(),
            "cost_dollars": self.hierarchy.total_cost_dollars(),
        }
