"""Agentic task-transition prediction (paper §III-G).

A first-order Markov chain over tool invocations tracks
P(tool_j | tool_i) from observed sequences, combined with per-tool KV
cache size profiles (EMA-smoothed mean / variance / peak).  On a detected
tool switch the serving engine:

  1. pre-allocates KV capacity for the predicted next tool,
  2. adjusts head-granular importance multipliers for the transition,
  3. prefetches the predicted tool's context blocks from lower tiers.

Sessions are classified into memory-demand tiers (Light / Medium / Heavy /
Extreme) from aggregate features for proactive capacity planning.
"""
from __future__ import annotations

import math
import threading
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

SESSION_CLASSES = ("light", "medium", "heavy", "extreme")


@dataclass
class ToolProfile:
    """EMA-smoothed KV-cache memory profile of one tool."""
    mean: float = 0.0
    var: float = 0.0
    peak: float = 0.0
    count: int = 0
    _decay: float = 0.8

    def observe(self, nbytes: float) -> None:
        if self.count == 0:
            self.mean = nbytes
        else:
            d = self._decay
            delta = nbytes - self.mean
            self.mean = d * self.mean + (1 - d) * nbytes
            self.var = d * self.var + (1 - d) * delta * delta
        self.peak = max(self.peak, nbytes)
        self.count += 1

    @property
    def std(self) -> float:
        return math.sqrt(max(0.0, self.var))


class MarkovToolPredictor:
    """First-order Markov chain over tool invocations."""

    def __init__(self, smoothing: float = 0.5):
        self.smoothing = smoothing
        self._counts: Dict[str, Dict[str, float]] = defaultdict(
            lambda: defaultdict(float))
        self._tools: set = set()
        self.profiles: Dict[str, ToolProfile] = defaultdict(ToolProfile)
        self._lock = threading.RLock()

    def observe_transition(self, prev_tool: Optional[str], tool: str,
                           kv_bytes: float = 0.0) -> None:
        with self._lock:
            self._tools.add(tool)
            if prev_tool is not None:
                self._tools.add(prev_tool)
                self._counts[prev_tool][tool] += 1.0
            if kv_bytes > 0:
                self.profiles[tool].observe(kv_bytes)

    def transition_probs(self, tool: str) -> Dict[str, float]:
        """Laplace-smoothed P(next | tool); sums to 1 over known tools."""
        with self._lock:
            tools = sorted(self._tools)
            if not tools:
                return {}
            row = self._counts.get(tool, {})
            s = self.smoothing
            denom = sum(row.values()) + s * len(tools)
            return {t: (row.get(t, 0.0) + s) / denom for t in tools}

    def predict_next(self, tool: str, k: int = 1) -> List[Tuple[str, float]]:
        probs = self.transition_probs(tool)
        return sorted(probs.items(), key=lambda kv: -kv[1])[:k]

    def predicted_memory_demand(self, tool: str) -> float:
        """Expected KV bytes of the most likely next tool (mean + 1 std,
        the pre-allocation target of §III-G step 1)."""
        nxt = self.predict_next(tool, k=1)
        if not nxt:
            return 0.0
        t, p = nxt[0]
        prof = self.profiles.get(t)
        if prof is None or prof.count == 0:
            return 0.0
        return p * (prof.mean + prof.std)

    def transition_type(self, prev_tool: Optional[str], tool: str) -> str:
        """Map a raw tool transition onto the predictor's 4 categories."""
        if prev_tool is None:
            return "reasoning_step"
        if prev_tool == tool:
            return "same_tool_repeat"
        if tool.startswith("agent:") or prev_tool.startswith("agent:"):
            return "agent_handoff"
        return "tool_switch"


# ---------------------------------------------------------------------------
# Session memory-demand classification (paper §III-G last paragraph)
# ---------------------------------------------------------------------------
@dataclass
class SessionFeatures:
    total_tokens: int = 0
    n_tool_calls: int = 0
    distinct_tools: int = 0
    peak_kv_bytes: float = 0.0


def classify_session(f: SessionFeatures,
                     *, gb: float = 1024 ** 3) -> str:
    score = 0
    if f.total_tokens > 8_192 or f.peak_kv_bytes > 2 * gb:
        score += 1
    if f.total_tokens > 32_768 or f.peak_kv_bytes > 8 * gb:
        score += 1
    if f.n_tool_calls > 10 or f.distinct_tools > 5 \
            or f.peak_kv_bytes > 32 * gb or f.total_tokens > 131_072:
        score += 1
    return SESSION_CLASSES[score]
