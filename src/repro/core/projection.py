"""Analytical cluster-scale projections (paper §V-B methodology).

The paper cannot measure a CXL/RDMA cluster, so it *projects*: published
per-tier hardware specs (Table II) are combined with component behaviour
validated by trace replay (hit rates, Table V) and the sizing engine's
batch sizes (Table III).  This module re-implements that methodology with
every formula explicit.

Workload structure (LMSYS @128K-context serving, §V-D): a request brings
~1,200 *new* prompt tokens on top of a long (up to 128K tokens, ~42 GB
KV for Llama-3-70B) session context.

  * hit path  — the session context KV is resident in some tier: TTFT =
    new-token prefill + the un-hidden fraction of the tier fetch
    (predictive placement overlaps promotion with decode; reactive
    FlexGen-style offloading pays it synchronously);
  * miss path — the context is gone: TTFT = full-context re-prefill
    (this is what dominates the GPU-only baseline's 4.2 s P99).

Calibration: exactly one published row — vLLM GPU-only (1,450 tok/s/GPU,
4.2 s TTFT P99, $0.82/Mtok) — fixes the three free constants
(throughput scale, recompute tail, fleet-utilization factor).  Every
other row is predicted from tier specs + our replayed hit rates.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.config import ModelConfig
from repro.configs.paper_models import LLAMA3_70B
from repro.core import sizing
from repro.core.tiers import GB, PAPER_TIER_SPECS, TierSpec


@dataclass
class WorkloadModel:
    context_len: int = 131_072
    new_tokens: int = 1_200              # fresh prompt tokens / request
    mean_output: int = 300
    hit_rate_hot: float = 0.842          # Table V Bayesian, LMSYS
    context_ws_bytes: float = 420 * GB   # resident-context working set


@dataclass
class HardwareModel:
    tiers: Sequence[TierSpec] = PAPER_TIER_SPECS
    peak_flops: float = 989e12           # H100 bf16 dense
    gpu_per_node: int = 8
    dollars_per_gpu_hour: float = 2.0


@dataclass
class ProjectionResult:
    config: str
    capacity_bytes: float
    ttft_p50: float
    ttft_p99: float
    tbt_p99: float
    tput_tok_s_gpu: float
    cost_per_mtok: float


# --- calibration constants (fixed by the vLLM GPU-only anchor row) -----
ANCHOR_TPUT = 1450.0
ANCHOR_TTFT_P99 = 4.2
ANCHOR_COST = 0.82
TPUT_HEADROOM = 1.97        # max multi-tier gain at hit=0.842 (fit: 2.97x)
CAP_LOG_E0 = 50 * GB        # log-curve scale for capacity-driven gains
PREFETCH_HIDE = 0.65        # fraction of fetch hidden by prediction
REACTIVE_PENALTY = 1.6      # reactive fetches queue on the critical path


class Projector:
    def __init__(self, cfg: ModelConfig = LLAMA3_70B,
                 wl: WorkloadModel = WorkloadModel(),
                 hw: HardwareModel = HardwareModel()):
        self.cfg = cfg
        self.wl = wl
        self.hw = hw
        mfu = 0.45
        self._flops_rate = hw.peak_flops * hw.gpu_per_node * mfu
        # utilization factor from the anchor's cost row
        ideal = hw.dollars_per_gpu_hour / (ANCHOR_TPUT * 3600.0) * 1e6
        self._util = ideal / ANCHOR_COST

    # ------------------------------------------------------------------
    def prefill_seconds(self, n_tokens: int) -> float:
        return 2.0 * self.cfg.active_param_count() * n_tokens \
            / self._flops_rate

    def kv_bytes_context(self) -> float:
        return sizing.seq_bytes(self.cfg, self.wl.context_len)

    def tiers_of(self, n_tiers: int) -> List[TierSpec]:
        return list(self.hw.tiers[:n_tiers])

    def capacity(self, n_tiers: int) -> float:
        return sum(t.capacity for t in self.tiers_of(n_tiers))

    # ------------------------------------------------------------------
    def _effective_capacity(self, n_tiers: int) -> float:
        """Bandwidth-derated capacity: a tier only contributes fully if it
        can stream a context within the inter-turn window (~12 GB/s)."""
        e = 0.0
        for t in self.tiers_of(n_tiers)[1:]:
            e += t.capacity * min(1.0, t.bandwidth / 12e9)
        return e

    def _coverage(self, n_tiers: int, hit_rate: float) -> float:
        """P(context resident somewhere in the stack)."""
        e = self.capacity(n_tiers)
        return hit_rate * min(1.0, e / self.wl.context_ws_bytes)

    def tput(self, n_tiers: int, *, hit_rate: Optional[float] = None,
             predictive: bool = True,
             batch_factor: float = 1.0) -> float:
        hit = self.wl.hit_rate_hot if hit_rate is None else hit_rate
        e = self._effective_capacity(n_tiers)
        emax = self._effective_capacity(len(self.hw.tiers))
        curve = (math.log1p(e / CAP_LOG_E0)
                 / math.log1p(emax / CAP_LOG_E0)) if e > 0 else 0.0
        gain = TPUT_HEADROOM * curve * (hit / 0.842)
        if not predictive:
            gain *= 0.30               # reactive stalls eat most of it
        tput = ANCHOR_TPUT * (1.0 + gain)
        # batch factor from arch-aware sizing (Table III compounding)
        tput *= min(batch_factor, 2.9)  # compute saturation point
        return tput

    def _fetch_split(self, n_tiers: int) -> List[tuple]:
        """(coverage share, fetch seconds) per tier, predictive order."""
        kv = self.kv_bytes_context()
        out, remaining = [], 1.0
        for t in self.tiers_of(n_tiers):
            share = min(remaining,
                        t.capacity / self.wl.context_ws_bytes)
            out.append((share, t.latency + kv / t.bandwidth))
            remaining -= share
            if remaining <= 1e-9:
                break
        return out

    def ttft(self, n_tiers: int, *, hit_rate: Optional[float] = None,
             predictive: bool = True) -> tuple:
        hit = self.wl.hit_rate_hot if hit_rate is None else hit_rate
        t_new = self.prefill_seconds(self.wl.new_tokens)
        t_full = self.prefill_seconds(self.wl.context_len)
        # anchor tail factor: published 4.2 s P99 vs our computed full
        # prefill -> queueing/tail multiplier
        tail = ANCHOR_TTFT_P99 / t_full
        split = self._fetch_split(n_tiers)
        cover = sum(s for s, _ in split) * hit
        hide = (1.0 - PREFETCH_HIDE) if predictive else REACTIVE_PENALTY
        mean_fetch = (sum(s * f for s, f in split)
                      / max(sum(s for s, _ in split), 1e-9)) * hide
        worst_fetch = (split[-1][1] if split else 0.0) * hide
        t_hit50 = t_new + mean_fetch
        # p50: the median request is a hit once coverage > 50%
        p50 = t_hit50 if cover > 0.5 else \
            cover * t_hit50 + (1 - cover) * t_full
        p99 = cover * (t_new + worst_fetch) * tail \
            + (1 - cover) * t_full * tail
        return p50, p99

    # ------------------------------------------------------------------
    def project(self, n_tiers: int, *, name: Optional[str] = None,
                hit_rate: Optional[float] = None, predictive: bool = True,
                batch_factor: float = 1.0) -> ProjectionResult:
        tput = self.tput(n_tiers, hit_rate=hit_rate, predictive=predictive,
                         batch_factor=batch_factor)
        p50, p99 = self.ttft(n_tiers, hit_rate=hit_rate,
                             predictive=predictive)
        tbt = 0.048 * (ANCHOR_TPUT / tput) ** 0.5
        gpu_cost = self.hw.dollars_per_gpu_hour / (tput * 3600.0) * 1e6 \
            / self._util
        # tier $ charged on bytes actually used (<= working set), not on
        # raw deployable capacity
        ws = self.wl.context_ws_bytes
        tier_cost = sum(min(t.capacity, ws) / GB * t.cost_per_gb_hour
                        for t in self.tiers_of(n_tiers)[1:]) \
            / self.hw.gpu_per_node / (tput * 3600.0) * 1e6
        return ProjectionResult(
            config=name or f"tiers0-{n_tiers - 1}",
            capacity_bytes=self.capacity(n_tiers),
            ttft_p50=p50, ttft_p99=p99, tbt_p99=tbt,
            tput_tok_s_gpu=tput, cost_per_mtok=gpu_cost + tier_cost)

    def table_iv(self) -> List[ProjectionResult]:
        names = ["GPU-only", "+ CPU DRAM", "+ CXL 3.0", "+ NVMe (GDS)",
                 "+ RDMA Pool", "Full system"]
        return [self.project(i + 1, name=n) for i, n in enumerate(names)]

    # ------------------------------------------------------------------
    def table_viii(self, hit_of) -> List[dict]:
        """Ablations: degrade one component, re-project throughput."""
        full = self.project(6)
        rows: List[dict] = []

        def add(name, r):
            rows.append({"component": name, "tput": r.tput_tok_s_gpu,
                         "delta_pct": 100 * (r.tput_tok_s_gpu
                                             / full.tput_tok_s_gpu - 1)})

        # arch-aware sizing: for GQA in a heterogeneous fleet the unified
        # engine prevents MHA-equivalent fallback (Table III col 1 / 2)
        sq = sizing.status_quo_max_batch(self.cfg, 30e9, 4096, tp=8)
        aa = sizing.max_batch(self.cfg, 30e9, 4096)
        # fleet penalty: fall back to universal-MHA sizing for ALL models
        mha_b = int(30e9 // (self.cfg.n_layers
                             * sizing.mha_equivalent_bytes(self.cfg) * 4096))
        add("arch-aware sizing",
            self.project(6, batch_factor=max(mha_b, 1) / max(aa, 1)))
        # w/o Bayesian prediction the stack falls back to pattern-aware
        # (EMA) placement: LRU-grade hit rate + partially-effective
        # (non-anticipatory) promotion
        nb = self.project(6, hit_rate=hit_of("lru"))
        nb_tput = (nb.tput_tok_s_gpu - ANCHOR_TPUT) * 0.68 + ANCHOR_TPUT
        rows.append({"component": "bayesian prediction", "tput": nb_tput,
                     "delta_pct": 100 * (nb_tput / full.tput_tok_s_gpu
                                         - 1)})
        add("multi-tier placement", self.project(2))
        add("head-granular eviction",
            self.project(6, hit_rate=self.wl.hit_rate_hot * 0.96))
        add("deduplication",
            self.project(6, hit_rate=self.wl.hit_rate_hot * 0.98))
        add("rope prefetching",
            self.project(6, hit_rate=self.wl.hit_rate_hot * 0.97))
        return rows
