"""Architecture-variant-aware KV cache sizing engine (paper §III-A, eq. (3)).

The engine replaces the universal MHA formula with a dispatch on the
attention variant inferred from the model config:

    B(n) = 2 * h    * d * p * n      MHA
    B(n) = 2 * h_kv * d * p * n      GQA / MQA
    B(n) = (d_latent + d_rope) * p * n   MLA

Tensor-parallel conventions (reverse-engineered so every cell of the
paper's Table III reproduces exactly — see tests/test_sizing.py):

  * ``status-quo`` sizing (the "MHA batch" column) models today's
    frameworks: MHA-equivalent byte counts with **query heads sharded by
    TP** (each GPU budgets for h_q / tp heads).
  * ``arch-aware`` sizing (our engine) uses the exact variant formula with
    the KV state **replicated across TP** — conservative and correct for
    MLA, whose latent vector is shared by all heads and cannot be
    head-sharded.

SSM / RWKV architectures have O(1) recurrent state instead of a KV cache;
``recurrent_state_bytes`` sizes it (the paper's technique degenerates to a
fixed-size allocation for these — DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.config import (GQA, MHA, MLA, MQA, FAMILY_HYBRID, FAMILY_RWKV,
                          FAMILY_ENCDEC, ModelConfig)

BYTES = {"bfloat16": 2, "float16": 2, "float32": 4, "int8": 1, "int4": 0.5}


def dtype_bytes(dtype: str) -> float:
    return BYTES[dtype]


# ---------------------------------------------------------------------------
# Per-token-per-layer bytes — eq. (3)
# ---------------------------------------------------------------------------
def per_token_layer_bytes(cfg: ModelConfig, *, p: float | None = None,
                          tp: int = 1, shard_kv: bool = False) -> float:
    """Exact per-layer KV bytes for ONE token under the inferred variant.

    ``tp``/``shard_kv``: optionally divide the head dimension count by the
    tensor-parallel degree (only meaningful for head-sharded variants; MLA
    latent state is never sharded).
    """
    p = dtype_bytes(cfg.dtype) if p is None else p
    variant = cfg.attention_variant
    d = cfg.hd
    if variant == MLA:
        return (cfg.d_latent + cfg.d_rope) * p
    if variant == "none":          # RWKV — no per-token KV state at all
        return 0.0
    h_kv = cfg.n_kv_heads
    if shard_kv and tp > 1:
        h_kv = max(1, math.ceil(h_kv / tp))
    return 2 * h_kv * d * p


def mha_equivalent_bytes(cfg: ModelConfig, *, p: float | None = None,
                         tp: int = 1) -> float:
    """The universal-MHA fallback today's frameworks apply to unsupported
    variants (q heads sharded by TP)."""
    p = dtype_bytes(cfg.dtype) if p is None else p
    h_q = max(1, math.ceil(cfg.n_heads / tp))
    return 2 * h_q * cfg.hd * p


# ---------------------------------------------------------------------------
# Sequence / batch level — eq. (4)
# ---------------------------------------------------------------------------
def seq_bytes(cfg: ModelConfig, n: int, **kw) -> float:
    """Full-model KV bytes for one sequence of n tokens: L * B(n)."""
    return cfg.n_layers * per_token_layer_bytes(cfg, **kw) * n


def total_bytes(cfg: ModelConfig, batch: int, n: int, **kw) -> float:
    """M_total = B_s * L * B(n_max)   (eq. (4))."""
    return batch * seq_bytes(cfg, n, **kw)


def max_batch(cfg: ModelConfig, budget_bytes: float, n_max: int, **kw) -> int:
    """B_s* = floor(M_target / (L * B(n_max)))."""
    per_seq = seq_bytes(cfg, n_max, **kw)
    if per_seq <= 0:
        return 1 << 30               # recurrent archs: not KV-bound
    return int(budget_bytes // per_seq)


def status_quo_max_batch(cfg: ModelConfig, budget_bytes: float, n_max: int,
                         *, tp: int = 8) -> int:
    """Batch size under MHA-equivalent sizing (paper Table III col 1)."""
    per_seq = cfg.n_layers * mha_equivalent_bytes(cfg, tp=tp) * n_max
    return int(budget_bytes // per_seq)


# ---------------------------------------------------------------------------
# Recurrent state (SSM / RWKV / hybrid) — the paper's formula extended
# ---------------------------------------------------------------------------
def recurrent_state_bytes(cfg: ModelConfig, *, p: float | None = None) -> float:
    """Per-sequence persistent state for attention-free mixing layers."""
    p = dtype_bytes(cfg.dtype) if p is None else p
    if cfg.family == FAMILY_RWKV:
        # wkv state [H, d_head, d_head] + token-shift vectors (2 per layer)
        per_layer = cfg.n_heads * cfg.hd * cfg.hd + 2 * cfg.d_model
        return cfg.n_layers * per_layer * p
    if cfg.family == FAMILY_HYBRID:
        per_layer = (cfg.n_ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
                     + (cfg.d_inner + 2 * cfg.ssm_state) * cfg.ssm_conv)
        return cfg.n_layers * per_layer * p
    return 0.0


def decode_state_bytes(cfg: ModelConfig, n: int, batch: int = 1) -> float:
    """Total decode-time state: KV cache (attention layers) + recurrent."""
    kv = 0.0
    if cfg.family == FAMILY_HYBRID:
        kv = len(cfg.attn_layer_ids()) * per_token_layer_bytes(cfg) * n
    elif cfg.family == FAMILY_ENCDEC:
        kv = cfg.n_layers * per_token_layer_bytes(cfg) * (n + cfg.enc_len)
    elif cfg.family != FAMILY_RWKV:
        kv = seq_bytes(cfg, n)
        if cfg.family == "vlm":
            kv += len(cfg.cross_attn_layer_ids()) * \
                per_token_layer_bytes(cfg) * cfg.n_patches
    return batch * (kv + recurrent_state_bytes(cfg))


# ---------------------------------------------------------------------------
# Block sizing (paper §III-B Tier 0: arch-aware block granularity)
# ---------------------------------------------------------------------------
def block_tokens(cfg: ModelConfig) -> int:
    """PagedAttention block size per variant (paper: 512 MLA / 128 GQA-MQA /
    64 MHA) — chosen so a block is a few hundred KB in every variant.
    ``cfg.kv_block_tokens`` overrides the variant default (trace replay
    shrinks blocks so reduced models see trace-scale reuse granularity)."""
    if cfg.kv_block_tokens > 0:
        return cfg.kv_block_tokens
    v = cfg.attention_variant
    if v == MLA:
        return 512
    if v in (GQA, MQA):
        return 128
    if v == MHA:
        return 64
    return 128                        # recurrent: logical block for dedup


def block_bytes(cfg: ModelConfig) -> float:
    """Bytes of one full-model KV block (all layers)."""
    return cfg.n_layers * per_token_layer_bytes(cfg) * block_tokens(cfg)


@dataclass(frozen=True)
class SizingReport:
    model: str
    variant: str
    per_token_layer: float
    mha_equivalent: float
    compression: float
    seq_bytes_4k: float
    max_batch_arch_aware: int
    max_batch_status_quo: int


def sizing_report(cfg: ModelConfig, *, budget_bytes: float = 30e9,
                  n_max: int = 4096, tp: int = 8) -> SizingReport:
    """One-stop report reproducing the paper's Tables I and III."""
    btl = per_token_layer_bytes(cfg)
    mha = mha_equivalent_bytes(cfg)          # unsharded (Table I)
    return SizingReport(
        model=cfg.name,
        variant=cfg.attention_variant,
        per_token_layer=btl,
        mha_equivalent=mha,
        compression=mha / btl if btl else float("inf"),
        seq_bytes_4k=seq_bytes(cfg, n_max),
        max_batch_arch_aware=max_batch(cfg, budget_bytes, n_max),
        max_batch_status_quo=status_quo_max_batch(cfg, budget_bytes, n_max, tp=tp),
    )
