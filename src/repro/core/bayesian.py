"""Bayesian reuse prediction with Beta conjugate priors (paper §III-C).

Reuse probability is modelled per (block-type, transition-type) pair —
|B| x |T| = 16 pairs, each with an independent Beta(alpha, beta) posterior
initialized from a weakly-informative Beta(1, 1) prior.  Posterior updates
are O(1): a reuse event increments alpha, a miss increments beta.

The final estimate blends the posterior mean with an empirical frequency
over a sliding window of recent observations, weighted by a confidence
score that saturates toward 1 as observations accumulate:

    confidence(n) = n / (n + k)                     (saturation constant k)
    P = confidence * posterior_mean + (1 - confidence) * empirical

Well-observed pairs therefore rely on the posterior; newly-created pairs
lean on the recent empirical window, giving rapid adaptation to
distribution shift (paper: "a new tool entering the agentic workflow").
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Iterable, Tuple

# Paper §III-C: the two categorical variables.
BLOCK_TYPES = ("system_prompt", "tool_context", "user_context",
               "intermediate_reasoning")
TRANSITION_TYPES = ("same_tool_repeat", "tool_switch", "reasoning_step",
                    "agent_handoff")

Pair = Tuple[str, str]


@dataclass
class BetaPosterior:
    alpha: float = 1.0               # weakly informative prior
    beta: float = 1.0

    @property
    def mean(self) -> float:
        return self.alpha / (self.alpha + self.beta)

    @property
    def observations(self) -> float:
        return self.alpha + self.beta - 2.0   # excludes the prior mass

    def update(self, reused: bool) -> None:
        if reused:
            self.alpha += 1.0
        else:
            self.beta += 1.0

    def variance(self) -> float:
        a, b = self.alpha, self.beta
        return (a * b) / ((a + b) ** 2 * (a + b + 1.0))


class BayesianReusePredictor:
    """Online reuse-probability estimator over 16 (block, transition) pairs.

    Thread-safe: serving threads observe events while the placement policy
    reads estimates concurrently (paper §IV Concurrency).
    """

    def __init__(self, *, prior_alpha: float = 1.0, prior_beta: float = 1.0,
                 confidence_k: float = 20.0, window: int = 256,
                 block_types: Iterable[str] = BLOCK_TYPES,
                 transition_types: Iterable[str] = TRANSITION_TYPES):
        self.block_types = tuple(block_types)
        self.transition_types = tuple(transition_types)
        self.confidence_k = float(confidence_k)
        self.window = int(window)
        self._lock = threading.RLock()
        self._post: Dict[Pair, BetaPosterior] = {}
        self._recent: Dict[Pair, Deque[bool]] = {}
        for b in self.block_types:
            for t in self.transition_types:
                self._post[(b, t)] = BetaPosterior(prior_alpha, prior_beta)
                self._recent[(b, t)] = deque(maxlen=self.window)

    # -- queries ----------------------------------------------------------
    def _key(self, block_type: str, transition: str) -> Pair:
        if block_type not in self.block_types:
            block_type = "user_context"
        if transition not in self.transition_types:
            transition = "reasoning_step"
        return (block_type, transition)

    def posterior_mean(self, block_type: str, transition: str) -> float:
        with self._lock:
            return self._post[self._key(block_type, transition)].mean

    def confidence(self, block_type: str, transition: str) -> float:
        """Saturates toward 1 with observation count: n / (n + k)."""
        with self._lock:
            n = self._post[self._key(block_type, transition)].observations
        return n / (n + self.confidence_k)

    def empirical(self, block_type: str, transition: str) -> float:
        with self._lock:
            buf = self._recent[self._key(block_type, transition)]
            if not buf:
                return 0.5
            return sum(buf) / len(buf)

    def reuse_probability(self, block_type: str, transition: str) -> float:
        """Confidence-blended estimate (paper §III-C, final paragraph)."""
        key = self._key(block_type, transition)
        with self._lock:
            post = self._post[key]
            buf = self._recent[key]
            n = post.observations
            c = n / (n + self.confidence_k)
            emp = (sum(buf) / len(buf)) if buf else post.mean
            return c * post.mean + (1.0 - c) * emp

    # -- updates ----------------------------------------------------------
    def observe(self, block_type: str, transition: str, reused: bool) -> None:
        key = self._key(block_type, transition)
        with self._lock:
            self._post[key].update(reused)
            self._recent[key].append(bool(reused))

    # -- introspection / metrics ------------------------------------------
    def snapshot(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        with self._lock:
            for (b, t), post in self._post.items():
                out[f"{b}|{t}"] = {
                    "alpha": post.alpha, "beta": post.beta,
                    "mean": post.mean, "obs": post.observations,
                    "confidence": post.observations /
                                  (post.observations + self.confidence_k),
                }
        return out

    def state_dict(self) -> dict:
        with self._lock:
            return {f"{b}|{t}": (p.alpha, p.beta)
                    for (b, t), p in self._post.items()}

    def load_state_dict(self, state: dict) -> None:
        with self._lock:
            for k, (a, bb) in state.items():
                b, t = k.split("|")
                self._post[(b, t)] = BetaPosterior(a, bb)


class ThompsonSampler:
    """Thompson-sampling placement exploration over the Beta posteriors
    (the paper cites Thompson 1933 for exactly this machinery): instead
    of the posterior mean, draw P_reuse ~ Beta(alpha, beta) — uncertain
    pairs occasionally win fast-tier placement, generating the
    observations that collapse their posteriors.  Used by the placement
    policy when exploration is enabled."""

    def __init__(self, predictor: BayesianReusePredictor, seed: int = 0):
        import random
        self.predictor = predictor
        self._rng = random.Random(seed)

    def sample_reuse(self, block_type: str, transition: str) -> float:
        key = self.predictor._key(block_type, transition)
        with self.predictor._lock:
            post = self.predictor._post[key]
            a, b = post.alpha, post.beta
        return self._rng.betavariate(a, b)
