"""Core: the paper's contribution (predictive multi-tier KV cache management)."""
from repro.core.sizing import (per_token_layer_bytes, mha_equivalent_bytes,
                               seq_bytes, total_bytes, max_batch,
                               status_quo_max_batch, block_tokens,
                               block_bytes, sizing_report)
from repro.core.bayesian import BayesianReusePredictor, BLOCK_TYPES, TRANSITION_TYPES
from repro.core.tiers import (TierHierarchy, TierManager, TierSpec, RDMATier,
                              ConsistentHashRing, PAPER_TIER_SPECS,
                              TPU_V5E_TIER_SPECS, CapacityError)
from repro.core.eviction import (HeadImportanceTracker, BlockMeta, LRUPolicy,
                                 EMAPolicy, BayesianPolicy, POLICIES)
from repro.core.prefetch import RoPEPrefetcher, PrefetchRequest
from repro.core.dedup import (ContentStore, RadixTree, content_hash,
                              payload_hash, delta_checkpoint, CheckpointManifest)
from repro.core.agentic import (MarkovToolPredictor, ToolProfile,
                                SessionFeatures, classify_session)
from repro.core.policy import PlacementPolicy, PlacementDecision
from repro.core.cache_manager import PredictiveCacheManager, AccessResult
