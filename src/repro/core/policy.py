"""Latency-aware tier placement policy (paper §III-B, last paragraph).

Each block gets a *value score* balancing the cost of recomputation
against the cost of storage at each tier.  For candidate tier t the
expected cost of placing block b there is

    C(b, t) = P_reuse(b) * fetch_time(t, bytes_b)          # latency cost
            + (1 - P_reuse(b)) * 0                          # never fetched
            + lam_cost * cost_rate(t) * bytes_b             # $ cost
    fetch beats recompute only if fetch_time < recompute_cost, else the
    block is not worth keeping below the recompute-equivalent tier.

The placement target is argmin_t C(b, t) over tiers with free capacity —
frequently-reused, compute-expensive blocks land in fast tiers; rarely
accessed blocks migrate toward cheap storage; blocks whose recompute is
cheaper than any fetch are simply dropped.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

from repro.core.eviction import BlockMeta
from repro.core.tiers import TierHierarchy


@dataclass
class PlacementDecision:
    tier: Optional[int]              # None -> drop (recompute on demand)
    expected_cost: float
    value_score: float


class PlacementPolicy:
    def __init__(self, hierarchy: TierHierarchy, *,
                 cost_weight: float = 1e-10,
                 promote_margin: float = 0.8):
        self.hierarchy = hierarchy
        self.cost_weight = cost_weight
        # a move must cut expected cost by this factor to be worth issuing
        self.promote_margin = promote_margin

    # ------------------------------------------------------------------
    def value_score(self, meta: BlockMeta) -> float:
        """Seconds of recompute expected to be saved by keeping the block."""
        return meta.reuse_prob * meta.recompute_cost

    def expected_cost(self, meta: BlockMeta, tier_id: int) -> float:
        spec = self.hierarchy[tier_id].spec
        fetch = spec.transfer_time(meta.nbytes)
        latency_cost = meta.reuse_prob * min(fetch, meta.recompute_cost)
        dollar_cost = self.cost_weight * spec.cost_per_gb_hour * meta.nbytes
        return latency_cost + dollar_cost

    def target_tier(self, meta: BlockMeta) -> PlacementDecision:
        best_tier, best_cost = None, meta.reuse_prob * meta.recompute_cost
        # cost of NOT caching at all = P_reuse * recompute
        for t in self.hierarchy.active_tiers():
            if t.free < meta.nbytes and not t.contains(meta.block_id):
                continue
            c = self.expected_cost(meta, t.spec.tier_id)
            if c < best_cost:
                best_tier, best_cost = t.spec.tier_id, c
        return PlacementDecision(best_tier, best_cost, self.value_score(meta))

    # ------------------------------------------------------------------
    def should_promote(self, meta: BlockMeta, current_tier: int) -> Optional[int]:
        """Async promotion check: returns a faster target tier or None."""
        decision = self.target_tier(meta)
        if decision.tier is None or decision.tier >= current_tier:
            return None
        cur = self.expected_cost(meta, current_tier)
        if decision.expected_cost <= self.promote_margin * cur:
            return decision.tier
        return None

    def demotion_order(self, metas: Sequence[BlockMeta]) -> List[BlockMeta]:
        """Lowest value first — these cascade to cheaper tiers."""
        return sorted(metas, key=self.value_score)
