"""Content-addressable deduplication (paper §III-F).

Three cooperating indexes:

  * a SHA-256 **content store** with reference counting — identical KV
    blocks (system prompts, few-shot examples, tool descriptions repeated
    verbatim) are stored once;
  * a **radix tree** over token-id sequences for longest-prefix matching —
    a new request reuses every cached block along its longest matched
    prefix (this is what converts dedup hits into skipped prefill compute);
  * a **segment index** keying every registered block by its salted
    content digest independent of prompt position, so a prefix match
    that diverges mid-prompt (history truncation shifting blocks left)
    can *resume* on contiguous content past the divergent span.

Checkpoint persistence to Tier 5 uses delta-encoding: a manifest
references already-present blocks by hash and only ships new ones
(paper Table VI: 10-30% savings).
"""
from __future__ import annotations

import bisect
import hashlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np


def content_hash(tokens: Sequence[int], salt: str = "") -> str:
    """SHA-256 over the block's token ids (+ model salt so equal token
    blocks from different models never alias)."""
    h = hashlib.sha256()
    if salt:
        h.update(salt.encode())
    h.update(np.asarray(tokens, dtype=np.int32).tobytes())
    return h.hexdigest()


def payload_hash(arr: np.ndarray) -> str:
    h = hashlib.sha256()
    h.update(str(arr.dtype).encode())
    h.update(str(arr.shape).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Reference-counted content store
# ---------------------------------------------------------------------------
class ContentStore:
    """hash -> (block_id, refcount).  The first writer owns the canonical
    block; later identical blocks just bump the refcount."""

    def __init__(self):
        self._by_hash: Dict[str, str] = {}
        self._refs: Dict[str, int] = {}
        self._lock = threading.RLock()
        self.dedup_hits = 0
        self.inserts = 0

    def intern(self, h: str, block_id: str) -> Tuple[str, bool]:
        """Returns (canonical_block_id, was_duplicate)."""
        with self._lock:
            if h in self._by_hash:
                canonical = self._by_hash[h]
                self._refs[canonical] += 1
                self.dedup_hits += 1
                return canonical, True
            self._by_hash[h] = block_id
            self._refs[block_id] = 1
            self.inserts += 1
            return block_id, False

    def contains_hash(self, h: str) -> bool:
        with self._lock:
            return h in self._by_hash

    def lookup(self, h: str) -> Optional[str]:
        """Canonical block id for ``h`` (None if never interned) —
        without touching the refcount."""
        with self._lock:
            return self._by_hash.get(h)

    def refcount(self, block_id: str) -> int:
        with self._lock:
            return self._refs.get(block_id, 0)

    def release(self, h: str) -> Optional[str]:
        """Drop one reference; returns the block_id to free if it hit 0."""
        with self._lock:
            canonical = self._by_hash.get(h)
            if canonical is None:
                return None
            self._refs[canonical] -= 1
            if self._refs[canonical] <= 0:
                del self._refs[canonical]
                del self._by_hash[h]
                return canonical
            return None

    def stats(self) -> dict:
        with self._lock:
            return {"unique_blocks": len(self._by_hash),
                    "dedup_hits": self.dedup_hits,
                    "inserts": self.inserts}


# ---------------------------------------------------------------------------
# Radix tree over token sequences (prefix reuse across requests)
# ---------------------------------------------------------------------------
@dataclass
class RadixNode:
    edge: Tuple[int, ...] = ()
    children: Dict[Tuple, "RadixNode"] = field(default_factory=dict)
    block_ids: List[str] = field(default_factory=list)   # blocks along edge
    hits: int = 0


class RadixTree:
    """Compressed trie over token ids, block-granular.

    Insertion registers a request's token prefix as a chain of blocks;
    ``match`` returns the cached block ids covering the longest shared
    block-aligned prefix of a new request.  Lookup is O(matched tokens);
    the paper quotes <1 us per block which holds here (see benchmarks).
    """

    def __init__(self, block_tokens: int):
        self.block_tokens = block_tokens
        self.root = RadixNode()
        self._lock = threading.RLock()

    def _blocks_of(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        bt = self.block_tokens
        n = (len(tokens) // bt) * bt
        return [tuple(tokens[i:i + bt]) for i in range(0, n, bt)]

    def insert(self, tokens: Sequence[int], block_ids: Sequence[str]) -> None:
        """Register full blocks of `tokens` mapped 1:1 onto `block_ids`."""
        blocks = self._blocks_of(tokens)
        assert len(block_ids) >= len(blocks), "one block id per full block"
        with self._lock:
            node = self.root
            for blk, bid in zip(blocks, block_ids):
                child = node.children.get(blk)   # keyed by full block
                if child is not None:
                    node = child
                    if bid not in node.block_ids:
                        node.block_ids.append(bid)
                else:
                    nxt = RadixNode(edge=blk, block_ids=[bid])
                    node.children[blk] = nxt
                    node = nxt

    def match(self, tokens: Sequence[int]) -> List[str]:
        """Longest block-aligned prefix match -> canonical block ids."""
        out: List[str] = []
        with self._lock:
            node = self.root
            for blk in self._blocks_of(tokens):
                child = node.children.get(blk)
                if child is None or not child.block_ids:
                    break
                child.hits += 1
                out.append(child.block_ids[0])
                node = child
        return out

    def probe(self, tokens: Sequence[int]) -> List[str]:
        """Non-mutating ``match``: the same longest-prefix walk without
        bumping hit counters.  The prefix-aware router polls EVERY
        replica's tree per routed request; probing must not skew the
        hotness signal the eviction policies read."""
        out: List[str] = []
        with self._lock:
            node = self.root
            for blk in self._blocks_of(tokens):
                child = node.children.get(blk)
                if child is None or not child.block_ids:
                    break
                out.append(child.block_ids[0])
                node = child
        return out

    def remove_block(self, block_id: str) -> None:
        """Unregister an evicted block everywhere (rare; full walk)."""
        with self._lock:
            stack = [self.root]
            while stack:
                n = stack.pop()
                for c in n.children.values():
                    if block_id in c.block_ids:
                        c.block_ids.remove(block_id)
                    stack.append(c)

    def size(self) -> int:
        count = 0
        stack = [self.root]
        while stack:
            n = stack.pop()
            count += len(n.children)
            stack.extend(n.children.values())
        return count


# ---------------------------------------------------------------------------
# Segment index: position-independent content lookup (resume past divergence)
# ---------------------------------------------------------------------------
@dataclass
class SegmentMatch:
    """One resumed run of content-matched blocks within a query prompt."""
    start_block: int                 # block index into the query's prompt
    block_ids: List[str]             # canonical block id per matched block

    @property
    def n_blocks(self) -> int:
        return len(self.block_ids)

    @property
    def end_block(self) -> int:
        return self.start_block + len(self.block_ids)


class SegmentIndex:
    """Content-digest index over block *segments*.

    The radix tree can only reuse the longest contiguous prefix: one
    divergent block (history truncation shifting the conversation left)
    loses everything after it.  This index keys every registered full
    block by its salted content digest with no positional context, so a
    match can **resume** after a divergent span: ``match`` scans a
    query's full blocks from a given block index and groups consecutive
    digest hits into maximal segments — non-overlapping and in prompt
    order by construction, one lookup per scanned block.

    Index contents are a pure function of the inserted
    (digest, block id) pairs: per digest the ids are kept sorted and
    ``lookup`` returns the smallest, so the index is invariant to
    session insertion order under a fixed salt.
    """

    def __init__(self, block_tokens: int, salt: str = "",
                 min_blocks: int = 1):
        self.block_tokens = block_tokens
        self.salt = salt
        self.min_blocks = max(1, min_blocks)
        self._by_digest: Dict[str, List[str]] = {}   # digest -> sorted ids
        self._digests_of: Dict[str, Set[str]] = {}   # block id -> digests
        self._lock = threading.RLock()
        self.lookups = 0
        self.hits = 0

    def block_digest(self, tokens: Sequence[int]) -> str:
        """Digest of one full block's token ids under the index salt."""
        assert len(tokens) == self.block_tokens, "full blocks only"
        return content_hash(tokens, salt=self.salt)

    def _blocks_of(self, tokens: Sequence[int]) -> List[Sequence[int]]:
        bt = self.block_tokens
        n = (len(tokens) // bt) * bt
        return [tokens[i:i + bt] for i in range(0, n, bt)]

    def insert_block(self, tokens: Sequence[int], block_id: str,
                     digest: Optional[str] = None) -> str:
        """Register one full block; returns its digest (computed from
        ``tokens`` unless the caller already has it)."""
        d = digest if digest is not None else self.block_digest(tokens)
        with self._lock:
            ids = self._by_digest.setdefault(d, [])
            if block_id not in ids:
                bisect.insort(ids, block_id)
            self._digests_of.setdefault(block_id, set()).add(d)
        return d

    def insert_sequence(self, tokens: Sequence[int],
                        block_ids: Sequence[str]) -> None:
        """Register every full block of ``tokens`` mapped 1:1 onto
        ``block_ids`` (same contract as ``RadixTree.insert``)."""
        blocks = self._blocks_of(tokens)
        assert len(block_ids) >= len(blocks), "one block id per full block"
        for blk, bid in zip(blocks, block_ids):
            self.insert_block(blk, bid)

    def lookup(self, digest: str) -> Optional[str]:
        """Canonical (smallest) block id registered for ``digest``."""
        with self._lock:
            ids = self._by_digest.get(digest)
            return ids[0] if ids else None

    def remove_block(self, block_id: str) -> None:
        """Unregister an evicted block from every digest it backed."""
        with self._lock:
            for d in self._digests_of.pop(block_id, ()):
                ids = self._by_digest.get(d)
                if ids is None:
                    continue
                try:
                    ids.remove(block_id)
                except ValueError:
                    pass
                if not ids:
                    del self._by_digest[d]

    def match(self, tokens: Sequence[int],
              start_block: int = 0) -> List[SegmentMatch]:
        """Scan full blocks of ``tokens`` from block index
        ``start_block`` and return maximal runs of content hits as
        segments (>= ``min_blocks`` long).  Segments never overlap and
        appear in prompt order — the scan is a single left-to-right
        pass, one digest lookup per block."""
        blocks = self._blocks_of(tokens)
        out: List[SegmentMatch] = []
        run_start, run_ids = -1, []     # current run of consecutive hits
        with self._lock:
            for i in range(max(0, start_block), len(blocks)):
                self.lookups += 1
                bid = self.lookup(self.block_digest(blocks[i]))
                if bid is not None:
                    self.hits += 1
                    if run_start < 0:
                        run_start = i
                    run_ids.append(bid)
                elif run_start >= 0:
                    if len(run_ids) >= self.min_blocks:
                        out.append(SegmentMatch(run_start, run_ids))
                    run_start, run_ids = -1, []
            if run_start >= 0 and len(run_ids) >= self.min_blocks:
                out.append(SegmentMatch(run_start, run_ids))
        return out

    def size(self) -> int:
        with self._lock:
            return len(self._by_digest)

    def stats(self) -> dict:
        with self._lock:
            return {"digests": len(self._by_digest),
                    "lookups": self.lookups,
                    "hits": self.hits}


# ---------------------------------------------------------------------------
# Delta-encoded checkpoints (Tier 5 persistence, paper Table VI)
# ---------------------------------------------------------------------------
@dataclass
class CheckpointManifest:
    """A checkpoint is a manifest: every block referenced by hash, plus the
    subset of payloads not already in the destination store."""
    block_hashes: List[str]
    new_blocks: Dict[str, float]        # hash -> bytes actually written
    reused_blocks: Dict[str, float]     # hash -> bytes skipped

    @property
    def raw_bytes(self) -> float:
        return sum(self.new_blocks.values()) + sum(self.reused_blocks.values())

    @property
    def written_bytes(self) -> float:
        return sum(self.new_blocks.values())

    @property
    def savings(self) -> float:
        raw = self.raw_bytes
        return 0.0 if raw == 0 else 1.0 - self.written_bytes / raw


def delta_checkpoint(blocks: Iterable[Tuple[str, float]],
                     present: ContentStore) -> CheckpointManifest:
    """blocks: iterable of (content_hash, nbytes).  Blocks whose hash is
    already in `present` are referenced, not re-written."""
    hashes, new, reused = [], {}, {}
    seen_local: Dict[str, float] = {}
    for h, nbytes in blocks:
        hashes.append(h)
        if present.contains_hash(h) or h in seen_local:
            reused[h] = reused.get(h, 0.0) + nbytes   # every appearance
        else:
            new[h] = nbytes                           # written once
            seen_local[h] = nbytes
    return CheckpointManifest(hashes, new, reused)
