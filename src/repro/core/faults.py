"""Deterministic fault injection and fault tolerance for the tier stack.

The paper (§VII) claims the six-tier hierarchy "maintains correctness
under tier failure and degraded fabric conditions", but clean membership
events (``fail_tier`` / ``fail_node``) are the easy half: real NVMe,
RDMA fabric, and parallel-filesystem tiers exhibit *transient* I/O
errors, latency brownouts, silent bit flips, and node flaps.  This
module provides the machinery the hierarchy uses to survive them:

  * ``FaultInjector`` — a seeded, per-tier fault model.  Attached via
    ``TierHierarchy(fault_injector=...)`` it makes ``TierManager.read/
    write`` (and the RDMA / fleet-shared subclasses) raise typed
    ``TierIOError``s, inflate transfer times during brownouts, flip
    payload bits, and flap RDMA ring nodes — all driven by one seeded
    RNG so a chaos run replays bit-identically.  When no injector is
    attached every hook is skipped entirely: the fault layer is inert.
  * ``RetryPolicy`` — bounded attempts, exponential backoff with
    deterministic seeded jitter, and a per-op delay deadline.  Backoff
    delays are *modelled* virtual seconds (accumulated by the caller),
    never wall-clock sleeps, so trace replay stays fast.
  * crc32 payload checksums (``payload_crc``) written at demote/publish
    time and verified on read/import — corruption is detected and
    converted to a miss (``TierIntegrityError``), never decoded.
  * ``TierHealthMonitor`` — a per-tier health state machine
    (healthy → degraded → quarantined → probing) that drives the
    hierarchy's route-around-sick-tiers behavior through the same
    ``available`` flag the ``fail_tier``/``restore_tier`` plumbing uses.
"""
from __future__ import annotations

import threading
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

__all__ = [
    "TierIOError", "TierIntegrityError", "FaultProfile", "FaultInjector",
    "RetryPolicy", "FaultCounters", "HealthConfig", "TierHealthMonitor",
    "payload_crc", "HEALTHY", "DEGRADED", "QUARANTINED", "PROBING",
]


# ---------------------------------------------------------------------------
# Typed errors
# ---------------------------------------------------------------------------
class TierIOError(RuntimeError):
    """A tier I/O operation failed (injected transient error, node flap,
    or transfer timeout).  Retryable unless it is a ``TierIntegrityError``."""

    def __init__(self, tier_id: int, op: str, block_id: str,
                 kind: str = "transient", detail: str = ""):
        self.tier_id = tier_id
        self.op = op
        self.block_id = block_id
        self.kind = kind
        msg = f"tier {tier_id} {op} {block_id!r}: {kind}"
        if detail:
            msg += f" ({detail})"
        super().__init__(msg)


class TierIntegrityError(TierIOError):
    """Payload failed its crc32 check on read — the copy is corrupt.
    Never retried: callers convert it to a miss and recompute."""

    def __init__(self, tier_id: int, op: str, block_id: str,
                 detail: str = ""):
        super().__init__(tier_id, op, block_id, kind="corruption",
                         detail=detail)


# ---------------------------------------------------------------------------
# Checksums
# ---------------------------------------------------------------------------
def payload_crc(payload: np.ndarray) -> int:
    """crc32 over the payload bytes (dtype-agnostic)."""
    return zlib.crc32(np.ascontiguousarray(payload).tobytes())


# ---------------------------------------------------------------------------
# Fault model
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class FaultProfile:
    """Per-tier fault rates.  All probabilities are per-operation."""
    read_error_rate: float = 0.0     # transient read failure
    write_error_rate: float = 0.0    # transient write failure
    corruption_rate: float = 0.0     # in-flight bit flip on read payloads
    brownout_rate: float = 0.0       # op lands in a latency brownout
    brownout_latency_mult: float = 10.0   # transfer-time multiplier then
    stall_rate: float = 0.0          # async transfer never completes
    flap_rate: float = 0.0           # RDMA tiers: ring node drops + rejoins

    @property
    def any_faults(self) -> bool:
        return (self.read_error_rate > 0 or self.write_error_rate > 0
                or self.corruption_rate > 0 or self.brownout_rate > 0
                or self.stall_rate > 0 or self.flap_rate > 0)


class FaultInjector:
    """Seeded per-tier fault source.

    One RNG drives every probabilistic decision, so a single seed
    reproduces an entire chaos run.  Tiers without a profile draw
    nothing — the op-ordering of a fault-free tier is untouched.
    Thread-safe: the worker thread and the step loop share the stream
    under a lock (cross-thread interleaving is the one nondeterminism
    async mode already has).
    """

    def __init__(self, profiles: Dict[int, FaultProfile], seed: int = 0):
        self.profiles = dict(profiles)
        self.seed = seed
        self.enabled = True
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self._forced_stalls: set = set()      # block ids stalled forever
        self._forced_corruptions: set = set()  # block ids corrupted once
        self.read_brownouts_by_tier: Dict[int, int] = {}
        self.counters: Dict[str, int] = {
            "injected_read_errors": 0,
            "injected_write_errors": 0,
            "injected_corruptions": 0,
            "injected_brownouts": 0,
            "injected_stalls": 0,
            "injected_flaps": 0,
        }

    # -- targeted faults (tests / smoke) ------------------------------------
    def force_stall(self, block_id: str) -> None:
        """Stall every async transfer of ``block_id`` forever."""
        self._forced_stalls.add(block_id)

    def clear_stall(self, block_id: str) -> None:
        self._forced_stalls.discard(block_id)

    def force_corrupt(self, block_id: str) -> None:
        """Corrupt the next read of ``block_id`` (one-shot)."""
        self._forced_corruptions.add(block_id)

    # -- probabilistic hooks ------------------------------------------------
    def _draw(self) -> float:
        with self._lock:
            return float(self._rng.random())

    def _bump(self, key: str) -> None:
        with self._lock:
            self.counters[key] += 1

    def check_read(self, tier_id: int, block_id: str) -> float:
        """Raises ``TierIOError`` on an injected transient read error;
        otherwise returns the transfer-time multiplier (>1 in brownout)."""
        prof = self.profiles.get(tier_id)
        if prof is None or not self.enabled:
            return 1.0
        if prof.read_error_rate > 0 and self._draw() < prof.read_error_rate:
            self._bump("injected_read_errors")
            raise TierIOError(tier_id, "read", block_id)
        if prof.brownout_rate > 0 and self._draw() < prof.brownout_rate:
            self._bump("injected_brownouts")
            with self._lock:
                # read brownouts stall demand fetches (write brownouts
                # land on async demotions, which overlap compute) — the
                # replay's stall model charges these per tier
                self.read_brownouts_by_tier[tier_id] = (
                    self.read_brownouts_by_tier.get(tier_id, 0) + 1)
            return prof.brownout_latency_mult
        return 1.0

    def check_write(self, tier_id: int, block_id: str) -> float:
        prof = self.profiles.get(tier_id)
        if prof is None or not self.enabled:
            return 1.0
        if prof.write_error_rate > 0 and self._draw() < prof.write_error_rate:
            self._bump("injected_write_errors")
            raise TierIOError(tier_id, "write", block_id)
        if prof.brownout_rate > 0 and self._draw() < prof.brownout_rate:
            self._bump("injected_brownouts")
            return prof.brownout_latency_mult
        return 1.0

    def maybe_corrupt(self, tier_id: int, block_id: str,
                      payload: np.ndarray) -> np.ndarray:
        """Possibly flip one bit in a COPY of the payload (the stored
        bytes stay intact — this models an in-flight/readback flip).
        The returned copy will fail its crc check."""
        if not self.enabled:
            return payload
        forced = block_id in self._forced_corruptions
        prof = self.profiles.get(tier_id)
        if not forced and (prof is None or prof.corruption_rate <= 0
                           or self._draw() >= prof.corruption_rate):
            return payload
        self._forced_corruptions.discard(block_id)
        self._bump("injected_corruptions")
        buf = np.array(payload, copy=True)
        flat = buf.reshape(-1).view(np.uint8)
        with self._lock:
            idx = int(self._rng.integers(0, flat.size)) if flat.size else 0
        if flat.size:
            flat[idx] ^= 0x01
        return buf

    def should_stall(self, tier_id: int, block_id: str,
                     kind: str = "") -> bool:
        """Async transfer worker hook: should this transfer hang?"""
        if not self.enabled:
            return False
        if block_id in self._forced_stalls:
            self._bump("injected_stalls")
            return True
        prof = self.profiles.get(tier_id)
        if prof is None or prof.stall_rate <= 0:
            return False
        if self._draw() < prof.stall_rate:
            self._bump("injected_stalls")
            return True
        return False

    def maybe_flap(self, tier, op: str, block_id: str) -> None:
        """RDMA tiers: with ``flap_rate`` probability drop one ring node
        (its blocks re-home onto survivors) and immediately rejoin it,
        failing the in-flight op with a transient ``TierIOError``."""
        if not self.enabled:
            return
        prof = self.profiles.get(tier.spec.tier_id)
        if prof is None or prof.flap_rate <= 0:
            return
        if self._draw() >= prof.flap_rate:
            return
        nodes = tier.ring.nodes
        if len(nodes) <= 1:
            return                      # never flap the last node
        with self._lock:
            node = nodes[int(self._rng.integers(0, len(nodes)))]
        tier.fail_node(node)
        tier.add_node(node)
        self._bump("injected_flaps")
        raise TierIOError(tier.spec.tier_id, op, block_id, kind="flap",
                          detail=f"node {node} flapped")

    def stats(self) -> dict:
        with self._lock:
            return dict(self.counters)


# ---------------------------------------------------------------------------
# Retry policy
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with exponential backoff and deterministic jitter.

    Delays are *modelled* seconds (virtual time) — the caller accumulates
    them into its transfer accounting; nothing sleeps.  Escalation
    happens on whichever bound trips first: ``max_attempts`` total tries
    or cumulative backoff delay exceeding ``deadline_s``.
    """
    max_attempts: int = 4
    base_delay_s: float = 1e-3
    backoff_mult: float = 2.0
    jitter_frac: float = 0.25
    deadline_s: float = 0.25
    seed: int = 0

    def delay(self, attempt: int,
              rng: Optional[np.random.Generator] = None) -> float:
        """Backoff delay after the ``attempt``-th failed try (1-based)."""
        d = self.base_delay_s * self.backoff_mult ** (attempt - 1)
        if self.jitter_frac > 0 and rng is not None:
            d *= 1.0 + self.jitter_frac * (2.0 * float(rng.random()) - 1.0)
        return d

    def schedule(self) -> List[float]:
        """The full deterministic backoff schedule for one op under this
        policy's seed: delays after failed attempts 1..max_attempts-1,
        truncated where the cumulative delay would cross the deadline."""
        rng = np.random.default_rng(self.seed)
        out: List[float] = []
        cum = 0.0
        for attempt in range(1, self.max_attempts):
            d = self.delay(attempt, rng)
            if cum + d > self.deadline_s:
                break
            cum += d
            out.append(d)
        return out


@dataclass
class FaultCounters:
    """Hierarchy-level fault-tolerance accounting (one per hierarchy)."""
    retries: int = 0                 # transient errors absorbed by retry
    io_errors: int = 0               # ops that exhausted the retry budget
    integrity_failures: int = 0      # corrupt payloads caught by checksum
    retry_delay_s: float = 0.0       # modelled backoff delay (virtual s)
    probes: int = 0                  # recovery probes of quarantined tiers
    probe_recoveries: int = 0        # probes that restored routing
    quarantines: int = 0             # health transitions into quarantine


# ---------------------------------------------------------------------------
# Per-tier health state machine
# ---------------------------------------------------------------------------
HEALTHY = "healthy"
DEGRADED = "degraded"
QUARANTINED = "quarantined"
PROBING = "probing"


@dataclass(frozen=True)
class HealthConfig:
    degraded_after: int = 3          # consecutive failures -> degraded
    quarantine_after: int = 8        # consecutive failures -> quarantined
    recover_successes: int = 3       # consecutive successes -> healthy
    probe_interval: float = 25.0     # virtual seconds between probes


class TierHealthMonitor:
    """healthy → degraded → quarantined → probing state machine.

    Pure bookkeeping: the hierarchy feeds it per-op outcomes and acts on
    the returned state (flipping ``available`` to route traffic around
    quarantined tiers).  The only path out of quarantine is a successful
    recovery probe — ``probe_result(tid, True)`` — so a sick tier can
    never silently rejoin the demotion graph.
    """

    def __init__(self, n_tiers: int, config: Optional[HealthConfig] = None):
        self.cfg = config or HealthConfig()
        self._state: Dict[int, str] = {i: HEALTHY for i in range(n_tiers)}
        self._fails: Dict[int, int] = {i: 0 for i in range(n_tiers)}
        self._oks: Dict[int, int] = {i: 0 for i in range(n_tiers)}
        self._quarantined_at: Dict[int, float] = {}
        self.quarantines = 0
        self.recoveries = 0

    def state(self, tier_id: int) -> str:
        return self._state.get(tier_id, HEALTHY)

    def as_dict(self) -> Dict[int, str]:
        return dict(self._state)

    def record_failure(self, tier_id: int, now: float = 0.0) -> str:
        st = self._state.get(tier_id, HEALTHY)
        if st in (QUARANTINED, PROBING):
            return st
        self._oks[tier_id] = 0
        self._fails[tier_id] = self._fails.get(tier_id, 0) + 1
        if self._fails[tier_id] >= self.cfg.quarantine_after:
            self._state[tier_id] = QUARANTINED
            self._quarantined_at[tier_id] = now
            self._fails[tier_id] = 0
            self.quarantines += 1
        elif self._fails[tier_id] >= self.cfg.degraded_after:
            self._state[tier_id] = DEGRADED
        return self._state[tier_id]

    def record_success(self, tier_id: int, now: float = 0.0) -> str:
        st = self._state.get(tier_id, HEALTHY)
        if st in (QUARANTINED, PROBING):
            return st
        self._fails[tier_id] = 0
        self._oks[tier_id] = self._oks.get(tier_id, 0) + 1
        if st == DEGRADED and self._oks[tier_id] >= self.cfg.recover_successes:
            self._state[tier_id] = HEALTHY
        return self._state[tier_id]

    def due_probe(self, tier_id: int, now: float) -> bool:
        """True (and transitions to PROBING) when a quarantined tier's
        probe interval has elapsed."""
        if self._state.get(tier_id) != QUARANTINED:
            return False
        if now - self._quarantined_at.get(tier_id, 0.0) < \
                self.cfg.probe_interval:
            return False
        self._state[tier_id] = PROBING
        return True

    def probe_result(self, tier_id: int, ok: bool, now: float = 0.0) -> str:
        """Outcome of a recovery probe.  Success is the ONLY transition
        out of quarantine; failure re-quarantines with a fresh timer."""
        if self._state.get(tier_id) != PROBING:
            return self._state.get(tier_id, HEALTHY)
        if ok:
            self._state[tier_id] = HEALTHY
            self._fails[tier_id] = 0
            self._oks[tier_id] = 0
            self._quarantined_at.pop(tier_id, None)
            self.recoveries += 1
        else:
            self._state[tier_id] = QUARANTINED
            self._quarantined_at[tier_id] = now
        return self._state[tier_id]
