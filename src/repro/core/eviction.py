"""Head-granular eviction with EMA importance (paper §III-D).

Maintains a [layer][head] importance matrix updated every attention step
with an exponential moving average that folds in recency and positional-
distance decay.  Architecture handling:

  * GQA — query heads sharing a KV head are grouped; the KV head's score
    is the max over its group.
  * MLA — the matrix collapses to [layer][1] (latent KV shared by heads).
  * MHA — uniform weights; MQA — single KV head.

Eviction picks the block with the lowest weighted aggregate importance.
During agentic task transitions, per-head multipliers bias eviction toward
heads less relevant for the incoming task (§III-G step 2).
"""
from __future__ import annotations

import math
import threading
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.config import GQA, MHA, MLA, MQA, ModelConfig


class HeadImportanceTracker:
    """EMA-scored per-(layer, head) importance matrix."""

    def __init__(self, cfg: ModelConfig, *, ema_decay: float = 0.6,
                 position_decay: float = 1e-4):
        self.cfg = cfg
        self.ema_decay = float(ema_decay)
        self.position_decay = float(position_decay)
        variant = cfg.attention_variant
        if variant == MLA:
            self.n_tracked = 1                      # latent bottleneck
        elif variant in (GQA, MQA, MHA):
            self.n_tracked = max(1, cfg.n_kv_heads)
        else:
            self.n_tracked = 1                      # recurrent archs
        n_layers = max(1, cfg.n_layers)
        self.matrix = np.full((n_layers, self.n_tracked), 0.5, dtype=np.float64)
        self.multipliers = np.ones_like(self.matrix)
        self._lock = threading.RLock()

    # ------------------------------------------------------------------
    def _fold_groups(self, per_q_head: np.ndarray) -> np.ndarray:
        """Map per-query-head scores onto tracked KV heads (max over the
        GQA group, paper §III-D)."""
        cfg = self.cfg
        if self.n_tracked == 1:
            return per_q_head.max(axis=-1, keepdims=True)
        g = cfg.q_group
        h_kv = cfg.n_kv_heads
        trimmed = per_q_head[..., :g * h_kv].reshape(*per_q_head.shape[:-1],
                                                     h_kv, g)
        return trimmed.max(axis=-1)

    def update(self, layer: int, attn_mass: np.ndarray,
               query_pos: Optional[int] = None,
               key_pos: Optional[np.ndarray] = None) -> None:
        """attn_mass: per-query-head attention mass [n_heads] for this step
        (e.g. sum of attention probabilities onto the tracked block).
        Positional-distance decay discounts mass on far-away keys."""
        mass = np.asarray(attn_mass, dtype=np.float64)
        if query_pos is not None and key_pos is not None:
            dist = abs(float(query_pos) - float(np.mean(key_pos)))
            mass = mass * math.exp(-self.position_decay * dist)
        folded = self._fold_groups(mass)
        a = self.ema_decay
        with self._lock:
            self.matrix[layer] = a * self.matrix[layer] + (1.0 - a) * folded

    def bulk_update(self, attn_mass: np.ndarray) -> None:
        """attn_mass [n_layers, n_heads] — one EMA step for all layers."""
        folded = self._fold_groups(np.asarray(attn_mass, dtype=np.float64))
        a = self.ema_decay
        with self._lock:
            self.matrix = a * self.matrix + (1.0 - a) * folded

    # ------------------------------------------------------------------
    def head_weights(self) -> np.ndarray:
        """Architecture-dependent aggregation weights (paper: uniform for
        MHA, proportional to group size for GQA)."""
        v = self.cfg.attention_variant
        if v == GQA:
            w = np.full(self.n_tracked, float(self.cfg.q_group))
        else:
            w = np.ones(self.n_tracked)
        return w / w.sum()

    def block_score(self, layers: Optional[Iterable[int]] = None) -> float:
        """Weighted aggregate importance (with task-transition multipliers)."""
        with self._lock:
            m = self.matrix * self.multipliers
        if layers is not None:
            idx = list(layers)
            m = m[idx] if idx else m
        return float((m * self.head_weights()[None, :]).mean(axis=0).sum())

    def set_transition_multipliers(self, mult: np.ndarray) -> None:
        with self._lock:
            self.multipliers = np.broadcast_to(
                np.asarray(mult, dtype=np.float64), self.matrix.shape).copy()

    def reset_multipliers(self) -> None:
        with self._lock:
            self.multipliers = np.ones_like(self.matrix)


# ---------------------------------------------------------------------------
# Block-level eviction policies (used by trace replay + live engine)
# ---------------------------------------------------------------------------
@dataclass
class BlockMeta:
    block_id: str
    nbytes: float
    block_type: str = "user_context"
    last_access: float = 0.0
    access_count: int = 0
    ema_score: float = 0.5
    reuse_prob: float = 0.5
    pinned: bool = False
    positions: Tuple[int, int] = (0, 0)      # token position range
    recompute_cost: float = 1.0              # seconds to regenerate


class EvictionPolicy:
    name = "base"

    def score(self, meta: BlockMeta, now: float) -> float:
        """Lower score evicts first."""
        raise NotImplementedError

    def select_victim(self, metas: Iterable[BlockMeta], now: float
                      ) -> Optional[BlockMeta]:
        best, best_s = None, math.inf
        for m in metas:
            if m.pinned:
                continue
            s = self.score(m, now)
            if s < best_s:
                best, best_s = m, s
        return best

    def select_victims(self, metas: Iterable[BlockMeta], now: float,
                       k: int) -> List[BlockMeta]:
        """k lowest-scoring victims in one scan (amortized eviction)."""
        import heapq
        scored = [(self.score(m, now), i, m)
                  for i, m in enumerate(metas) if not m.pinned]
        return [m for _, _, m in heapq.nsmallest(k, scored)]


class LRUPolicy(EvictionPolicy):
    """Reactive baseline (paper Problem 3)."""
    name = "lru"

    def score(self, meta: BlockMeta, now: float) -> float:
        return meta.last_access


class EMAPolicy(EvictionPolicy):
    """Pattern-aware baseline: recency-decayed access frequency."""
    name = "ema"

    def __init__(self, decay: float = 0.6):
        self.decay = decay

    def touch(self, meta: BlockMeta) -> None:
        meta.ema_score = self.decay * meta.ema_score + (1 - self.decay)

    def age(self, meta: BlockMeta) -> None:
        meta.ema_score = self.decay * meta.ema_score

    def score(self, meta: BlockMeta, now: float) -> float:
        return meta.ema_score


class BayesianPolicy(EMAPolicy):
    """The paper's predictive eviction: approximate Belady ordering using
    the Bayesian reuse posterior (§III-C) as a predicted-reuse-distance
    bonus on top of exact recency.

        score = last_access + horizon * P_reuse(type, transition)
              + horizon * w_r * tanh(recompute_cost)
              + horizon * w_h * head_importance

    A system-prompt block (P ~ 0.95) effectively stays "recent" for an
    extra ~horizon of virtual time after its last access; scratch
    reasoning (P ~ 0) degenerates to plain LRU and is evicted first.
    Blocks are evicted in ascending score order (lowest = evict first).
    """
    name = "bayesian"

    def __init__(self, head_tracker: Optional[HeadImportanceTracker] = None,
                 recompute_weight: float = 0.1, head_weight: float = 0.05,
                 horizon: float = 100.0, decay: float = 0.6):
        super().__init__(decay=decay)
        self.head_tracker = head_tracker
        self.recompute_weight = recompute_weight
        self.head_weight = head_weight
        self.horizon = horizon
        self._head_cache = (None, 0.0)     # (clock, score)

    def _head_score(self, now: float) -> float:
        if self.head_tracker is None:
            return 0.0
        if self._head_cache[0] != now:     # refresh once per clock tick
            self._head_cache = (now, self.head_tracker.block_score())
        return self._head_cache[1]

    def score(self, meta: BlockMeta, now: float) -> float:
        s = meta.last_access + self.horizon * meta.reuse_prob
        s += self.horizon * self.recompute_weight * \
            math.tanh(meta.recompute_cost)
        s += self.horizon * self.head_weight * self._head_score(now)
        return s


POLICIES = {"lru": LRUPolicy, "ema": EMAPolicy, "bayesian": BayesianPolicy}
