"""Prometheus-style observability (paper §IV).

"Per-tier capacity, hit rates, promotion/demotion rates, Bayesian
prediction accuracy, and per-model batch sizes are exported as Prometheus
metrics. Per-request cost tracking aggregates memory-tier-hours consumed
to compute $/Mtok."

A dependency-free registry with the text exposition format; the serving
engine and cache manager publish into it.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, Optional, Tuple

_Label = Tuple[Tuple[str, str], ...]


class Registry:
    def __init__(self):
        self._gauges: Dict[Tuple[str, _Label], float] = {}
        self._counters: Dict[Tuple[str, _Label], float] = {}
        self._help: Dict[str, str] = {}
        self._lock = threading.RLock()

    @staticmethod
    def _key(name: str, labels: Optional[dict]) -> Tuple[str, _Label]:
        return name, tuple(sorted((labels or {}).items()))

    def gauge(self, name: str, value: float, labels: Optional[dict] = None,
              help: str = "") -> None:
        with self._lock:
            self._gauges[self._key(name, labels)] = float(value)
            if help:
                self._help[name] = help

    def inc(self, name: str, value: float = 1.0,
            labels: Optional[dict] = None, help: str = "") -> None:
        with self._lock:
            k = self._key(name, labels)
            self._counters[k] = self._counters.get(k, 0.0) + float(value)
            if help:
                self._help[name] = help

    def get(self, name: str, labels: Optional[dict] = None) -> float:
        k = self._key(name, labels)
        with self._lock:
            if k in self._gauges:
                return self._gauges[k]
            return self._counters.get(k, 0.0)

    # -- text exposition format ------------------------------------------
    def expose(self) -> str:
        lines = []
        with self._lock:
            seen = set()
            for store, kind in ((self._gauges, "gauge"),
                                (self._counters, "counter")):
                for (name, labels), v in sorted(store.items()):
                    if name not in seen:
                        if name in self._help:
                            lines.append(f"# HELP {name} {self._help[name]}")
                        lines.append(f"# TYPE {name} {kind}")
                        seen.add(name)
                    if labels:
                        lab = ",".join(f'{k}="{val}"' for k, val in labels)
                        lines.append(f"{name}{{{lab}}} {v}")
                    else:
                        lines.append(f"{name} {v}")
        return "\n".join(lines) + "\n"


def publish_manager(reg: Registry, mgr, model: str = "model") -> None:
    """Publish a PredictiveCacheManager's state (paper §IV metric set)."""
    m = mgr.metrics()
    reg.gauge("kv_cache_hit_rate_hot", m["hit_rate_hot"],
              {"model": model}, help="tier 0+1 hit rate")
    reg.gauge("kv_cache_accesses_total", m["accesses"], {"model": model})
    reg.gauge("kv_cache_promotions_total", m["promotions"],
              {"model": model})
    reg.gauge("kv_cache_demotions_total", m["demotions"], {"model": model})
    reg.gauge("kv_cache_cost_dollars", m["cost_dollars"], {"model": model})
    for t in m["tiers"]:
        lab = {"model": model, "tier": t["tier"]}
        reg.gauge("kv_tier_used_bytes", t["used"], lab)
        reg.gauge("kv_tier_capacity_bytes", t["capacity"], lab)
        reg.gauge("kv_tier_reads_total", t["reads"], lab)
        reg.gauge("kv_tier_evictions_total", t["evictions"], lab)
    for pair, stats in m["predictor"].items():
        if stats["obs"] > 0:
            reg.gauge("kv_bayes_posterior_mean", stats["mean"],
                      {"model": model, "pair": pair})
