"""RoPE-aware prefetching (paper §III-E).

RoPE's rotational structure makes attention weights decay smoothly with
positional distance, so during decode at position n the blocks covering
positions [n, n + w] are the most likely next accesses.  The window w
adapts per layer: narrow for local-attention (early) layers, wide for
global-attention (late) layers, and grows/shrinks with the observed hit
rate of previous prefetches.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclass
class PrefetchRequest:
    block_id: str
    target_tier: int
    reason: str = "rope_window"


class RoPEPrefetcher:
    def __init__(self, block_tokens: int, n_layers: int,
                 *, base_window: int = 512, min_window: int = 128,
                 max_window: int = 4096, adapt_rate: float = 0.1):
        self.block_tokens = block_tokens
        self.n_layers = max(1, n_layers)
        self.base_window = base_window
        self.min_window = min_window
        self.max_window = max_window
        self.adapt_rate = adapt_rate
        self._window = float(base_window)
        self._lock = threading.RLock()
        self.issued = 0
        self.useful = 0

    # ------------------------------------------------------------------
    def layer_window(self, layer: int) -> int:
        """Early layers attend locally, late layers globally — scale the
        dynamic window linearly from 0.5x to 1.5x across depth."""
        frac = 0.5 + (layer / max(1, self.n_layers - 1))
        return int(max(self.min_window, min(self.max_window,
                                            self._window * frac)))

    @property
    def window(self) -> int:
        return int(self._window)

    def plan(self, seq_blocks: Sequence[str], position: int,
             resident: Callable[[str], bool], *, layer: Optional[int] = None,
             target_tier: int = 0) -> List[PrefetchRequest]:
        """Blocks covering positions [position, position + w] that are not
        already resident in the target tier -> async promotion requests."""
        w = self.layer_window(layer) if layer is not None else int(self._window)
        bt = self.block_tokens
        first = position // bt
        last = (position + w) // bt
        out: List[PrefetchRequest] = []
        for bi in range(first, min(last + 1, len(seq_blocks))):
            bid = seq_blocks[bi]
            if not resident(bid):
                out.append(PrefetchRequest(bid, target_tier))
        with self._lock:
            self.issued += len(out)
        return out

    # ------------------------------------------------------------------
    def feedback(self, was_useful: bool) -> None:
        """Adapt the window: widen when prefetches hit, narrow when they
        waste bandwidth."""
        with self._lock:
            if was_useful:
                self.useful += 1
                self._window = min(self.max_window,
                                   self._window * (1.0 + self.adapt_rate))
            else:
                self._window = max(self.min_window,
                                   self._window * (1.0 - self.adapt_rate))

    def stats(self) -> dict:
        with self._lock:
            return {"window": int(self._window), "issued": self.issued,
                    "useful": self.useful,
                    "accuracy": self.useful / self.issued if self.issued else 0.0}
