"""Mixture-of-Experts FFN with top-k routing (granite-moe archs).

GShard-style capacity-bounded dispatch.  Tokens are reshaped into
``[n_groups, group, d]`` with the group dim sharded over the data axes
(so dispatch never crosses shards), and the top-k slots are processed by
a sequential k-loop — peak dispatch tensor is O(group * E * C) per k-slot
instead of O(group * k * E * C).  The group size is kept small (256)
because the combine tensor scales with group^2 * k * cf.

Expert weights are tensor-parallel over the per-expert hidden dim
(``mlp`` -> model axis), which divides evenly for any expert count.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import NOSHARD, PSpec

GROUP = 256          # tokens per dispatch group


def moe_pspecs(cfg: ModelConfig) -> Dict[str, PSpec]:
    d, e, f = cfg.d_model, cfg.layout_n_experts, cfg.moe_ff
    return {
        "router": PSpec((d, e), ("embed", None)),
        "w_gate": PSpec((e, d, f), ("experts", "embed", "mlp")),
        "w_up": PSpec((e, d, f), ("experts", "embed", "mlp")),
        "w_down": PSpec((e, f, d), ("experts", "mlp", "embed"),
                        scale=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


def _n_groups(t: int, dp: int) -> int:
    """Group count: a multiple of the dp degree (so groups shard evenly)
    with ~GROUP tokens per group."""
    if t % dp != 0:
        dp = 1
    per_shard = t // dp
    g = dp * max(1, per_shard // GROUP)
    while t % g != 0:
        g -= 1
    return max(1, g)


def moe_ffn(p: Dict, x: jax.Array, cfg: ModelConfig,
            shd=NOSHARD) -> Tuple[jax.Array, jax.Array]:
    """x [B,S,D] -> (out [B,S,D], aux loss)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.layout_n_experts, cfg.top_k
    dp = getattr(shd, "dp_size", lambda: 1)()
    ng = _n_groups(t, dp)
    group = t // ng
    capacity = max(4, int(math.ceil(group * k / cfg.n_experts
                                    * cfg.capacity_factor)))

    xg = x.reshape(ng, group, d)
    xg = shd(xg, "moe_groups", None, None)
    logits = jnp.einsum("gtd,de->gte", xg, p["router"]).astype(jnp.float32)
    if e != cfg.n_experts:
        # padded experts (expert-parallel layout): never routable
        pad_mask = jnp.where(jnp.arange(e) < cfg.n_experts, 0.0, -1e9)
        logits = logits + pad_mask
    probs = jax.nn.softmax(logits, axis=-1)                 # [G,t,E]
    top_p, top_i = jax.lax.top_k(probs, k)                  # [G,t,k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    counts = jnp.zeros((ng, 1, e), jnp.float32)             # used capacity
    dispatch = jnp.zeros((ng, group, e, capacity), jnp.float32)
    combine = jnp.zeros((ng, group, e, capacity), jnp.float32)
    for j in range(k):                                      # sequential slots
        oh = jax.nn.one_hot(top_i[:, :, j], e, dtype=jnp.float32)  # [G,t,E]
        pos = counts + jnp.cumsum(oh, axis=1) - oh          # [G,t,E]
        pos_j = jnp.sum(pos * oh, axis=-1)                  # [G,t]
        keep = (pos_j < capacity).astype(jnp.float32)
        pos_oh = jax.nn.one_hot(pos_j.astype(jnp.int32), capacity,
                                dtype=jnp.float32)          # [G,t,C]
        disp = jnp.einsum("gte,gtc,gt->gtec", oh, pos_oh, keep)
        dispatch = dispatch + disp
        combine = combine + disp * (top_p[:, :, j] * keep)[..., None, None]
        counts = counts + jnp.sum(oh * keep[..., None], axis=1,
                                  keepdims=True)

    xin = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xg)
    xin = shd(xin, "moe_groups", None, None, None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["w_gate"])
                    .astype(jnp.float32)).astype(x.dtype)
    h = h * jnp.einsum("gecd,edf->gecf", xin, p["w_up"])
    h = shd(h, "moe_groups", None, None, "mlp")
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), y)
    # load-balance auxiliary loss (Switch eq. 4)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_i, e, dtype=jnp.float32).sum(axis=2),
        axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs) / k
    return out.reshape(b, s, d), aux
