"""Mamba2 (SSD) block — the sequence mixer of the zamba2 hybrid.

Training/prefill runs the chunked SSD algorithm: quadratic attention-like
computation inside fixed-size chunks, a linear recurrence across chunk
boundaries (lax.scan).  All exponentials are of non-positive arguments
(within-chunk decays), so the chunked form is numerically safe at any
chunk size.  Decode is the O(1) recurrent update.

State per sequence: ssm state [H, head_dim, N] + conv ring buffer — this
is what ``core/sizing.recurrent_state_bytes`` budgets (the paper's sizing
engine extended to attention-free mixers, DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import NOSHARD, PSpec, rms_norm

CHUNK = 64


def mamba_pspecs(cfg: ModelConfig) -> Dict[str, PSpec]:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    kw = cfg.ssm_conv
    return {
        "w_z": PSpec((d, di), ("embed", "inner")),
        "w_x": PSpec((d, di), ("embed", "inner")),
        "w_B": PSpec((d, n), ("embed", None)),
        "w_C": PSpec((d, n), ("embed", None)),
        "w_dt": PSpec((d, h), ("embed", "heads")),
        "conv_x": PSpec((kw, di), (None, "inner"), scale=0.2),
        "conv_B": PSpec((kw, n), (None, None), scale=0.2),
        "conv_C": PSpec((kw, n), (None, None), scale=0.2),
        "A_log": PSpec((h,), ("heads",), init="zeros"),
        "D": PSpec((h,), ("heads",), init="ones"),
        "dt_bias": PSpec((h,), ("heads",), init="zeros"),
        "norm": PSpec((di,), ("inner",), init="ones"),
        "w_out": PSpec((di, d), ("inner", "embed"),
                       scale=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


# ---------------------------------------------------------------------------
# causal depthwise conv
# ---------------------------------------------------------------------------
def _causal_conv(x: jax.Array, kernel: jax.Array) -> jax.Array:
    """x [B,S,C], kernel [K,C] -> causal depthwise conv [B,S,C]."""
    k = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    parts = [xp[:, i:i + x.shape[1], :] * kernel[i] for i in range(k)]
    return sum(parts)


def _conv_step(state: jax.Array, xt: jax.Array,
               kernel: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """state [B,K-1,C], xt [B,C] -> (new_state, y [B,C])."""
    k = kernel.shape[0]
    window = jnp.concatenate([state, xt[:, None, :]], axis=1)   # [B,K,C]
    y = jnp.einsum("bkc,kc->bc", window, kernel)
    return window[:, 1:, :], y


# ---------------------------------------------------------------------------
# chunked SSD (training / prefill)
# ---------------------------------------------------------------------------
def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b_in: jax.Array,
                c_in: jax.Array, *, chunk: int = CHUNK,
                init_state: jax.Array | None = None
                ) -> Tuple[jax.Array, jax.Array]:
    """x [B,S,H,P], dt [B,S,H] (>0), a [H] (<0), b_in/c_in [B,S,N].

    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    chunk = min(chunk, s)
    if s % chunk != 0:
        chunk = s
    nc = s // chunk
    xr = x.reshape(bsz, nc, chunk, h, p)
    dtr = dt.reshape(bsz, nc, chunk, h)
    br = b_in.reshape(bsz, nc, chunk, n)
    cr = c_in.reshape(bsz, nc, chunk, n)

    da = dtr * a                                   # [b,nc,c,h] (<= 0)
    cum = jnp.cumsum(da, axis=2)                   # inclusive
    # ---- intra-chunk (quadratic within chunk) ----
    li = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # [b,nc,i,j,h]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    li = jnp.where(mask[None, None, :, :, None], li, 0.0)
    scores = jnp.einsum("bzin,bzjn->bzij", cr, br).astype(jnp.float32)
    wx = (dtr[..., None] * xr).astype(jnp.float32)               # dt_j B_j x_j
    y_intra = jnp.einsum("bzij,bzijh,bzjhp->bzihp",
                         scores, li, wx)
    # ---- chunk states ----
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)                 # [b,nc,c,h]
    states = jnp.einsum("bzch,bzcn,bzchp->bzhpn",
                        (decay_end * dtr).astype(jnp.float32), br, xr)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                      # [b,nc,h]

    def scan_fn(carry, inp):
        st, dec = inp                              # [b,h,p,n], [b,h]
        new = carry * dec[:, :, None, None] + st
        return new, carry                          # emit state ENTERING chunk

    s0 = (jnp.zeros((bsz, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final, entering = jax.lax.scan(
        scan_fn, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    entering = entering.transpose(1, 0, 2, 3, 4)   # [b,nc,h,p,n]
    # ---- inter-chunk contribution ----
    y_inter = jnp.einsum("bzin,bzhpn->bzihp", cr, entering) \
        * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(bsz, s, h, p).astype(x.dtype)
    return y, final.astype(x.dtype)


def ssd_step(state: jax.Array, xt: jax.Array, dt: jax.Array, a: jax.Array,
             bt: jax.Array, ct: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """O(1) decode: state [B,H,P,N], xt [B,H,P], dt [B,H], bt/ct [B,N]."""
    dec = jnp.exp(dt * a)                                        # [B,H]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xt, bt)
    new_state = state * dec[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, ct)
    return new_state, y


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------
def mamba_block(p: Dict, x: jax.Array, cfg: ModelConfig, *,
                shd=NOSHARD) -> jax.Array:
    """Training/prefill path. x [B,S,D] -> [B,S,D]."""
    bsz, s, d = x.shape
    h, hd, n = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = jnp.einsum("bsd,di->bsi", x, p["w_z"])
    xs = _causal_conv(jnp.einsum("bsd,di->bsi", x, p["w_x"]), p["conv_x"])
    xs = shd(jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype),
             "batch", "seq", "inner")
    b_in = _causal_conv(jnp.einsum("bsd,dn->bsn", x, p["w_B"]), p["conv_B"])
    c_in = _causal_conv(jnp.einsum("bsd,dn->bsn", x, p["w_C"]), p["conv_C"])
    b_in = jax.nn.silu(b_in.astype(jnp.float32))
    c_in = jax.nn.silu(c_in.astype(jnp.float32))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(bsz, s, h, hd)
    y, _ = ssd_chunked(xh, dt, a, b_in, c_in)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(bsz, s, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm"], cfg.norm_eps)
    return jnp.einsum("bsi,id->bsd", y, p["w_out"])


def mamba_decode_step(p: Dict, xt: jax.Array, state: Dict, cfg: ModelConfig,
                      *, shd=NOSHARD) -> Tuple[jax.Array, Dict]:
    """xt [B,D]; state {ssm [B,H,P,N], conv_x [B,K-1,di],
    conv_B/conv_C [B,K-1,N]} -> (y [B,D], new state)."""
    bsz, d = xt.shape
    h, hd = cfg.n_ssm_heads, cfg.ssm_head_dim
    z = jnp.einsum("bd,di->bi", xt, p["w_z"])
    cx, xc = _conv_step(state["conv_x"],
                        jnp.einsum("bd,di->bi", xt, p["w_x"]), p["conv_x"])
    cb, bc = _conv_step(state["conv_B"],
                        jnp.einsum("bd,dn->bn", xt, p["w_B"]), p["conv_B"])
    cc, cc_in = _conv_step(state["conv_C"],
                           jnp.einsum("bd,dn->bn", xt, p["w_C"]), p["conv_C"])
    xs = jax.nn.silu(xc.astype(jnp.float32)).astype(xt.dtype)
    b_in = jax.nn.silu(bc.astype(jnp.float32))
    c_in = jax.nn.silu(cc_in.astype(jnp.float32))
    dt = jax.nn.softplus(
        jnp.einsum("bd,dh->bh", xt, p["w_dt"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(bsz, h, hd)
    new_ssm, y = ssd_step(state["ssm"].astype(jnp.float32), xh, dt, a,
                          b_in, c_in)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(bsz, cfg.d_inner).astype(xt.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(xt.dtype),
                 p["norm"], cfg.norm_eps)
    out = jnp.einsum("bi,id->bd", y, p["w_out"])
    new_state = {"ssm": new_ssm.astype(state["ssm"].dtype),
                 "conv_x": cx, "conv_B": cb, "conv_C": cc}
    return out, new_state


def mamba_state_pspecs(cfg: ModelConfig, batch: int) -> Dict[str, PSpec]:
    h, hd, n = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    k, di = cfg.ssm_conv, cfg.d_inner
    return {
        "ssm": PSpec((batch, h, hd, n), ("batch", "heads", None, None),
                     init="zeros"),
        "conv_x": PSpec((batch, k - 1, di), ("batch", None, "inner"),
                        init="zeros"),
        "conv_B": PSpec((batch, k - 1, n), ("batch", None, None),
                        init="zeros"),
        "conv_C": PSpec((batch, k - 1, n), ("batch", None, None),
                        init="zeros"),
    }


# ---------------------------------------------------------------------------
# reference (sequential) oracle for tests
# ---------------------------------------------------------------------------
def ssd_reference(x, dt, a, b_in, c_in):
    """Token-by-token recurrence; slow but obviously correct."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    state = jnp.zeros((bsz, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        state, y = ssd_step(state, x[:, t].astype(jnp.float32), dt[:, t], a,
                            b_in[:, t], c_in[:, t])
        ys.append(y)
    return jnp.stack(ys, axis=1).astype(x.dtype), state
