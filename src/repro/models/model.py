"""Unified model builder: one ``Model`` object per architecture family.

Entry points (all pure functions of (params, ...) — jit/pjit-ready):

    train_loss(params, batch)            -> (loss, metrics)
    prefill(params, batch)               -> (logits [B,V], DecodeState)
    decode_step(params, state, tokens)   -> (logits [B,V], DecodeState)

Layer stacks are scanned (``lax.scan`` over stacked per-layer params) with
``jax.checkpoint`` rematerialization in training — compile time and HLO
size stay O(1) in depth.  Heterogeneous families (VLM cross-attention
every 5 layers, zamba2's shared attention every 6 Mamba2 layers) scan
homogeneous segments and interleave the special blocks.

DecodeState is a dict pytree; KV caches are laid out [L, B, S, H_kv, hd]
(or [L, B, S, d_latent+d_rope] for MLA) so the sequence dim can be
sharded over the ``model`` mesh axis for flash-decoding-style decode
(DESIGN.md §Decode-sharding).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import (FAMILY_DECODER, FAMILY_ENCDEC, FAMILY_HYBRID,
                          FAMILY_MOE, FAMILY_RWKV, FAMILY_VLM, KIND_DECODE,
                          KIND_PREFILL, KIND_TRAIN, MLA, ModelConfig,
                          ShapeConfig)
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (NOSHARD, PSpec, abstract, cross_entropy,
                                 layer_norm, materialize, rms_norm,
                                 sinusoidal_positions, stack_specs, swiglu)

Params = Any
Batch = Dict[str, jax.Array]
DecodeState = Dict[str, Any]


def _ln_spec(d: int) -> PSpec:
    return PSpec((d,), ("embed",), init="ones")


def _dense_ffn_pspecs(cfg: ModelConfig) -> Dict[str, PSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": PSpec((d, f), ("embed", "mlp")),
        "w_up": PSpec((d, f), ("embed", "mlp")),
        "w_down": PSpec((f, d), ("mlp", "embed"),
                        scale=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


def _sinusoid_at(positions: jax.Array, dim: int) -> jax.Array:
    """Sinusoidal embedding at arbitrary integer positions [...]->[...,dim]."""
    i = jnp.arange(dim // 2, dtype=jnp.float32)
    angle = positions[..., None].astype(jnp.float32) / jnp.power(
        10000.0, 2 * i / dim)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ===========================================================================
# Base class
# ===========================================================================
class Model:
    family: str = "base"

    def __init__(self, cfg: ModelConfig, shd=NOSHARD,
                 aligned_decode: bool = False, scan_unroll: int = 1,
                 kv_dtype: str = "bfloat16"):
        self.cfg = cfg
        self.shd = shd
        self.aligned = aligned_decode
        self.scan_unroll = scan_unroll   # layer-scan unroll factor (perf)
        self.kv_dtype = kv_dtype         # "bfloat16" | "int8" (decode cache)
        self.specs = self.param_specs()

    # -- params -------------------------------------------------------------
    def param_specs(self) -> Params:
        raise NotImplementedError

    def init_params(self, rng: jax.Array) -> Params:
        return materialize(self.specs, rng, dtype=jnp.bfloat16)

    def abstract_params(self) -> Params:
        return abstract(self.specs)

    # -- state --------------------------------------------------------------
    def decode_state_specs(self, batch: int, max_len: int) -> Params:
        raise NotImplementedError

    def init_decode_state(self, batch: int, max_len: int) -> DecodeState:
        specs = self.decode_state_specs(batch, max_len)
        state = jax.tree.map(
            lambda p: jnp.zeros(p.shape, _state_dtype(p)), specs,
            is_leaf=lambda x: isinstance(x, PSpec))
        return state

    def abstract_decode_state(self, batch: int, max_len: int) -> DecodeState:
        specs = self.decode_state_specs(batch, max_len)
        return jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, _state_dtype(p)), specs,
            is_leaf=lambda x: isinstance(x, PSpec))

    # -- entry points ---------------------------------------------------------
    def train_loss(self, params: Params, batch: Batch
                   ) -> Tuple[jax.Array, Dict]:
        raise NotImplementedError

    def prefill(self, params: Params, batch: Batch
                ) -> Tuple[jax.Array, DecodeState]:
        raise NotImplementedError

    def decode_step(self, params: Params, state: DecodeState,
                    tokens: jax.Array) -> Tuple[jax.Array, DecodeState]:
        raise NotImplementedError

    def supports_paged_decode(self) -> bool:
        """Whether decode_step_paged is available for this config."""
        return False

    def decode_step_paged(self, params: Params, state: DecodeState,
                          tokens: jax.Array,
                          backend: str = None) -> Tuple[jax.Array,
                                                        DecodeState]:
        raise NotImplementedError(
            f"{type(self).__name__} has no paged decode path")

    def supports_chunked_prefill(self) -> bool:
        """Whether prefill_chunk is available for this config."""
        return False

    def prefill_chunk(self, params: Params, state: DecodeState,
                      tokens: jax.Array, offset: jax.Array,
                      backend: str = None) -> Dict:
        raise NotImplementedError(
            f"{type(self).__name__} has no chunked prefill path")

    def prefill_chunk_seg(self, params: Params, state: DecodeState,
                          tokens: jax.Array, chunk_positions: jax.Array,
                          backend: str = None) -> Dict:
        raise NotImplementedError(
            f"{type(self).__name__} has no segment prefill path")

    # -- dry-run inputs -------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every input of the entry point."""
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == KIND_TRAIN:
            out = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                   "labels": jax.ShapeDtypeStruct((b, s), i32)}
        elif shape.kind == KIND_PREFILL:
            out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        else:
            out = {"tokens": jax.ShapeDtypeStruct((b,), i32)}
        self._add_aux_specs(out, shape)
        return out

    def _add_aux_specs(self, out: Dict, shape: ShapeConfig) -> None:
        pass

    # -- helpers --------------------------------------------------------------
    def _embed(self, params: Params, tokens: jax.Array) -> jax.Array:
        return self.shd(params["embed"][tokens], "batch", "seq", None)

    def _logits(self, params: Params, x: jax.Array) -> jax.Array:
        if self.cfg.tie_embeddings:
            lg = jnp.einsum("...d,vd->...v", x, params["embed"])
        else:
            lg = jnp.einsum("...d,dv->...v", x, params["head"])
        axes = (("batch", "vocab") if lg.ndim == 2
                else ("batch", "seq", "vocab"))
        return self.shd(lg, *axes)

    def _loss_from_logits(self, logits, labels) -> Tuple[jax.Array, Dict]:
        loss = cross_entropy(logits, labels)
        return loss, {"loss": loss}


def _state_dtype(p: PSpec):
    return jnp.dtype(p.dtype) if p.dtype else jnp.bfloat16


def _int_spec(shape, axes) -> PSpec:
    return PSpec(tuple(shape), tuple(axes), init="zeros", dtype="int32")


# ===========================================================================
# Decoder-only (dense / MoE / MLA) + VLM
# ===========================================================================
class DecoderModel(Model):
    family = FAMILY_DECODER

    # -- parameter tree -------------------------------------------------------
    def _layer_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        if cfg.attention_variant == MLA:
            a = attn.mla_pspecs(cfg)
        else:
            a = attn.attn_pspecs(cfg)
        ffn = (moe_mod.moe_pspecs(cfg) if cfg.n_experts > 0
               else _dense_ffn_pspecs(cfg))
        return {"attn": a, "ffn": ffn,
                "ln1": _ln_spec(cfg.d_model), "ln2": _ln_spec(cfg.d_model)}

    def param_specs(self) -> Params:
        cfg = self.cfg
        out = {
            "embed": PSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
            "layers": stack_specs(self._layer_specs(), cfg.n_layers),
            "ln_f": _ln_spec(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            out["head"] = PSpec((cfg.d_model, cfg.vocab_size),
                                ("embed", "vocab"))
        if cfg.family == FAMILY_VLM:
            n_cross = len(cfg.cross_attn_layer_ids())
            cross = {"attn": attn.attn_pspecs(cfg, cross=True),
                     "ln": _ln_spec(cfg.d_model),
                     "gate": PSpec((1,), (None,), init="zeros")}
            out["cross"] = stack_specs(cross, n_cross)
            out["patch_proj"] = PSpec((cfg.d_model, cfg.d_model),
                                      ("embed", "embed_out"))
        return out

    # -- blocks -----------------------------------------------------------
    def _ffn(self, lp, h):
        cfg = self.cfg
        if cfg.n_experts > 0:
            out, aux = moe_mod.moe_ffn(lp["ffn"], h, cfg, self.shd)
            return out, aux
        return swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                      lp["ffn"]["w_down"], self.shd), 0.0

    def _self_block_full(self, lp, x, positions):
        """Training/prefill layer; returns (x, (k, v or latent), aux)."""
        cfg = self.cfg
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if cfg.attention_variant == MLA:
            o, latent = attn.mla_attention_prefill(lp["attn"], h, positions,
                                                   cfg, shd=self.shd)
            kv = (latent,)
        else:
            q, k, v = attn.project_qkv(lp["attn"], h, positions, cfg,
                                       shd=self.shd)
            o = attn.causal_attention(q, k, v, shd=self.shd)
            mask = attn.head_mask(cfg, o.dtype)
            if mask is not None:
                o = o * mask          # zero padded layout heads
            o = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
            kv = (k, v)
        x = self.shd(x + o, "batch", "seq_res", None)
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        f, aux = self._ffn(lp, h)
        return self.shd(x + f, "batch", "seq_res", None), kv, aux

    def _self_block_decode(self, lp, x, kv, lengths):
        """Decode layer; x [B,1,D]; kv = per-layer cache slices."""
        cfg = self.cfg
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        new_len = lengths + 1
        if cfg.attention_variant == MLA:
            (latent,) = kv
            o, latent = attn.mla_attention_decode(lp["attn"], h, latent,
                                                  new_len, cfg, self.shd,
                                                  aligned=self.aligned)
            new_kv = (latent,)
        elif self.kv_dtype == "int8":
            k_c, v_c, ks_c, vs_c = kv
            pos = lengths[:, None]
            q, k_new, v_new = attn.project_qkv(lp["attn"], h, pos, cfg,
                                               shd=NOSHARD)
            kq, ks = attn.quantize_kv(k_new)
            vq, vs = attn.quantize_kv(v_new)
            k_c = attn.cache_write(k_c, kq, lengths, aligned=self.aligned)
            v_c = attn.cache_write(v_c, vq, lengths, aligned=self.aligned)
            ks_c = attn.cache_write(ks_c, ks, lengths, aligned=self.aligned)
            vs_c = attn.cache_write(vs_c, vs, lengths, aligned=self.aligned)
            kd = attn.dequantize_kv(k_c, ks_c, h.dtype)
            vd = attn.dequantize_kv(v_c, vs_c, h.dtype)
            o = attn.decode_attention(q, kd, vd, new_len, shd=self.shd)
            mask = attn.head_mask(cfg, o.dtype)
            if mask is not None:
                o = o * mask
            o = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
            new_kv = (k_c, v_c, ks_c, vs_c)
            x = x + o
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            f, _ = self._ffn(lp, h)
            return x + f, new_kv
        else:
            k_c, v_c = kv
            pos = lengths[:, None]
            q, k_new, v_new = attn.project_qkv(lp["attn"], h, pos, cfg,
                                               shd=NOSHARD)
            k_c = attn.cache_write(k_c, k_new, lengths, aligned=self.aligned)
            v_c = attn.cache_write(v_c, v_new, lengths, aligned=self.aligned)
            o = attn.decode_attention(q, k_c, v_c, new_len, shd=self.shd)
            mask = attn.head_mask(cfg, o.dtype)
            if mask is not None:
                o = o * mask
            o = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
            new_kv = (k_c, v_c)
        x = x + o
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        f, _ = self._ffn(lp, h)
        return x + f, new_kv

    def _cross_block(self, cp, x, xk, xv):
        cfg = self.cfg
        h = rms_norm(x, cp["ln"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, cp["attn"]["wq"])
        o = attn.full_attention(q, xk, xv)
        o = jnp.einsum("bshk,hkd->bsd", o, cp["attn"]["wo"])
        return x + jnp.tanh(cp["gate"].astype(jnp.float32)).astype(x.dtype) * o

    def _cross_kv(self, cp, patches):
        k = jnp.einsum("bpd,dhk->bphk", patches, cp["attn"]["wk"])
        v = jnp.einsum("bpd,dhk->bphk", patches, cp["attn"]["wv"])
        return k, v

    # -- full-sequence forward ------------------------------------------------
    def _forward_full(self, params, tokens, patches=None, *,
                      collect_cache: bool = False, remat: bool = False):
        cfg = self.cfg
        b, s = tokens.shape
        x = self._embed(params, tokens)
        positions = jnp.arange(s)[None, :]
        aux_total = 0.0

        def layer_fn(x, lp):
            x, kv, aux = self._self_block_full(lp, x, positions)
            return x, (kv if collect_cache else None, aux)

        body = jax.checkpoint(layer_fn) if remat else layer_fn

        if cfg.family == FAMILY_VLM:
            patches_e = jnp.einsum("bpd,de->bpe", patches,
                                   params["patch_proj"])
            n_cross = len(cfg.cross_attn_layer_ids())
            per = cfg.n_layers // n_cross
            self_stack = jax.tree.map(
                lambda a: a.reshape((n_cross, per) + a.shape[1:]),
                params["layers"])

            def group_fn(x, gp):
                cp, sp = gp
                xk, xv = self._cross_kv(cp, patches_e)
                x = self._cross_block(cp, x, xk, xv)
                x, outs = jax.lax.scan(body, x, sp)
                return x, outs

            gbody = jax.checkpoint(group_fn) if remat else group_fn
            x, outs = jax.lax.scan(gbody, x, (params["cross"], self_stack))
            caches, auxes = outs
            if collect_cache:
                caches = jax.tree.map(
                    lambda a: a.reshape((-1,) + a.shape[2:]), caches)
        else:
            x, (caches, auxes) = jax.lax.scan(
                body, x, params["layers"],
                unroll=min(self.scan_unroll, cfg.n_layers))
        aux_total = jnp.mean(auxes) if cfg.n_experts > 0 else 0.0
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return x, caches, aux_total

    # -- entry points ------------------------------------------------------
    def train_loss(self, params, batch):
        x, _, aux = self._forward_full(
            params, batch["tokens"], batch.get("patches"), remat=True)
        logits = self._logits(params, x)
        loss, metrics = self._loss_from_logits(logits, batch["labels"])
        if self.cfg.n_experts > 0:
            loss = loss + self.cfg.router_aux_weight * aux
            metrics["aux_loss"] = aux
        metrics["loss"] = loss
        return loss, metrics

    def prefill(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x, caches, _ = self._forward_full(
            params, tokens, batch.get("patches"), collect_cache=True)
        logits = self._logits(params, x[:, -1])
        state: DecodeState = {"lengths": jnp.full((b,), s, jnp.int32)}
        if cfg.attention_variant == MLA:
            state["latent"] = self.shd(caches[0], None, "batch", "kv_seq", None)
        else:
            state["k"] = self.shd(caches[0], None, "batch", "kv_seq", None, None)
            state["v"] = self.shd(caches[1], None, "batch", "kv_seq", None, None)
        if cfg.family == FAMILY_VLM:
            patches_e = jnp.einsum("bpd,de->bpe", batch["patches"],
                                   params["patch_proj"])
            xks, xvs = jax.vmap(self._cross_kv, in_axes=(0, None))(
                params["cross"], patches_e)
            state["xk"], state["xv"] = xks, xvs
        return logits, state

    def prefill_suffix(self, params, batch, prefix_kv, q_offset: int):
        """Prefix-cache-aware prefill: attend suffix queries over
        [cached prefix KV ; suffix KV].  prefix_kv = (k, v) [L,B,P,..]
        (or (latent,) for MLA).  This is what converts radix-tree prefix
        hits into skipped prefill compute (paper §III-F)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = self._embed(params, tokens)
        positions = q_offset + jnp.arange(s)[None, :]

        if cfg.attention_variant == MLA:
            (lat_pre,) = prefix_kv

            def layer_fn(x, inp):
                lp, lpre = inp
                h = rms_norm(x, lp["ln1"], cfg.norm_eps)
                q_nope, q_rope, lat_new = attn.mla_project(
                    lp["attn"], h, positions, cfg, self.shd)
                lat_full = jnp.concatenate([lpre, lat_new], axis=1)
                dl, dr = cfg.d_latent, cfg.d_rope
                c_kv, k_rope = lat_full[..., :dl], lat_full[..., dl:]
                k = jnp.einsum("bsl,lhk->bshk", c_kv, lp["attn"]["w_uk"])
                v = jnp.einsum("bsl,lhk->bshk", c_kv, lp["attn"]["w_uv"])
                q = jnp.concatenate([q_nope, q_rope], axis=-1)
                k = jnp.concatenate(
                    [k, jnp.broadcast_to(k_rope[:, :, None, :],
                                         k.shape[:3] + (dr,))], axis=-1)
                o = attn.causal_attention(q, k, v, q_offset=q_offset,
                                          shd=self.shd)
                o = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
                x = x + o
                h = rms_norm(x, lp["ln2"], cfg.norm_eps)
                f, _ = self._ffn(lp, h)
                return x + f, lat_new

            x, lat_suffix = jax.lax.scan(layer_fn, x,
                                         (params["layers"], lat_pre))
            x = rms_norm(x, params["ln_f"], cfg.norm_eps)
            return self._logits(params, x[:, -1]), (lat_suffix,)

        k_pre, v_pre = prefix_kv

        def layer_fn(x, inp):
            lp, kp, vp = inp
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = attn.project_qkv(lp["attn"], h, positions, cfg,
                                       shd=self.shd)
            k_full = jnp.concatenate([kp, k], axis=1)
            v_full = jnp.concatenate([vp, v], axis=1)
            o = attn.causal_attention(q, k_full, v_full,
                                      q_offset=q_offset, shd=self.shd)
            mask = attn.head_mask(cfg, o.dtype)
            if mask is not None:
                o = o * mask
            o = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
            x = x + o
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            f, _ = self._ffn(lp, h)
            return x + f, (k, v)

        x, (ks, vs) = jax.lax.scan(layer_fn, x,
                                   (params["layers"], k_pre, v_pre))
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return self._logits(params, x[:, -1]), (ks, vs)

    def decode_step(self, params, state, tokens):
        cfg = self.cfg
        b = tokens.shape[0]
        x = self.shd.embed_lookup(params["embed"], tokens)[:, None, :]
        lengths = state["lengths"]

        if cfg.attention_variant == MLA:
            def layer_fn(x, inp):
                lp, latent = inp
                x, (latent,) = self._self_block_decode(lp, x, (latent,),
                                                       lengths)
                return x, latent
            x, latents = jax.lax.scan(layer_fn, x,
                                      (params["layers"], state["latent"]))
            new_state = {**state, "latent": latents,
                         "lengths": lengths + 1}
        elif cfg.family == FAMILY_VLM:
            n_cross = len(cfg.cross_attn_layer_ids())
            per = cfg.n_layers // n_cross
            self_stack = jax.tree.map(
                lambda a: a.reshape((n_cross, per) + a.shape[1:]),
                params["layers"])
            kv_stack = jax.tree.map(
                lambda a: a.reshape((n_cross, per) + a.shape[1:]),
                (state["k"], state["v"]))

            def layer_fn(x, inp):
                lp, kv = inp
                x, kv = self._self_block_decode(lp, x, kv, lengths)
                return x, kv

            def group_fn(x, gp):
                cp, sp, kvs, xk, xv = gp
                x = self._cross_block(cp, x, xk, xv)
                x, kvs = jax.lax.scan(layer_fn, x, (sp, kvs))
                return x, kvs

            x, kvs = jax.lax.scan(
                group_fn, x, (params["cross"], self_stack, kv_stack,
                              state["xk"], state["xv"]))
            k_new, v_new = jax.tree.map(
                lambda a: a.reshape((-1,) + a.shape[2:]), kvs)
            new_state = {**state, "k": k_new, "v": v_new,
                         "lengths": lengths + 1}
        elif self.kv_dtype == "int8":
            def layer_fn(x, inp):
                lp, k_c, v_c, ks_c, vs_c = inp
                x, kv = self._self_block_decode(
                    lp, x, (k_c, v_c, ks_c, vs_c), lengths)
                return x, kv
            x, (ks, vs, kss, vss) = jax.lax.scan(
                layer_fn, x, (params["layers"], state["k"], state["v"],
                              state["k_scale"], state["v_scale"]))
            new_state = {**state, "k": ks, "v": vs, "k_scale": kss,
                         "v_scale": vss, "lengths": lengths + 1}
        else:
            def layer_fn(x, inp):
                lp, k_c, v_c = inp
                x, (k_c, v_c) = self._self_block_decode(lp, x, (k_c, v_c),
                                                        lengths)
                return x, (k_c, v_c)
            x, (ks, vs) = jax.lax.scan(
                layer_fn, x, (params["layers"], state["k"], state["v"]))
            new_state = {**state, "k": ks, "v": vs, "lengths": lengths + 1}

        x = rms_norm(x[:, 0], params["ln_f"], cfg.norm_eps)
        return self._logits(params, x), new_state

    # -- paged decode (block-table KV pool; serving fast path) ---------------
    def supports_paged_decode(self) -> bool:
        """Paged decode covers dense/MoE decoder-only configs (incl. MLA).
        VLM (cross-attn state) and int8 caches fall back to the dense
        slot layout."""
        cfg = self.cfg
        return (cfg.family in (FAMILY_DECODER, FAMILY_MOE)
                and self.kv_dtype != "int8")

    def decode_step_paged(self, params, state, tokens, backend=None):
        """One batched decode step over a paged KV pool.

        state: {"k_pages"/"v_pages" [L, N, page, Hkv, hd]} (or MLA
        {"latent_pages" [L, N, page, dl+dr]}) + "block_tables" [B, P]
        int32 + "lengths" [B] int32.  The new token's KV is scattered
        into each request's current page; attention reads through the
        block table via the paged attention ops (table entry 0 is the
        caller's scratch page for inactive batch rows).  ``backend``
        selects the kernel backend (``kernels/backend.py``: compiled
        Pallas on TPU / jitted XLA gathers elsewhere by default).
        """
        from repro.kernels import ops

        cfg = self.cfg
        b = tokens.shape[0]
        x = self.shd.embed_lookup(params["embed"], tokens)[:, None, :]
        lengths = state["lengths"]
        bt = state["block_tables"]
        pool_key = "latent_pages" if cfg.attention_variant == MLA else "k_pages"
        page = state[pool_key].shape[2]
        page_ids = bt[jnp.arange(b), lengths // page]
        offs = lengths % page
        new_len = lengths + 1

        if cfg.attention_variant == MLA:
            dl, dr = cfg.d_latent, cfg.d_rope
            scale = 1.0 / math.sqrt(cfg.hd + dr)

            def layer_fn(x, inp):
                lp, latp = inp
                h = rms_norm(x, lp["ln1"], cfg.norm_eps)
                q_nope, q_rope, new_latent = attn.mla_project(
                    lp["attn"], h, lengths[:, None], cfg)
                latp = latp.at[page_ids, offs].set(
                    new_latent[:, 0].astype(latp.dtype))
                # absorb W_uk into the query; the kernel attends in
                # latent space and returns ctx [B, Hq, dl]
                q_lat = jnp.einsum("bshk,lhk->bshl", q_nope,
                                   lp["attn"]["w_uk"])
                ctx = ops.mla_decode(q_lat[:, 0], q_rope[:, 0], latp, bt,
                                     new_len, d_latent=dl, scale=scale,
                                     backend=backend)
                out = jnp.einsum("bhl,lhk->bhk", ctx, lp["attn"]["w_uv"])
                o = jnp.einsum("bhk,hkd->bd", out, lp["attn"]["wo"])[:, None]
                x = x + o
                h = rms_norm(x, lp["ln2"], cfg.norm_eps)
                f, _ = self._ffn(lp, h)
                return x + f, latp

            x, lats = jax.lax.scan(layer_fn, x,
                                   (params["layers"], state["latent_pages"]))
            new_state = {**state, "latent_pages": lats, "lengths": new_len}
        else:
            def layer_fn(x, inp):
                lp, kp, vp = inp
                h = rms_norm(x, lp["ln1"], cfg.norm_eps)
                q, k_new, v_new = attn.project_qkv(lp["attn"], h,
                                                   lengths[:, None], cfg,
                                                   shd=NOSHARD)
                kp = kp.at[page_ids, offs].set(k_new[:, 0].astype(kp.dtype))
                vp = vp.at[page_ids, offs].set(v_new[:, 0].astype(vp.dtype))
                o = ops.paged_decode(q[:, 0], kp, vp, bt, new_len,
                                     backend=backend)
                mask = attn.head_mask(cfg, o.dtype)
                if mask is not None:
                    o = o * mask              # zero padded layout heads
                o = jnp.einsum("bhk,hkd->bd", o, lp["attn"]["wo"])[:, None]
                x = x + o
                h = rms_norm(x, lp["ln2"], cfg.norm_eps)
                f, _ = self._ffn(lp, h)
                return x + f, (kp, vp)

            x, (kps, vps) = jax.lax.scan(
                layer_fn, x,
                (params["layers"], state["k_pages"], state["v_pages"]))
            new_state = {**state, "k_pages": kps, "v_pages": vps,
                         "lengths": new_len}

        x = rms_norm(x[:, 0], params["ln_f"], cfg.norm_eps)
        return self._logits(params, x), new_state

    # -- chunked prefill (token-budget mixed batches; serving fast path) -----
    def supports_chunked_prefill(self) -> bool:
        return self.supports_paged_decode()

    def prefill_chunk(self, params, state, tokens, offset, backend=None):
        """Prefill a fixed-size prompt chunk against the request's
        already-resident paged KV.

        tokens [1, C] int32 (zero-padded past the valid suffix); offset
        [1] int32 — tokens already written to this slot's pages; state:
        {"k_pages"/"v_pages"} (or MLA {"latent_pages"}) + "block_table"
        [1, P] int32.  Attention is causal within the chunk and full
        over pool tokens < offset (kernels/paged_prefill.py).  Returns
        the chunk's per-layer KV ({"k"/"v"} [L,1,C,Hkv,hd] or
        {"latent"} [L,1,C,dl+dr]) for the caller to scatter into the
        pool via ``PagedKVCache.write_chunk`` — logits are never needed:
        the first decode step consumes the final prompt token.
        """
        from repro.kernels import ops

        cfg = self.cfg
        c = tokens.shape[1]
        x = self._embed(params, tokens)
        positions = offset[:, None] + jnp.arange(c)[None, :]
        bt = state["block_table"]

        if cfg.attention_variant == MLA:
            dl, dr = cfg.d_latent, cfg.d_rope
            scale = 1.0 / math.sqrt(cfg.hd + dr)

            def layer_fn(x, inp):
                lp, latp = inp
                h = rms_norm(x, lp["ln1"], cfg.norm_eps)
                q_nope, q_rope, latent = attn.mla_project(
                    lp["attn"], h, positions, cfg)
                q_lat = jnp.einsum("bshk,lhk->bshl", q_nope,
                                   lp["attn"]["w_uk"])
                ctx = ops.mla_prefill(q_lat, q_rope, latent, latp, bt,
                                      offset, d_latent=dl, scale=scale,
                                      backend=backend)
                out = jnp.einsum("bshl,lhk->bshk", ctx, lp["attn"]["w_uv"])
                o = jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"])
                x = x + o
                h = rms_norm(x, lp["ln2"], cfg.norm_eps)
                f, _ = self._ffn(lp, h)
                return x + f, latent

            _, lats = jax.lax.scan(layer_fn, x,
                                   (params["layers"],
                                    state["latent_pages"]))
            return {"latent": lats}

        def layer_fn(x, inp):
            lp, kp, vp = inp
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = attn.project_qkv(lp["attn"], h, positions, cfg,
                                       shd=NOSHARD)
            o = ops.paged_prefill(q, k, v, kp, vp, bt, offset,
                                  backend=backend)
            mask = attn.head_mask(cfg, o.dtype)
            if mask is not None:
                o = o * mask              # zero padded layout heads
            o = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
            x = x + o
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            f, _ = self._ffn(lp, h)
            return x + f, (k, v)

        _, (ks, vs) = jax.lax.scan(
            layer_fn, x,
            (params["layers"], state["k_pages"], state["v_pages"]))
        return {"k": ks, "v": vs}

    def prefill_chunk_seg(self, params, state, tokens, chunk_positions,
                          backend=None):
        """Segment-prefill a chunk of prompt *gap* tokens at arbitrary
        ascending absolute positions (``chunk_positions`` [1, C] int32;
        negative = padding).  Same contract as ``prefill_chunk`` except
        the chunk may span multiple gaps with resumed pool-resident
        segments between them: RoPE is applied at each token's true
        position and attention runs through the segment kernels
        (kernels/ops.py ``paged_prefill_seg``/``mla_prefill_seg``).
        Every position below a chunk token's that is not in the chunk
        must already be resident in the slot's pages."""
        from repro.kernels import ops

        cfg = self.cfg
        positions = jnp.maximum(chunk_positions, 0)   # RoPE-safe padding
        bt = state["block_table"]

        x = self._embed(params, tokens)
        if cfg.attention_variant == MLA:
            dl, dr = cfg.d_latent, cfg.d_rope
            scale = 1.0 / math.sqrt(cfg.hd + dr)

            def layer_fn(x, inp):
                lp, latp = inp
                h = rms_norm(x, lp["ln1"], cfg.norm_eps)
                q_nope, q_rope, latent = attn.mla_project(
                    lp["attn"], h, positions, cfg)
                q_lat = jnp.einsum("bshk,lhk->bshl", q_nope,
                                   lp["attn"]["w_uk"])
                ctx = ops.mla_prefill_seg(q_lat, q_rope, latent, latp, bt,
                                          chunk_positions, d_latent=dl,
                                          scale=scale, backend=backend)
                out = jnp.einsum("bshl,lhk->bshk", ctx, lp["attn"]["w_uv"])
                o = jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"])
                x = x + o
                h = rms_norm(x, lp["ln2"], cfg.norm_eps)
                f, _ = self._ffn(lp, h)
                return x + f, latent

            _, lats = jax.lax.scan(layer_fn, x,
                                   (params["layers"],
                                    state["latent_pages"]))
            return {"latent": lats}

        def layer_fn(x, inp):
            lp, kp, vp = inp
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = attn.project_qkv(lp["attn"], h, positions, cfg,
                                       shd=NOSHARD)
            o = ops.paged_prefill_seg(q, k, v, kp, vp, bt,
                                      chunk_positions, backend=backend)
            mask = attn.head_mask(cfg, o.dtype)
            if mask is not None:
                o = o * mask              # zero padded layout heads
            o = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
            x = x + o
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            f, _ = self._ffn(lp, h)
            return x + f, (k, v)

        _, (ks, vs) = jax.lax.scan(
            layer_fn, x,
            (params["layers"], state["k_pages"], state["v_pages"]))
        return {"k": ks, "v": vs}

    # -- decode state ----------------------------------------------------------
    def decode_state_specs(self, batch, max_len):
        cfg = self.cfg
        L, hkv, hd = cfg.n_layers, max(cfg.n_kv_heads, 1), cfg.hd
        out = {"lengths": _int_spec((batch,), ("batch",))}
        if cfg.attention_variant == MLA:
            out["latent"] = PSpec((L, batch, max_len,
                                   cfg.d_latent + cfg.d_rope),
                                  ("layers", "batch", "kv_seq", None),
                                  init="zeros")
        else:
            dt = "int8" if self.kv_dtype == "int8" else None
            kvspec = PSpec((L, batch, max_len, hkv, hd),
                           ("layers", "batch", "kv_seq", None, None),
                           init="zeros", dtype=dt)
            out["k"] = kvspec
            out["v"] = kvspec
            if self.kv_dtype == "int8":
                sspec = PSpec((L, batch, max_len, hkv, 1),
                              ("layers", "batch", "kv_seq", None, None),
                              init="zeros")
                out["k_scale"] = sspec
                out["v_scale"] = sspec
        if cfg.family == FAMILY_VLM:
            n_cross = len(cfg.cross_attn_layer_ids())
            xspec = PSpec((n_cross, batch, cfg.n_patches, hkv, hd),
                          ("layers", "batch", None, "kv_heads", None),
                          init="zeros")
            out["xk"] = xspec
            out["xv"] = xspec
        return out

    def _add_aux_specs(self, out, shape):
        cfg = self.cfg
        if cfg.family == FAMILY_VLM and shape.kind != KIND_DECODE:
            out["patches"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.n_patches, cfg.d_model),
                jnp.bfloat16)


class VLMModel(DecoderModel):
    family = FAMILY_VLM


# ===========================================================================
# Hybrid: Mamba2 backbone + shared attention block (zamba2)
# ===========================================================================
class HybridModel(Model):
    family = FAMILY_HYBRID

    def param_specs(self):
        cfg = self.cfg
        layer = {"mamba": ssm_mod.mamba_pspecs(cfg),
                 "ln": _ln_spec(cfg.d_model)}
        shared = {"attn": attn.attn_pspecs(cfg),
                  "ffn": _dense_ffn_pspecs(cfg),
                  "ln1": _ln_spec(cfg.d_model),
                  "ln2": _ln_spec(cfg.d_model)}
        return {
            "embed": PSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
            "layers": stack_specs(layer, cfg.n_layers),
            "shared": shared,
            "ln_f": _ln_spec(cfg.d_model),
        }

    def _segments(self):
        cfg = self.cfg
        ids = cfg.attn_layer_ids()
        bounds, prev = [], 0
        for i in ids:
            bounds.append((prev, i + 1, True))
            prev = i + 1
        if prev < cfg.n_layers:
            bounds.append((prev, cfg.n_layers, False))
        return bounds

    def _mamba_layer_full(self, lp, x):
        h = rms_norm(x, lp["ln"], self.cfg.norm_eps)
        return x + ssm_mod.mamba_block(lp["mamba"], h, self.cfg,
                                       shd=self.shd)

    def _shared_attn_full(self, params, x, positions, *, cache=None,
                          lengths=None):
        cfg, sp = self.cfg, params["shared"]
        h = rms_norm(x, sp["ln1"], cfg.norm_eps)
        if cache is None:
            q, k, v = attn.project_qkv(sp["attn"], h, positions, cfg,
                                       shd=self.shd)
            o = attn.causal_attention(q, k, v, shd=self.shd)
            new_cache = (k, v)
        else:
            k_c, v_c = cache
            q, k_new, v_new = attn.project_qkv(sp["attn"], h,
                                               lengths[:, None], cfg)
            k_c = attn.cache_write(k_c, k_new, lengths, aligned=self.aligned)
            v_c = attn.cache_write(v_c, v_new, lengths, aligned=self.aligned)
            o = attn.decode_attention(q, k_c, v_c, lengths + 1, shd=self.shd)
            new_cache = (k_c, v_c)
        o = jnp.einsum("bshk,hkd->bsd", o, sp["attn"]["wo"])
        x = x + o
        h = rms_norm(x, sp["ln2"], cfg.norm_eps)
        x = x + swiglu(h, sp["ffn"]["w_gate"], sp["ffn"]["w_up"],
                       sp["ffn"]["w_down"], self.shd)
        return x, new_cache

    def _forward_full(self, params, tokens, *, collect_cache=False,
                      remat=False):
        cfg = self.cfg
        b, s = tokens.shape
        x = self._embed(params, tokens)
        positions = jnp.arange(s)[None, :]
        body = (jax.checkpoint(lambda x, lp: (self._mamba_layer_full(lp, x),
                                              None))
                if remat else lambda x, lp: (self._mamba_layer_full(lp, x),
                                             None))
        caches = []
        for (lo, hi, has_attn) in self._segments():
            seg = jax.tree.map(lambda a: a[lo:hi], params["layers"])
            x, _ = jax.lax.scan(body, x, seg)
            if has_attn:
                x, kv = self._shared_attn_full(params, x, positions)
                if collect_cache:
                    caches.append(kv)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        if collect_cache:
            ks = jnp.stack([c[0] for c in caches])
            vs = jnp.stack([c[1] for c in caches])
            return x, (ks, vs)
        return x, None

    def train_loss(self, params, batch):
        x, _ = self._forward_full(params, batch["tokens"], remat=True)
        logits = self._logits(params, x)
        return self._loss_from_logits(logits, batch["labels"])

    def prefill(self, params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        # run full forward for caches; recompute mamba states via chunked
        # scan final states
        cfg = self.cfg
        x = self._embed(params, tokens)
        positions = jnp.arange(s)[None, :]
        ssm_states, conv_states, attn_caches = [], [], []

        def layer_with_state(x, lp):
            h = rms_norm(x, lp["ln"], cfg.norm_eps)
            y, st = _mamba_block_with_state(lp["mamba"], h, cfg, self.shd)
            return x + y, st

        for (lo, hi, has_attn) in self._segments():
            seg = jax.tree.map(lambda a: a[lo:hi], params["layers"])
            x, sts = jax.lax.scan(layer_with_state, x, seg)
            ssm_states.append(sts)
            if has_attn:
                x, kv = self._shared_attn_full(params, x, positions)
                attn_caches.append(kv)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = self._logits(params, x[:, -1])
        sts = jax.tree.map(lambda *xs: jnp.concatenate(xs), *ssm_states)
        state = {"mamba": sts,
                 "k": self.shd(jnp.stack([c[0] for c in attn_caches]),
                               None, "batch", "kv_seq", None, None),
                 "v": self.shd(jnp.stack([c[1] for c in attn_caches]),
                               None, "batch", "kv_seq", None, None),
                 "lengths": jnp.full((b,), s, jnp.int32)}
        return logits, state

    def decode_step(self, params, state, tokens):
        cfg = self.cfg
        x = self.shd.embed_lookup(params["embed"], tokens)    # [B,D]
        lengths = state["lengths"]

        def layer_fn(x, inp):
            lp, st = inp
            h = rms_norm(x[:, None], lp["ln"], cfg.norm_eps)[:, 0]
            y, st = ssm_mod.mamba_decode_step(lp["mamba"], h, st, cfg)
            return x + y, st

        new_m, new_k, new_v = [], [], []
        ai = 0
        for (lo, hi, has_attn) in self._segments():
            seg = jax.tree.map(lambda a: a[lo:hi], params["layers"])
            mst = jax.tree.map(lambda a: a[lo:hi], state["mamba"])
            x, mst = jax.lax.scan(layer_fn, x, (seg, mst))
            new_m.append(mst)
            if has_attn:
                kv = (state["k"][ai], state["v"][ai])
                x2, kv = self._shared_attn_full(
                    params, x[:, None], None, cache=kv, lengths=lengths)
                x = x2[:, 0]
                new_k.append(kv[0])
                new_v.append(kv[1])
                ai += 1
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = self._logits(params, x)
        new_state = {"mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs),
                                           *new_m),
                     "k": jnp.stack(new_k), "v": jnp.stack(new_v),
                     "lengths": lengths + 1}
        return logits, new_state

    def decode_state_specs(self, batch, max_len):
        cfg = self.cfg
        n_apps = len(cfg.attn_layer_ids())
        m = stack_specs(ssm_mod.mamba_state_pspecs(cfg, batch), cfg.n_layers)
        kv = PSpec((n_apps, batch, max_len, cfg.n_kv_heads, cfg.hd),
                   ("layers", "batch", "kv_seq", None, None), init="zeros")
        return {"mamba": m, "k": kv, "v": kv,
                "lengths": _int_spec((batch,), ("batch",))}


def _mamba_block_with_state(p, x, cfg, shd):
    """mamba_block variant that also returns the final SSM/conv states
    (for prefill -> decode handoff)."""
    bsz, s, d = x.shape
    h, hd = cfg.n_ssm_heads, cfg.ssm_head_dim
    z = jnp.einsum("bsd,di->bsi", x, p["w_z"])
    x_pre = jnp.einsum("bsd,di->bsi", x, p["w_x"])
    b_pre = jnp.einsum("bsd,dn->bsn", x, p["w_B"])
    c_pre = jnp.einsum("bsd,dn->bsn", x, p["w_C"])
    xs = ssm_mod._causal_conv(x_pre, p["conv_x"])
    xs = shd(jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype),
             "batch", "seq", "inner")
    b_in = jax.nn.silu(ssm_mod._causal_conv(b_pre, p["conv_B"])
                       .astype(jnp.float32))
    c_in = jax.nn.silu(ssm_mod._causal_conv(c_pre, p["conv_C"])
                       .astype(jnp.float32))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(bsz, s, h, hd)
    y, final = ssm_mod.ssd_chunked(xh, dt, a, b_in, c_in)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(bsz, s, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    k = cfg.ssm_conv
    state = {"ssm": final,
             "conv_x": x_pre[:, -(k - 1):, :],
             "conv_B": b_pre[:, -(k - 1):, :],
             "conv_C": c_pre[:, -(k - 1):, :]}
    return out, state


# ===========================================================================
# RWKV6
# ===========================================================================
class RWKVModel(Model):
    family = FAMILY_RWKV

    def param_specs(self):
        cfg = self.cfg
        layer = dict(rwkv_mod.rwkv_pspecs(cfg))
        layer.update(ln1_g=_ln_spec(cfg.d_model),
                     ln1_b=PSpec((cfg.d_model,), ("embed",), init="zeros"),
                     ln2_g=_ln_spec(cfg.d_model),
                     ln2_b=PSpec((cfg.d_model,), ("embed",), init="zeros"))
        return {
            "embed": PSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
            "ln0_g": _ln_spec(cfg.d_model),
            "ln0_b": PSpec((cfg.d_model,), ("embed",), init="zeros"),
            "layers": stack_specs(layer, cfg.n_layers),
            "ln_f": _ln_spec(cfg.d_model),
            "head": PSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab")),
        }

    def _layer_full(self, lp, x):
        cfg = self.cfg
        h = layer_norm(x, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
        x = x + rwkv_mod.time_mix(lp, h, cfg, shd=self.shd)
        h = layer_norm(x, lp["ln2_g"], lp["ln2_b"], cfg.norm_eps)
        x = x + rwkv_mod.channel_mix(lp, h, shd=self.shd)
        return x

    def _forward_full(self, params, tokens, remat=False):
        cfg = self.cfg
        x = self._embed(params, tokens)
        x = layer_norm(x, params["ln0_g"], params["ln0_b"], cfg.norm_eps)
        body = (jax.checkpoint(lambda x, lp: (self._layer_full(lp, x), None))
                if remat else lambda x, lp: (self._layer_full(lp, x), None))
        x, _ = jax.lax.scan(body, x, params["layers"])
        return layer_norm(x, params["ln_f"],
                          jnp.zeros_like(params["ln_f"]), cfg.norm_eps)

    def train_loss(self, params, batch):
        x = self._forward_full(params, batch["tokens"], remat=True)
        logits = self._logits(params, x)
        return self._loss_from_logits(logits, batch["labels"])

    def prefill(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = self._embed(params, tokens)
        x = layer_norm(x, params["ln0_g"], params["ln0_b"], cfg.norm_eps)

        def layer_with_state(x, lp):
            h = layer_norm(x, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
            tm_out, wkv = _time_mix_with_state(lp, h, cfg, self.shd)
            x = x + tm_out
            h2 = layer_norm(x, lp["ln2_g"], lp["ln2_b"], cfg.norm_eps)
            x = x + rwkv_mod.channel_mix(lp, h2, shd=self.shd)
            return x, {"wkv": wkv, "tm_x": h[:, -1], "cm_x": h2[:, -1]}

        x, states = jax.lax.scan(layer_with_state, x, params["layers"])
        x = layer_norm(x, params["ln_f"], jnp.zeros_like(params["ln_f"]),
                       cfg.norm_eps)
        logits = self._logits(params, x[:, -1])
        states["lengths"] = jnp.full((b,), s, jnp.int32)
        return logits, states

    def decode_step(self, params, state, tokens):
        cfg = self.cfg
        x = self.shd.embed_lookup(params["embed"], tokens)
        x = layer_norm(x[:, None], params["ln0_g"], params["ln0_b"],
                       cfg.norm_eps)[:, 0]

        def layer_fn(x, inp):
            lp, st = inp
            h = layer_norm(x[:, None], lp["ln1_g"], lp["ln1_b"],
                           cfg.norm_eps)[:, 0]
            tm_out, st = rwkv_mod.rwkv_decode_step(lp, h, None, st, cfg)
            x = x + tm_out
            h2 = layer_norm(x[:, None], lp["ln2_g"], lp["ln2_b"],
                            cfg.norm_eps)[:, 0]
            cm_out, st = rwkv_mod.channel_mix_step(lp, h2, st)
            return x + cm_out, st

        lstate = {k: state[k] for k in ("wkv", "tm_x", "cm_x")}
        x, new_lstate = jax.lax.scan(layer_fn, x, (params["layers"], lstate))
        x = layer_norm(x[:, None], params["ln_f"],
                       jnp.zeros_like(params["ln_f"]), cfg.norm_eps)[:, 0]
        new_state = dict(new_lstate)
        new_state["lengths"] = state["lengths"] + 1
        return self._logits(params, x), new_state

    def decode_state_specs(self, batch, max_len):
        cfg = self.cfg
        st = stack_specs(rwkv_mod.rwkv_state_pspecs(cfg, batch),
                         cfg.n_layers)
        st["lengths"] = _int_spec((batch,), ("batch",))
        return st


def _time_mix_with_state(p, x, cfg, shd):
    bsz, s, d = x.shape
    xprev = rwkv_mod._shift(x)
    xr = rwkv_mod._mix(x, xprev, p["mu_r"])
    xk = rwkv_mod._mix(x, xprev, p["mu_k"])
    xv = rwkv_mod._mix(x, xprev, p["mu_v"])
    xw = rwkv_mod._mix(x, xprev, p["mu_w"])
    xg = rwkv_mod._mix(x, xprev, p["mu_g"])
    r = jnp.einsum("bsd,dhk->bshk", xr, p["w_r"])
    k = jnp.einsum("bsd,dhk->bshk", xk, p["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", xv, p["w_v"])
    g = jax.nn.silu(jnp.einsum("bsd,dhk->bshk", xg, p["w_g"])
                    .astype(jnp.float32))
    logw = rwkv_mod._log_decay(p, xw)
    y, final = rwkv_mod.wkv_chunked(r, k, v, logw, p["bonus_u"])
    y = rwkv_mod._group_norm(y, p["ln_x"], cfg.norm_eps) * g
    out = jnp.einsum("bshk,hkd->bsd", y.astype(x.dtype), p["w_o"])
    return out, final.astype(jnp.bfloat16)


# ===========================================================================
# Whisper-style encoder-decoder
# ===========================================================================
class EncDecModel(Model):
    family = FAMILY_ENCDEC

    def param_specs(self):
        cfg = self.cfg
        enc_layer = {"attn": attn.attn_pspecs(cfg),
                     "ffn": _dense_ffn_pspecs(cfg),
                     "ln1": _ln_spec(cfg.d_model), "ln2": _ln_spec(cfg.d_model)}
        dec_layer = {"attn": attn.attn_pspecs(cfg),
                     "xattn": attn.attn_pspecs(cfg, cross=True),
                     "ffn": _dense_ffn_pspecs(cfg),
                     "ln1": _ln_spec(cfg.d_model), "ln2": _ln_spec(cfg.d_model),
                     "ln3": _ln_spec(cfg.d_model)}
        return {
            "embed": PSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
            "enc_layers": stack_specs(enc_layer, cfg.n_enc_layers),
            "dec_layers": stack_specs(dec_layer, cfg.n_layers),
            "ln_enc": _ln_spec(cfg.d_model),
            "ln_dec": _ln_spec(cfg.d_model),
        }

    def encode(self, params, frames):
        """frames [B, enc_len, D] — precomputed (conv frontend stub)."""
        cfg = self.cfg
        b, s, d = frames.shape
        pos = _sinusoid_at(jnp.arange(s), d).astype(frames.dtype)
        x = frames + pos[None]

        def layer_fn(x, lp):
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = attn.project_qkv(lp["attn"], h,
                                       jnp.arange(s)[None], cfg,
                                       rope=False, shd=self.shd)
            o = attn.full_attention(q, k, v)
            x = x + jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            x = x + swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                           lp["ffn"]["w_down"], self.shd)
            return x, None

        x, _ = jax.lax.scan(layer_fn, x, params["enc_layers"])
        return rms_norm(x, params["ln_enc"], cfg.norm_eps)

    def _dec_layer_full(self, lp, x, enc_out, positions):
        cfg = self.cfg
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = attn.project_qkv(lp["attn"], h, positions, cfg,
                                   rope=False, shd=self.shd)
        o = attn.causal_attention(q, k, v, shd=self.shd)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["xattn"]["wq"])
        xk = jnp.einsum("bpd,dhk->bphk", enc_out, lp["xattn"]["wk"])
        xv = jnp.einsum("bpd,dhk->bphk", enc_out, lp["xattn"]["wv"])
        o = attn.full_attention(q, xk, xv)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp["xattn"]["wo"])
        h = rms_norm(x, lp["ln3"], cfg.norm_eps)
        x = x + swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                       lp["ffn"]["w_down"], self.shd)
        return x, (k, v, xk, xv)

    def _embed_dec(self, params, tokens, positions):
        if tokens.shape[-1] == 1:
            x = self.shd.embed_lookup(params["embed"],
                                      tokens[:, 0])[:, None, :]
        else:
            x = params["embed"][tokens]
        return x + _sinusoid_at(positions, self.cfg.d_model).astype(x.dtype)

    def train_loss(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.arange(s)[None]
        x = self._embed_dec(params, tokens, positions)

        def body(x, lp):
            x, _ = self._dec_layer_full(lp, x, enc_out, positions)
            return x, None

        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec_layers"])
        x = rms_norm(x, params["ln_dec"], cfg.norm_eps)
        logits = self._logits(params, x)
        return self._loss_from_logits(logits, batch["labels"])

    def prefill(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.arange(s)[None]
        x = self._embed_dec(params, tokens, positions)

        def body(x, lp):
            x, caches = self._dec_layer_full(lp, x, enc_out, positions)
            return x, caches

        x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["dec_layers"])
        x = rms_norm(x, params["ln_dec"], cfg.norm_eps)
        logits = self._logits(params, x[:, -1])
        state = {"k": self.shd(ks, None, "batch", "kv_seq", None, None),
                 "v": self.shd(vs, None, "batch", "kv_seq", None, None),
                 "xk": xks, "xv": xvs,
                 "lengths": jnp.full((b,), s, jnp.int32)}
        return logits, state

    def decode_step(self, params, state, tokens):
        cfg = self.cfg
        lengths = state["lengths"]
        x = self._embed_dec(params, tokens[:, None], lengths[:, None])

        def layer_fn(x, inp):
            lp, k_c, v_c, xk, xv = inp
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k_new, v_new = attn.project_qkv(lp["attn"], h,
                                               lengths[:, None], cfg,
                                               rope=False)
            k_c = attn.cache_write(k_c, k_new, lengths, aligned=self.aligned)
            v_c = attn.cache_write(v_c, v_new, lengths, aligned=self.aligned)
            o = attn.decode_attention(q, k_c, v_c, lengths + 1,
                                      shd=self.shd)
            x = x + jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", h, lp["xattn"]["wq"])
            o = attn.full_attention(q, xk, xv)
            x = x + jnp.einsum("bshk,hkd->bsd", o, lp["xattn"]["wo"])
            h = rms_norm(x, lp["ln3"], cfg.norm_eps)
            x = x + swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                           lp["ffn"]["w_down"])
            return x, (k_c, v_c)

        x, (ks, vs) = jax.lax.scan(
            layer_fn, x, (params["dec_layers"], state["k"], state["v"],
                          state["xk"], state["xv"]))
        x = rms_norm(x[:, 0], params["ln_dec"], cfg.norm_eps)
        new_state = {**state, "k": ks, "v": vs, "lengths": lengths + 1}
        return self._logits(params, x), new_state

    def decode_state_specs(self, batch, max_len):
        cfg = self.cfg
        L, hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        kv = PSpec((L, batch, max_len, hkv, hd),
                   ("layers", "batch", "kv_seq", None, None), init="zeros")
        xkv = PSpec((L, batch, cfg.enc_len, hkv, hd),
                    ("layers", "batch", None, "kv_heads", None), init="zeros")
        return {"k": kv, "v": kv, "xk": xkv, "xv": xkv,
                "lengths": _int_spec((batch,), ("batch",))}

    def _add_aux_specs(self, out, shape):
        cfg = self.cfg
        if shape.kind != KIND_DECODE:
            out["frames"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.enc_len, cfg.d_model), jnp.bfloat16)


# ===========================================================================
# factory
# ===========================================================================
FAMILIES = {
    FAMILY_DECODER: DecoderModel,
    FAMILY_MOE: DecoderModel,
    FAMILY_VLM: VLMModel,
    FAMILY_HYBRID: HybridModel,
    FAMILY_RWKV: RWKVModel,
    FAMILY_ENCDEC: EncDecModel,
}


def build_model(cfg: ModelConfig, shd=NOSHARD,
                aligned_decode: bool = False,
                scan_unroll: int = 1,
                kv_dtype: str = "bfloat16") -> Model:
    return FAMILIES[cfg.family](cfg, shd, aligned_decode=aligned_decode,
                                scan_unroll=scan_unroll, kv_dtype=kv_dtype)
