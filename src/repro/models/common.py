"""Shared model-building blocks: param-spec trees, norms, RoPE, MLPs.

Params are plain nested dicts of jnp arrays.  Every parameter is declared
once as a ``PSpec`` (shape + logical axes + init); the same declaration
materializes the weights, produces the ``PartitionSpec`` tree for pjit,
and yields ``ShapeDtypeStruct`` trees for the dry-run — so sharding can
never drift out of sync with the parameter structure.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Parameter declaration
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PSpec:
    """Declarative parameter: shape, logical axis names, init scale."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"            # normal | zeros | ones | small
    scale: float = 0.02
    dtype: Optional[str] = None     # None -> the tree's default dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


ParamTree = Any     # nested dict of PSpec / jnp arrays


def stack_specs(tree: ParamTree, n: int) -> ParamTree:
    """Add a leading 'layers' axis to every PSpec (for lax.scan stacks)."""
    def f(p: PSpec) -> PSpec:
        return PSpec((n,) + p.shape, ("layers",) + p.axes, p.init, p.scale,
                     p.dtype)
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, PSpec))


def _dt(p: PSpec, default):
    return jnp.dtype(p.dtype) if p.dtype else default


def materialize(tree: ParamTree, rng: jax.Array, dtype=jnp.bfloat16) -> ParamTree:
    leaves, treedef = jax.tree.flatten(
        tree, is_leaf=lambda x: isinstance(x, PSpec))
    keys = jax.random.split(rng, len(leaves))
    out = []
    for k, p in zip(keys, leaves):
        dt = _dt(p, dtype)
        if p.init == "zeros":
            out.append(jnp.zeros(p.shape, dt))
        elif p.init == "ones":
            out.append(jnp.ones(p.shape, dt))
        else:
            scale = p.scale if p.init == "normal" else p.scale * 0.1
            out.append((jax.random.normal(k, p.shape, jnp.float32)
                        * scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def abstract(tree: ParamTree, dtype=jnp.bfloat16) -> ParamTree:
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, _dt(p, dtype)),
        tree, is_leaf=lambda x: isinstance(x, PSpec))


# ---------------------------------------------------------------------------
# Sharding hook: models call shd(x, *logical_axes) on activations.
# distributed/sharding.py supplies a real implementation; default no-op.
# ---------------------------------------------------------------------------
class NoSharding:
    def __call__(self, x, *axes):
        return x

    def embed_lookup(self, emb, tokens):
        return emb[tokens]

    def dp_size(self) -> int:
        return 1


NOSHARD = NoSharding()


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * gamma


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(dt) * gamma + beta


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array, shd=NOSHARD) -> jax.Array:
    g = shd(jnp.einsum("...d,df->...f", x, w_gate), "batch", "seq", "mlp")
    u = shd(jnp.einsum("...d,df->...f", x, w_up), "batch", "seq", "mlp")
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def gelu_mlp(x: jax.Array, w_in: jax.Array, b_in: jax.Array,
             w_out: jax.Array, b_out: jax.Array, shd=NOSHARD) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, w_in) + b_in
    h = shd(jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype),
            "batch", "seq", "mlp")
    return jnp.einsum("...f,fd->...d", h, w_out) + b_out


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., seq, heads, head_dim]; positions broadcastable to [..., seq]."""
    dim = x.shape[-1]
    freqs = rope_freqs(dim, theta)                       # [dim/2]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [..., s, dim/2]
    cos = jnp.cos(angles)[..., None, :]                  # [..., s, 1, dim/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, dim: int) -> jax.Array:
    pos = np.arange(n)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    out = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(out, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Softmax cross-entropy over (possibly vocab-sharded) logits
# ---------------------------------------------------------------------------
def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None,
                  z_loss: float = 1e-4) -> jax.Array:
    """logits [..., V] fp-any, labels [...] int32.  Stable fp32 math; the
    vocab reductions lower to all-reduces under vocab-sharded logits."""
    lg = logits.astype(jnp.float32)
    m = jnp.max(lg, axis=-1, keepdims=True)
    shifted = lg - jax.lax.stop_gradient(m)
    sumexp = jnp.sum(jnp.exp(shifted), axis=-1)
    logz = jnp.log(sumexp)
    gold = jnp.take_along_axis(shifted, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(logz)
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
