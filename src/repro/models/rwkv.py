"""RWKV6 "Finch" block — attention-free time-mix with data-dependent decay.

The hallmark of RWKV6 is the per-channel, per-token decay w_t produced
from the input (here via a low-rank projection).  Training/prefill uses a
chunked linear-attention formulation (GLA-style): within a chunk the
pairwise decay products are computed in factored form; across chunks a
[H, dk, dv] state is carried by lax.scan.  Stability: log-decays are
clamped to [-LOG_CLAMP, -eps] and the chunk is kept small so the factored
exponents stay inside fp32 range (|exponent| <= CHUNK * LOG_CLAMP < 88).

Simplification vs the released model (DESIGN.md §Simplifications): token-
shift mixing coefficients are static per channel (RWKV6's extra LoRA on
the mix coefficients is dropped); the decay LoRA — the architectural
novelty — is kept.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import NOSHARD, PSpec, rms_norm

CHUNK = 16
LOG_CLAMP = 5.0       # CHUNK * LOG_CLAMP = 80 < 88 (fp32 exp range)
DECAY_LORA = 32


def rwkv_pspecs(cfg: ModelConfig) -> Dict[str, PSpec]:
    d, f = cfg.d_model, cfg.d_ff
    h, hd = cfg.n_heads, cfg.hd
    out_scale = 0.02 / math.sqrt(2 * max(cfg.n_layers, 1))
    return {
        # time-mix
        "mu_r": PSpec((d,), ("embed",), init="zeros"),
        "mu_k": PSpec((d,), ("embed",), init="zeros"),
        "mu_v": PSpec((d,), ("embed",), init="zeros"),
        "mu_w": PSpec((d,), ("embed",), init="zeros"),
        "mu_g": PSpec((d,), ("embed",), init="zeros"),
        "w_r": PSpec((d, h, hd), ("embed", "heads", None)),
        "w_k": PSpec((d, h, hd), ("embed", "heads", None)),
        "w_v": PSpec((d, h, hd), ("embed", "heads", None)),
        "w_g": PSpec((d, h, hd), ("embed", "heads", None)),
        "decay_a": PSpec((d, DECAY_LORA), ("embed", None)),
        "decay_b": PSpec((DECAY_LORA, h, hd), (None, "heads", None)),
        "decay_0": PSpec((h, hd), ("heads", None), init="zeros"),
        "bonus_u": PSpec((h, hd), ("heads", None)),
        "ln_x": PSpec((h, hd), ("heads", None), init="ones"),
        "w_o": PSpec((h, hd, d), ("heads", None, "embed"), scale=out_scale),
        # channel-mix
        "cmu_k": PSpec((d,), ("embed",), init="zeros"),
        "cmu_r": PSpec((d,), ("embed",), init="zeros"),
        "cw_k": PSpec((d, f), ("embed", "mlp")),
        "cw_v": PSpec((f, d), ("mlp", "embed"), scale=out_scale),
        "cw_r": PSpec((d, d), ("embed", "embed_out")),
    }


def _shift(x: jax.Array, last: jax.Array | None = None) -> jax.Array:
    """Token shift: x_{t-1} (zeros / carried `last` at t=0). x [B,S,D]."""
    if last is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([last[:, None, :], x[:, :-1]], axis=1)


def _mix(x: jax.Array, xprev: jax.Array, mu: jax.Array) -> jax.Array:
    m = jax.nn.sigmoid(mu.astype(jnp.float32)).astype(x.dtype)
    return x + (xprev - x) * m


def _log_decay(p: Dict, xw: jax.Array) -> jax.Array:
    """Data-dependent log-decay in [-LOG_CLAMP, -1e-4]. xw [...,D] ->
    [..., H, hd] (fp32)."""
    lora = jnp.einsum("...d,dl->...l", xw, p["decay_a"])
    w = jnp.einsum("...l,lhk->...hk", jnp.tanh(lora.astype(jnp.float32)),
                   p["decay_b"].astype(jnp.float32))
    w = p["decay_0"].astype(jnp.float32) + w
    return -jnp.clip(jax.nn.softplus(w) + 1e-4, 1e-4, LOG_CLAMP)


# ---------------------------------------------------------------------------
# chunked WKV (training / prefill)
# ---------------------------------------------------------------------------
def wkv_chunked(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
                u: jax.Array, *, chunk: int = CHUNK,
                init_state: jax.Array | None = None
                ) -> Tuple[jax.Array, jax.Array]:
    """r/k/v [B,S,H,K], logw [B,S,H,K] (<=0 fp32), u [H,K].

    Returns (y [B,S,H,K], final state [B,H,K,K] = sum k (x) v with decay).
    """
    bsz, s, h, dk = r.shape
    chunk = min(chunk, s)
    if s % chunk != 0:
        chunk = s
    nc = s // chunk
    rr = r.reshape(bsz, nc, chunk, h, dk).astype(jnp.float32)
    kk = k.reshape(bsz, nc, chunk, h, dk).astype(jnp.float32)
    vv = v.reshape(bsz, nc, chunk, h, dk).astype(jnp.float32)
    lw = logw.reshape(bsz, nc, chunk, h, dk)

    cw = jnp.cumsum(lw, axis=2)                       # inclusive, <= 0
    cw_prev = cw - lw                                 # exclusive (t-1)
    r_f = rr * jnp.exp(cw_prev)                       # exponent <= 0
    k_f = kk * jnp.exp(-cw)                           # exponent <= C*clamp
    # strictly-lower-triangular pairwise terms
    amat = jnp.einsum("bzihk,bzjhk->bzijh", r_f, k_f)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    amat = jnp.where(mask[None, None, :, :, None], amat, 0.0)
    y_intra = jnp.einsum("bzijh,bzjhe->bzihe", amat, vv)
    # bonus diagonal (u)
    bonus = jnp.einsum("bzihk,hk,bzihk->bzih", rr, u.astype(jnp.float32), kk)
    y_intra = y_intra + bonus[..., None] * vv
    # inter-chunk
    k_end = kk * jnp.exp(cw[:, :, -1:, :, :] - cw)    # exponent <= 0
    states = jnp.einsum("bzjhk,bzjhe->bzhke", k_end, vv)
    chunk_decay = jnp.exp(cw[:, :, -1])               # [b,nc,h,dk]

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[..., None] + st
        return new, carry

    s0 = (jnp.zeros((bsz, h, dk, dk), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final, entering = jax.lax.scan(
        scan_fn, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2, 3)))
    entering = entering.transpose(1, 0, 2, 3, 4)      # [b,nc,h,dk,dv]
    y_inter = jnp.einsum("bzihk,bzhke->bzihe", r_f, entering)
    y = (y_intra + y_inter).reshape(bsz, s, h, dk)
    return y, final


def wkv_step(state: jax.Array, r: jax.Array, k: jax.Array, v: jax.Array,
             logw: jax.Array, u: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """O(1) decode. state [B,H,K,V]; r/k/v [B,H,K]; logw [B,H,K] fp32."""
    sf = state.astype(jnp.float32)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    kv = jnp.einsum("bhk,bhe->bhke", kf, vf)
    y = jnp.einsum("bhk,bhke->bhe", rf, sf + u.astype(jnp.float32)[None, :, :, None] * kv)
    new = sf * jnp.exp(logw)[..., None] + kv
    return new, y


# ---------------------------------------------------------------------------
# full blocks
# ---------------------------------------------------------------------------
def _group_norm(y: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    """Per-head normalization of the wkv output. y [...,H,K]."""
    yf = y.astype(jnp.float32)
    mu = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    return ((yf - mu) * jax.lax.rsqrt(var + eps)) * gamma.astype(jnp.float32)


def time_mix(p: Dict, x: jax.Array, cfg: ModelConfig, *,
             last: jax.Array | None = None, shd=NOSHARD) -> jax.Array:
    bsz, s, d = x.shape
    xprev = _shift(x, last)
    xr = _mix(x, xprev, p["mu_r"])
    xk = _mix(x, xprev, p["mu_k"])
    xv = _mix(x, xprev, p["mu_v"])
    xw = _mix(x, xprev, p["mu_w"])
    xg = _mix(x, xprev, p["mu_g"])
    r = shd(jnp.einsum("bsd,dhk->bshk", xr, p["w_r"]), "batch", "seq", "heads", None)
    k = shd(jnp.einsum("bsd,dhk->bshk", xk, p["w_k"]), "batch", "seq", "heads", None)
    v = shd(jnp.einsum("bsd,dhk->bshk", xv, p["w_v"]), "batch", "seq", "heads", None)
    g = jax.nn.silu(jnp.einsum("bsd,dhk->bshk", xg, p["w_g"])
                    .astype(jnp.float32))
    logw = _log_decay(p, xw)
    y, _ = wkv_chunked(r, k, v, logw, p["bonus_u"])
    y = _group_norm(y, p["ln_x"], cfg.norm_eps) * g
    return jnp.einsum("bshk,hkd->bsd", y.astype(x.dtype), p["w_o"])


def channel_mix(p: Dict, x: jax.Array, *, last: jax.Array | None = None,
                shd=NOSHARD) -> jax.Array:
    xprev = _shift(x, last)
    xk = _mix(x, xprev, p["cmu_k"])
    xr = _mix(x, xprev, p["cmu_r"])
    k = jnp.einsum("bsd,df->bsf", xk, p["cw_k"])
    k = shd(jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype),
            "batch", "seq", "mlp")
    kv = jnp.einsum("bsf,fd->bsd", k, p["cw_v"])
    return jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr, p["cw_r"]).astype(jnp.float32)
    ).astype(x.dtype) * kv


def rwkv_decode_step(p: Dict, xt_tm: jax.Array, xt_cm_in: jax.Array | None,
                     state: Dict, cfg: ModelConfig,
                     shd=NOSHARD) -> Tuple[jax.Array, jax.Array, Dict]:
    """One token through time-mix; returns (tm_out, new_state). The caller
    handles residuals + norms and calls channel-mix separately via
    ``channel_mix_step``."""
    bsz, d = xt_tm.shape
    xprev = state["tm_x"]
    xr = _mix(xt_tm[:, None], xprev[:, None], p["mu_r"])[:, 0]
    xk = _mix(xt_tm[:, None], xprev[:, None], p["mu_k"])[:, 0]
    xv = _mix(xt_tm[:, None], xprev[:, None], p["mu_v"])[:, 0]
    xw = _mix(xt_tm[:, None], xprev[:, None], p["mu_w"])[:, 0]
    xg = _mix(xt_tm[:, None], xprev[:, None], p["mu_g"])[:, 0]
    r = jnp.einsum("bd,dhk->bhk", xr, p["w_r"])
    k = jnp.einsum("bd,dhk->bhk", xk, p["w_k"])
    v = jnp.einsum("bd,dhk->bhk", xv, p["w_v"])
    g = jax.nn.silu(jnp.einsum("bd,dhk->bhk", xg, p["w_g"])
                    .astype(jnp.float32))
    logw = _log_decay(p, xw)
    new_wkv, y = wkv_step(state["wkv"], r, k, v, logw, p["bonus_u"])
    y = _group_norm(y, p["ln_x"], cfg.norm_eps) * g
    out = jnp.einsum("bhk,hkd->bd", y.astype(xt_tm.dtype), p["w_o"])
    new_state = dict(state)
    new_state["wkv"] = new_wkv.astype(state["wkv"].dtype)
    new_state["tm_x"] = xt_tm
    return out, new_state


def channel_mix_step(p: Dict, xt: jax.Array, state: Dict,
                     shd=NOSHARD) -> Tuple[jax.Array, Dict]:
    xprev = state["cm_x"]
    xk = _mix(xt[:, None], xprev[:, None], p["cmu_k"])[:, 0]
    xr = _mix(xt[:, None], xprev[:, None], p["cmu_r"])[:, 0]
    k = jnp.square(jax.nn.relu(
        jnp.einsum("bd,df->bf", xk, p["cw_k"]).astype(jnp.float32)
    )).astype(xt.dtype)
    kv = jnp.einsum("bf,fd->bd", k, p["cw_v"])
    out = jax.nn.sigmoid(
        jnp.einsum("bd,de->be", xr, p["cw_r"]).astype(jnp.float32)
    ).astype(xt.dtype) * kv
    new_state = dict(state)
    new_state["cm_x"] = xt
    return out, new_state


def rwkv_state_pspecs(cfg: ModelConfig, batch: int) -> Dict[str, PSpec]:
    h, hd, d = cfg.n_heads, cfg.hd, cfg.d_model
    return {
        "wkv": PSpec((batch, h, hd, hd), ("batch", "heads", None, None),
                     init="zeros"),
        "tm_x": PSpec((batch, d), ("batch", None), init="zeros"),
        "cm_x": PSpec((batch, d), ("batch", None), init="zeros"),
    }


# ---------------------------------------------------------------------------
# sequential oracle for tests
# ---------------------------------------------------------------------------
def wkv_reference(r, k, v, logw, u):
    bsz, s, h, dk = r.shape
    state = jnp.zeros((bsz, h, dk, dk), jnp.float32)
    ys = []
    for t in range(s):
        state, y = wkv_step(state, r[:, t], k[:, t], v[:, t],
                            logw[:, t], u)
        ys.append(y)
    return jnp.stack(ys, axis=1), state
