from repro.models.model import Model, build_model, FAMILIES
from repro.models.common import PSpec, materialize, abstract, stack_specs, NOSHARD
