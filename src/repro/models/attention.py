"""Attention variants: MHA / GQA / MQA (head-grouped) and MLA (latent).

Prefill/training uses query-chunked exact causal attention (bounded score
memory at 32k+ context, flash-style).  Decode attends one query against a
pre-allocated KV cache with per-request length masks; the cache layout is
chosen for sequence sharding over the ``model`` mesh axis (flash-decoding
style — tiny softmax-stat collectives instead of KV all-gathers, see
DESIGN.md §Decode-sharding).

MLA (paper §II-B / §III-A) caches only ``[c_kv ; k_rope]`` per token —
(d_latent + d_rope) bytes * p — and decodes in the absorbed form, so the
57x memory claim is structural in the cache layout here.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import NOSHARD, PSpec, apply_rope

NEG_INF = -1e30
SCORES_BF16 = False   # set True to store score buffers at bf16 (perf flag)
STATIC_CAUSAL = False  # unroll q-chunks with static growing KV ranges:
                       # true-causal flops (2x less than masked-rectangle)


# ---------------------------------------------------------------------------
# Parameter declarations
# ---------------------------------------------------------------------------
def head_mask(cfg: ModelConfig, dtype=jnp.bfloat16):
    """[layout_q_heads, 1] multiplicative mask zeroing padded heads.
    Layout: per GQA group, real heads first, pads last — preserves the
    q->kv mapping i // layout_q_group."""
    hp, hkv = cfg.layout_q_heads, max(cfg.n_kv_heads, 1)
    if hp == cfg.n_heads:
        return None
    g, gp = cfg.q_group, cfg.layout_q_group
    idx = jnp.arange(hp)
    return ((idx % gp) < g).astype(dtype)[:, None]


def attn_pspecs(cfg: ModelConfig, *, cross: bool = False) -> Dict[str, PSpec]:
    d, hq, hkv, hd = (cfg.d_model, cfg.layout_q_heads,
                      max(cfg.n_kv_heads, 1), cfg.hd)
    scale = 0.02
    out = {
        "wq": PSpec((d, hq, hd), ("embed", "heads", None), scale=scale),
        "wk": PSpec((d, hkv, hd), ("embed", "kv_heads", None), scale=scale),
        "wv": PSpec((d, hkv, hd), ("embed", "kv_heads", None), scale=scale),
        "wo": PSpec((hq, hd, d), ("heads", None, "embed"),
                    scale=scale / math.sqrt(2 * max(cfg.n_layers, 1))),
    }
    if cfg.qkv_bias and not cross:
        out.update(
            bq=PSpec((hq, hd), ("heads", None), init="zeros"),
            bk=PSpec((hkv, hd), ("kv_heads", None), init="zeros"),
            bv=PSpec((hkv, hd), ("kv_heads", None), init="zeros"))
    return out


def mla_pspecs(cfg: ModelConfig) -> Dict[str, PSpec]:
    d, hq, hd = cfg.d_model, cfg.n_heads, cfg.hd
    dl, dr = cfg.d_latent, cfg.d_rope
    return {
        "wq": PSpec((d, hq, hd + dr), ("embed", "heads", None)),
        "w_dkv": PSpec((d, dl), ("embed", "latent")),
        "w_kr": PSpec((d, dr), ("embed", None)),
        "w_uk": PSpec((dl, hq, hd), ("latent", "heads", None)),
        "w_uv": PSpec((dl, hq, hd), ("latent", "heads", None)),
        "wo": PSpec((hq, hd, d), ("heads", None, "embed"),
                    scale=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------
def project_qkv(p: Dict, x: jax.Array, positions: jax.Array,
                cfg: ModelConfig, *, rope: bool = True, shd=NOSHARD):
    """x [B,S,D] -> q [B,S,Hq,hd], k/v [B,S,Hkv,hd] (RoPE applied)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    # q is TP-sharded on heads; k/v inherit sharding from the weights
    # (replicated when h_kv doesn't divide TP — constraining them onto
    # padded shards forces replicate-and-repartition resharding storms).
    q = shd(q, "batch", "seq", "heads", None)
    if rope and cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Exact causal attention, query-chunked (prefill / training)
# ---------------------------------------------------------------------------
def _grouped_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q [B,Sq,Hq,hd], k [B,Sk,Hkv,hd] -> scores [B,Hkv,G,Sq,Sk] fp32."""
    b, sq, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    return s / math.sqrt(hd)


def _grouped_out(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs [B,Hkv,G,Sq,Sk] fp32, v [B,Sk,Hkv,hd] -> [B,Sq,Hq,hd]."""
    b, hkv, g, sq, sk = probs.shape
    o = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return o.reshape(b, sq, hkv * g, -1)


def causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     *, q_offset: int = 0, chunk: int = 512,
                     shd=NOSHARD) -> jax.Array:
    """Exact causal attention with bounded memory: scan over query chunks,
    each chunk softmaxes over the full (masked) key range.

    GQA keys/values are expanded to query heads *once* (a single reshard,
    head-sharded thereafter) — grouping inside the chunk loop would force
    an SPMD reshard per chunk when h_kv doesn't divide the TP degree.
    """
    b, sq, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    if hkv != hq:
        g = hq // hkv
        k = shd(jnp.repeat(k, g, axis=2), "batch", "seq", "heads", None)
        v = shd(jnp.repeat(v, g, axis=2), "batch", "seq", "heads", None)
    chunk = min(chunk, sq)
    if sq % chunk != 0:
        chunk = sq          # irregular smoke shapes: single chunk
    nc = sq // chunk
    kpos = jnp.arange(sk)
    scale = 1.0 / math.sqrt(hd)

    def one_chunk(ci, qc):
        # qc [B, chunk, Hq, hd]
        qpos = q_offset + ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqhd,bkhd->bhqk", qc, k) * scale
        mask = kpos[None, :] <= qpos[:, None]            # [c, Sk]
        if SCORES_BF16:
            # halve score-buffer HBM traffic: buffers live at bf16, the
            # softmax max/sum reductions still run in f32 inside the
            # fused computation
            s = jnp.where(mask[None, None], s.astype(jnp.bfloat16),
                          jnp.bfloat16(NEG_INF))
            p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        else:
            s = jnp.where(mask[None, None], s.astype(jnp.float32),
                          NEG_INF)
            p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)

    if nc == 1:
        return one_chunk(0, q)
    if STATIC_CAUSAL and nc <= 16 and sq == sk and q_offset == 0:
        # unrolled chunks, each attending only k[:, :(ci+1)*chunk] — the
        # strictly-upper rectangle is never computed (true causal cost)
        outs = []
        for ci in range(nc):
            qc = q[:, ci * chunk:(ci + 1) * chunk]
            kend = (ci + 1) * chunk
            kc, vc = k[:, :kend], v[:, :kend]
            s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc) * scale
            qpos = ci * chunk + jnp.arange(chunk)
            mask = jnp.arange(kend)[None, :] <= qpos[:, None]
            if SCORES_BF16:
                s = jnp.where(mask[None, None], s.astype(jnp.bfloat16),
                              jnp.bfloat16(NEG_INF))
                pr = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
            else:
                s = jnp.where(mask[None, None], s.astype(jnp.float32),
                              NEG_INF)
                pr = jax.nn.softmax(s, axis=-1)
            outs.append(jnp.einsum("bhqk,bkhd->bqhd",
                                   pr.astype(vc.dtype), vc))
        return jnp.concatenate(outs, axis=1)
    qs = q.reshape(b, nc, chunk, hq, hd).transpose(1, 0, 2, 3, 4)
    outs = jax.lax.map(lambda args: one_chunk(args[0], args[1]),
                       (jnp.arange(nc), qs))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, hd)


def full_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   kv_mask: Optional[jax.Array] = None) -> jax.Array:
    """Bidirectional (encoder / cross) attention. kv_mask [B,Sk] bool."""
    s = _grouped_scores(q, k)
    if kv_mask is not None:
        s = jnp.where(kv_mask[:, None, None, None, :], s, NEG_INF)
    return _grouped_out(jax.nn.softmax(s, axis=-1), v)


# ---------------------------------------------------------------------------
# Decode: one query vs a length-masked KV cache
# ---------------------------------------------------------------------------
def cache_write(cache: jax.Array, new: jax.Array, lengths: jax.Array,
                *, aligned: bool = False) -> jax.Array:
    """Write one new token per request at its current length.

    cache [B, S, ...], new [B, 1, ...], lengths [B] int32.

    aligned=True: all requests are at the same position (steady-state
    decode benchmark / dry-run) — a single dynamic_update_slice, which
    SPMD-partitions to an in-place shard write.  aligned=False: ragged
    per-request positions via vmapped dus (lowers to scatter; used by the
    live engine — the TPU fast path for ragged batches is the paged
    attention Pallas kernel, kernels/paged_attention.py).
    """
    if aligned:
        idx = (0, lengths[0]) + (0,) * (cache.ndim - 2)
        return jax.lax.dynamic_update_slice(cache, new, idx)

    def upd(c, n, l):
        idx = (l,) + (0,) * (c.ndim - 1)
        return jax.lax.dynamic_update_slice(c, n, idx)
    return jax.vmap(upd)(cache, new, lengths)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, *, shd=NOSHARD) -> jax.Array:
    """q [B,1,Hq,hd]; caches [B,S,Hkv,hd]; lengths [B] = #valid tokens
    (including the newly-written one)."""
    b, _, hq, hd = q.shape
    sk = k_cache.shape[1]
    s = _grouped_scores(q, k_cache)                     # [B,Hkv,G,1,S]
    valid = jnp.arange(sk)[None, :] < lengths[:, None]  # [B,S]
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return _grouped_out(p, v_cache)                     # [B,1,Hq,hd]


# ---------------------------------------------------------------------------
# MLA — latent attention (paper §II-B): cache = [c_kv ; k_rope]
# ---------------------------------------------------------------------------
def mla_project(p: Dict, x: jax.Array, positions: jax.Array,
                cfg: ModelConfig, shd=NOSHARD):
    """Returns q_nope [B,S,H,hd], q_rope [B,S,H,dr], latent [B,S,dl+dr]."""
    dr = cfg.d_rope
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :-dr], q[..., -dr:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = jnp.einsum("bsd,dl->bsl", x, p["w_dkv"])
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["w_kr"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    latent = jnp.concatenate([c_kv, k_rope], axis=-1)   # the cached state
    return q_nope, q_rope, shd(latent, "batch", "seq", None)


def mla_attention_prefill(p: Dict, x: jax.Array, positions: jax.Array,
                          cfg: ModelConfig, *, chunk: int = 512,
                          shd=NOSHARD) -> Tuple[jax.Array, jax.Array]:
    """Naive (non-absorbed) causal MLA for prefill; returns (out, latent)."""
    dl, dr = cfg.d_latent, cfg.d_rope
    q_nope, q_rope, latent = mla_project(p, x, positions, cfg, shd)
    c_kv, k_rope = latent[..., :dl], latent[..., dl:]
    k = jnp.einsum("bsl,lhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsl,lhk->bshk", c_kv, p["w_uv"])
    # fold the shared rope key into per-head keys / queries
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k, jnp.broadcast_to(k_rope[:, :, None, :], k.shape[:3] + (dr,))],
        axis=-1)
    out = causal_attention(q, k, v, chunk=chunk, shd=shd)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return o, latent


def mla_attention_decode(p: Dict, x: jax.Array, latent_cache: jax.Array,
                         lengths: jax.Array, cfg: ModelConfig,
                         shd=NOSHARD, aligned: bool = False
                         ) -> Tuple[jax.Array, jax.Array]:
    """Absorbed-form decode: queries move into latent space, so attention
    reads only the (d_latent + d_rope)-wide cache — the 57x win.

    latent_cache [B, S, dl+dr] must already contain the new token at index
    lengths-1.  Returns (out [B,1,D], new_latent [B,1,dl+dr]).
    """
    dl, dr = cfg.d_latent, cfg.d_rope
    positions = (lengths - 1)[:, None]
    q_nope, q_rope, new_latent = mla_project(p, x, positions, cfg, shd)
    latent_cache = cache_write(latent_cache, new_latent, lengths - 1,
                               aligned=aligned)
    c_kv, k_rope = latent_cache[..., :dl], latent_cache[..., dl:]
    # absorb W_uk into the query:  q_lat [B,1,H,dl]
    q_lat = jnp.einsum("bshk,lhk->bshl", q_nope, p["w_uk"])
    s = (jnp.einsum("bshl,btl->bhst", q_lat, c_kv)
         + jnp.einsum("bshr,btr->bhst", q_rope, k_rope)).astype(jnp.float32)
    s = s / math.sqrt(cfg.hd + dr)
    valid = jnp.arange(latent_cache.shape[1])[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhst,btl->bshl", pr, c_kv)         # [B,1,H,dl]
    out = jnp.einsum("bshl,lhk->bshk", ctx, p["w_uv"])
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return o, latent_cache


# ---------------------------------------------------------------------------
# int8 KV cache (paper §VI: "the sizing formulas accept a precision
# parameter p that can represent quantized formats") — per-token-per-head
# symmetric quantization; scales stored alongside the cache.
# ---------------------------------------------------------------------------
def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x [..., hd] -> (int8 values, f16-ish scale [..., 1])."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.bfloat16) * scale).astype(dtype)
