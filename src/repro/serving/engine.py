"""The serving engine: a unified token-budget step loop mixing chunked
prefill with decode in one batch per step, over a paged block-table KV
cache (dense slots as ``paged=False`` fallback), with the paper's
predictive multi-tier cache manager on the prompt-block level and an
async tier-transfer worker off the step loop.

Per step:
  1. poll the async transfer worker: completed demotions release their
     staging buffers, completed fetches un-park restoring requests;
  2. admit waiting requests into free slots — radix-tree prefix match
     maps pool-resident prefix pages straight into the new request's
     block table (copy-on-write sharing; lower-tier blocks are copied
     from their payloads) and advances the prefill chunk cursor for
     free; the unmatched suffix enters ``Phase.PREFILL``;
  3. budget-select the mixed batch (``Scheduler.plan_step``): every
     decode stream gets one token, prefill chunks fill the rest of
     ``max_step_tokens`` — a 4k-token prompt never stalls running
     decodes;
  4. granted prefill chunks run through the block-table-aware Pallas
     flash-prefill kernels (causal within the chunk, full attention to
     prior pages) and scatter into the pool via
     ``PagedKVCache.write_chunk``; a request whose cursor reaches the
     prompt end transitions PREFILL -> DECODE;
  5. one batched decode over the decoding slots through the Pallas paged
     attention kernels (block-table indirection; MLA uses the absorbed
     latent kernel); sample next tokens;
  6. finished requests release their slot's page references (refcounted;
     manager-pinned prefix pages linger for cross-request reuse);
  7. RoPE prefetch promotions are submitted to the transfer worker
     instead of running inline;
  8. stragglers (per-phase deadline) are preempted: their KV payload is
     handed to the async worker for demotion (double-buffered — an
     immediate restore is served from the staging buffer; after the
     write lands, restore is an async fetch the scheduler waits on
     without blocking decode).

``EngineConfig(chunked_prefill=False)`` (and the dense ``paged=False``
layout, which has no paged pool to chunk into) falls back to the
original monolithic prefill-at-admission for A/B comparison.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import MLA, ModelConfig
from repro.core import sizing
from repro.core.cache_manager import PredictiveCacheManager
from repro.core.tiers import (TPU_V5E_TIER_SPECS, AsyncTierTransferWorker,
                              TierSpec, TransferRequest)
from repro.kernels.backend import resolve_backend
from repro.models.model import build_model
from repro.serving import sampler as sampler_mod
from repro.serving.kvcache import PagedKVCache, SlotKVCache
from repro.serving.request import Phase, Request, SamplingParams
from repro.serving.scheduler import Scheduler, SchedulerConfig


@dataclass
class EngineConfig:
    max_len: int = 512
    kv_budget_bytes: float = float(1 << 30)
    policy: str = "bayesian"
    enable_dedup: bool = True
    enable_prefetch: bool = True
    enable_multi_tier: bool = True
    status_quo_sizing: bool = False
    deadline_s: float = 600.0
    seed: int = 0
    tier_specs: Tuple[TierSpec, ...] = TPU_V5E_TIER_SPECS
    pad_prefill_to: int = 32          # bucket suffix lengths (jit cache)
    paged: bool = True                # block-table KV pool (False: dense A/B)
    page_tokens: int = 64             # physical page size (kernel tile)
    async_transfers: bool = True      # tier moves off the step loop
    chunked_prefill: bool = True      # mixed token-budget batches
    #                                   (False: monolithic prefill A/B)
    prefill_chunk_tokens: int = 64    # kernel chunk size (jit cache)
    max_step_tokens: int = 256        # per-step token budget
    tier0_from_budget: bool = True    # rescale tier-0 capacity to
    #                                   kv_budget_bytes (False: trace replay
    #                                   keeps the pressure capacities of the
    #                                   supplied tier_specs verbatim)
    kernel_backend: Optional[str] = None   # paged-op backend: "pallas" |
    #                                   "interpret" | "xla"; None resolves
    #                                   via kernels/backend.py (the
    #                                   REPRO_KERNEL_BACKEND env var, else
    #                                   pallas on TPU / xla elsewhere)
    segment_reuse: bool = True        # content-segment index: resume
    #                                   pool-resident blocks mid-prompt
    #                                   beyond the contiguous radix prefix
    #                                   (False: monolithic-radix A/B)
    fault_injector: Optional[object] = None   # core/faults.FaultInjector:
    #                                   seeded per-tier fault model threaded
    #                                   through the manager's hierarchy and
    #                                   the transfer worker (None: the fault
    #                                   layer is completely inert)
    retry_policy: Optional[object] = None     # core/faults.RetryPolicy
    #                                   (None + injector -> defaults)
    transfer_timeout_s: float = 30.0  # async transfer wall deadline: a
    #                                   stalled transfer is shed as a failed
    #                                   TransferEvent after this long
    fused_step: bool = True           # decode attention + logits + sampling
    #                                   in ONE jitted closure with the KV
    #                                   state donated through it and the
    #                                   device block-table state cached
    #                                   across steady-state steps (False:
    #                                   per-request sampling dispatches, the
    #                                   pre-PR7 A/B path — greedy decode is
    #                                   token-identical either way)


def _fused_paged_step(model, backend, params, state, tokens, active, rng,
                      temperature, top_k, top_p):
    """One fused decode step over the paged pool: block-table gather,
    paged attention, logits projection and per-row sampling in a single
    jitted program (jitted with ``state`` donated — the KV pools are
    updated in place, never copied).

    ``active`` masks the rows actually decoding this step: masked rows'
    lengths stay put (the model returns +1 for every row), so the
    returned state is exactly next step's input when the decode set is
    unchanged — the engine hands it straight back without a host
    rebuild."""
    logits, new_state = model.decode_step_paged(params, state, tokens,
                                                backend=backend)
    new_state["lengths"] = state["lengths"] + active
    toks = sampler_mod.sample_batched(logits, rng, temperature, top_k,
                                      top_p)
    return toks, new_state


def _fused_dense_step(model, params, state, tokens, rng, temperature,
                      top_k, top_p):
    """Fused decode + sampling for the dense slot layout (lengths keep
    the unfused dense semantics: every row advances)."""
    logits, new_state = model.decode_step(params, state, tokens)
    toks = sampler_mod.sample_batched(logits, rng, temperature, top_k,
                                      top_p)
    return toks, new_state


class ServingEngine:
    def __init__(self, cfg: ModelConfig,
                 engine_cfg: Optional[EngineConfig] = None,
                 params=None, rng: Optional[jax.Array] = None):
        # a fresh EngineConfig per engine: a shared default instance
        # would leak config mutations across engines
        engine_cfg = EngineConfig() if engine_cfg is None else engine_cfg
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.model = build_model(cfg)
        rng = jax.random.PRNGKey(engine_cfg.seed) if rng is None else rng
        self.params = params if params is not None else \
            self.model.init_params(rng)
        self.scheduler = Scheduler(cfg, SchedulerConfig(
            kv_budget_bytes=engine_cfg.kv_budget_bytes,
            max_len=engine_cfg.max_len,
            deadline_s=engine_cfg.deadline_s,
            status_quo_sizing=engine_cfg.status_quo_sizing,
            max_step_tokens=engine_cfg.max_step_tokens))
        self.paged = engine_cfg.paged and self.model.supports_paged_decode()
        # resolved once per engine: the paged attention ops' backend
        # (compiled Pallas on TPU, jitted XLA gathers elsewhere; the env
        # var / config override is validated here, at construction)
        self.kernel_backend = resolve_backend(engine_cfg.kernel_backend)
        self.fused = engine_cfg.fused_step
        if self.paged:
            bt = sizing.block_tokens(cfg)
            if bt % engine_cfg.page_tokens != 0:
                raise ValueError(
                    f"page_tokens {engine_cfg.page_tokens} must divide the "
                    f"manager block size {bt}")
            self.kv = PagedKVCache(self.model, self.scheduler.n_slots,
                                   engine_cfg.max_len,
                                   page_tokens=engine_cfg.page_tokens)
            self._decode = jax.jit(
                functools.partial(self.model.decode_step_paged,
                                  backend=self.kernel_backend),
                donate_argnums=(1,))
            self._fused_decode = jax.jit(
                functools.partial(_fused_paged_step, self.model,
                                  self.kernel_backend),
                donate_argnums=(1,))
        else:
            self.kv = SlotKVCache(self.model, self.scheduler.n_slots,
                                  engine_cfg.max_len)
            self._decode = jax.jit(self.model.decode_step,
                                   donate_argnums=(1,))
            self._fused_decode = jax.jit(
                functools.partial(_fused_dense_step, self.model),
                donate_argnums=(1,))
        # scale tier-0 capacity to the configured budget so eviction and
        # tier demotion actually engage at live-test scale (replay passes
        # tier0_from_budget=False to keep its pressure capacities)
        specs = list(engine_cfg.tier_specs)
        if engine_cfg.tier0_from_budget:
            specs[0] = TierSpec(0, specs[0].name, specs[0].bandwidth,
                                specs[0].latency, specs[0].cost_per_gb_hour,
                                engine_cfg.kv_budget_bytes)
        self.manager = PredictiveCacheManager(
            cfg, specs=tuple(specs), policy=engine_cfg.policy,
            enable_dedup=engine_cfg.enable_dedup,
            enable_prefetch=engine_cfg.enable_prefetch,
            enable_multi_tier=engine_cfg.enable_multi_tier,
            fault_injector=engine_cfg.fault_injector,
            retry_policy=engine_cfg.retry_policy)
        self.worker = (AsyncTierTransferWorker(
            self.manager.hierarchy,
            default_timeout_s=engine_cfg.transfer_timeout_s)
            if engine_cfg.async_transfers else None)
        self.chunked = (engine_cfg.chunked_prefill and self.paged
                        and self.model.supports_chunked_prefill())
        self._rng = jax.random.PRNGKey(engine_cfg.seed + 1)
        self._prefill = jax.jit(self.model.prefill)
        self._prefill_chunk = jax.jit(
            functools.partial(self.model.prefill_chunk,
                              backend=self.kernel_backend))
        # segment reuse needs the chunked paged path: resumed mid-prompt
        # islands are CoW-mapped / injected into the block table and the
        # gaps between them prefill through the position-explicit kernel
        self.seg_enabled = engine_cfg.segment_reuse and self.chunked
        self._prefill_chunk_seg = (jax.jit(
            functools.partial(self.model.prefill_chunk_seg,
                              backend=self.kernel_backend))
            if self.chunked else None)
        # request_id -> [payload | None, length]; payload is the staging
        # buffer — dropped once the async demotion write lands
        self._preempted_payloads: Dict[int, list] = {}
        # request_id -> ticket of the *latest* demote: stale events from
        # an earlier preemption epoch of the same request are ignored
        self._demote_tickets: Dict[int, int] = {}
        self._inflight_prefetch: set = set()
        self._session_tool: Dict[str, Optional[str]] = {}
        # block-registration epoch: _extend_prefix only re-walks the
        # radix tree when new blocks appeared since the request's last
        # match (request_id -> epoch seen)
        self._block_epoch = 0
        self._prefix_checked: Dict[int, int] = {}
        # admission-time agentic transition, reused by mid-prefill
        # prefix accesses so the Bayesian posteriors see the right pair
        self._admit_transition: Dict[int, str] = {}
        self.steps = 0
        self.idle_transfer_waits = 0   # run() iterations with only
        #                                restores in flight (no decode work)
        self.prefill_chunks = 0        # kernel chunk calls
        self.prefill_tokens_total = 0  # prompt tokens through the chunk path
        self.cow_share_hits = 0        # prefix blocks served by CoW page map
        self.inject_hits = 0           # ... by tier payload injection
        self.shared_fetch_hits = 0     # ... imported from the fleet-shared
        #                                tier (content another replica
        #                                published; charged as tier-4 fetch)
        self.segment_share_hits = 0    # mid-prompt blocks resumed via the
        #                                segment index by CoW page map
        self.segment_inject_hits = 0   # ... by tier payload injection
        self.segment_chunks = 0        # position-explicit kernel chunks
        self.last_step_prefill_tokens = 0
        self.max_step_prefill_tokens = 0   # budget-compliance witness

    # ------------------------------------------------------------------
    def bind_fleet_store(self, store, owner: str) -> bool:
        """Bind this replica's tier 4 to the cluster's fleet-shared KV
        store (see ``core/tiers.FleetKVStore``); call before traffic."""
        return self.manager.bind_fleet_store(store, owner)

    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int], *, params: SamplingParams = None,
               session_id: str = None, block_type: str = "user_context",
               tool: str = None, retain_blocks: bool = False,
               block_types: Sequence[str] = None) -> Request:
        req = Request(prompt=list(prompt),
                      params=params or SamplingParams(),
                      session_id=session_id, block_type=block_type,
                      tool=tool, retain_blocks=retain_blocks,
                      block_types=list(block_types) if block_types else None)
        if self.chunked:
            # chunked prefill writes only valid tokens (no pad rounding)
            need = req.prompt_len + req.params.max_new_tokens + 1
        else:
            pad = self.ecfg.pad_prefill_to
            need = ((req.prompt_len + pad - 1) // pad) * pad \
                + req.params.max_new_tokens + 1
        if need > self.ecfg.max_len:
            raise ValueError(
                f"request needs {need} cache slots > max_len "
                f"{self.ecfg.max_len} (prompt {req.prompt_len} + "
                f"max_new {req.params.max_new_tokens})")
        self.scheduler.submit(req)
        return req

    # ------------------------------------------------------------------
    # admission: prefix reuse + suffix prefill
    # ------------------------------------------------------------------
    def _admit(self, req: Request, slot: int) -> None:
        mgr = self.manager
        bt = mgr.block_tokens
        transition = "reasoning_step"
        if req.tool is not None:
            prev = self._session_tool.get(req.session_id)
            transition = mgr.on_tool_switch(prev, req.tool,
                                            kv_bytes=sizing.decode_state_bytes(
                                                self.cfg, req.prompt_len))
            self._session_tool[req.session_id] = req.tool

        # restore a preempted request wholesale (step() guarantees the
        # payload is present — buffer-less restores go through the async
        # fetch path before re-admission)
        if req.request_id in self._preempted_payloads:
            payload, length = self._preempted_payloads.pop(req.request_id)
            self.kv.restore_slot(slot, payload, length)
            self._drop_tier_copy(req.request_id)
            if req.prefill_left > 0:
                # preempted mid-prompt: the restored KV covers the chunk
                # cursor; resume chunked prefill where it left off
                self.scheduler.start_prefill(req, slot)
            else:
                self.scheduler.start(req, slot)
            return

        # prefill covers tokens[:-1]; the first decode step consumes the
        # final token (so prefill logits are never needed and pad
        # positions never produce the sampled token).  ``generated`` is
        # non-empty only on lost-payload recovery, where the whole
        # context is re-prefilled.
        tokens_all = list(req.prompt) + list(req.generated)
        effective = tokens_all[:-1]
        req.prefill_tokens, req.prefill_pos = None, 0
        matched = mgr.match_prefix(effective)
        prefix_len, n_hit = 0, 0
        for bid in matched:
            res = mgr.access(bid, transition=transition)
            if res.recomputed:
                break                      # payload lost -> recompute rest
            if res.hit:
                req.hot_hit_blocks += 1
            if self.paged and self.kv.can_share(bid):
                # pool-resident block: CoW-map its physical pages
                self.kv.share_block(slot, bid, prefix_len)
                self.cow_share_hits += 1
            else:
                pl = mgr._payloads.get(bid)
                if pl is None:
                    break
                self.kv.inject_block(slot, pl, prefix_len)
                self.inject_hits += 1
            prefix_len += bt
            n_hit += 1
        # fleet-shared tier probe: past the local radix match, content
        # ANOTHER replica published extends the prefix via a tier-4
        # fetch + payload injection — paid as a fetch stall, but far
        # cheaper than re-prefilling the blocks (and once imported the
        # blocks are local + hot for the session's next turn)
        if mgr.fleet_bound:
            while prefix_len + bt <= len(effective):
                blk = effective[prefix_len:prefix_len + bt]
                btype = req.block_type
                if req.block_types is not None and \
                        prefix_len // bt < len(req.block_types):
                    btype = req.block_types[prefix_len // bt]
                got = mgr.import_shared_block(
                    blk, block_type=btype,
                    recompute_cost=self._block_recompute_cost(),
                    positions=(prefix_len, prefix_len + bt))
                if got is None:
                    break
                bid, pl = got
                self.kv.inject_block(slot, pl, prefix_len)
                self.shared_fetch_hits += 1
                req.shared_hit_blocks += 1
                prefix_len += bt
                n_hit += 1
        req.prefix_hit_blocks = n_hit

        # segment reuse: beyond the contiguous prefix, the content-
        # segment index finds pool/tier-resident runs of full blocks at
        # matching positions mid-prompt (e.g. a ShareGPT history whose
        # head was truncated away, shifting the surviving turns left by
        # whole blocks).  Each resumed block is a priced ``mgr.access``
        # (tier fetch / posterior update, hot hits counted exactly like
        # prefix hits) and is materialized as a CoW page map or a
        # payload injection; the gaps between resumed spans prefill
        # through the position-explicit segment kernel.
        if self.seg_enabled:
            seg_spans = []
            req.segment_hit_blocks = 0     # lost-payload re-admission
            for seg in mgr.match_segments(effective, start_block=n_hit):
                run_start, run_len = seg.start_block, 0
                for j, bid in enumerate(seg.block_ids):
                    res = mgr.access(bid, transition=transition)
                    ok = not res.recomputed
                    if ok:
                        start = (seg.start_block + j) * bt
                        if self.paged and self.kv.can_share(bid):
                            self.kv.share_block(slot, bid, start)
                            self.segment_share_hits += 1
                        else:
                            pl = mgr._payloads.get(bid)
                            if pl is None:
                                ok = False
                            else:
                                self.kv.inject_block(slot, pl, start)
                                self.segment_inject_hits += 1
                    if ok:
                        if res.hit:
                            req.hot_hit_blocks += 1
                        if run_len == 0:
                            run_start = seg.start_block + j
                        run_len += 1
                        req.segment_hit_blocks += 1
                    else:
                        if run_len:
                            seg_spans.append((run_start, run_len))
                        run_len = 0
                if run_len:
                    seg_spans.append((run_start, run_len))
            req.seg_spans = seg_spans

        if self.chunked:
            # token-budget path: prefix-hit blocks advance the chunk
            # cursor for free; the suffix streams through plan_step()
            req.prefill_tokens = effective
            req.prefill_pos = prefix_len
            self._prefix_checked[req.request_id] = self._block_epoch
            self._admit_transition[req.request_id] = transition
            self.kv.set_length(slot, prefix_len)
            self.scheduler.start_prefill(req, slot)
            if req.seg_spans:
                # a resumed span adjacent to the prefix frontier moves
                # the chunk cursor past it immediately
                self._skip_resumed(req)
            if req.prefill_left == 0:
                self._finish_prefill(req)
            return

        # monolithic fallback (dense layout / --no-chunked A/B): prefill
        # the whole unmatched suffix in one forward
        suffix = list(effective[prefix_len:])
        pad = self.ecfg.pad_prefill_to
        padded_len = max(pad, ((len(suffix) + pad - 1) // pad) * pad)
        toks = jnp.asarray(
            [suffix + [0] * (padded_len - len(suffix))], jnp.int32)
        if prefix_len == 0:
            logits, state1 = self._prefill(self.params, {"tokens": toks})
            self.kv.write_prefill(slot, state1, padded_len)
        else:
            prefix_kv = self.kv.prefix_kv(slot, prefix_len)
            logits, suffix_kv = self.model.prefill_suffix(
                self.params, {"tokens": toks}, prefix_kv, prefix_len)
            state1 = (dict(latent=suffix_kv[0])
                      if self.cfg.attention_variant == MLA
                      else dict(k=suffix_kv[0], v=suffix_kv[1]))
            self.kv.write_range(slot, state1, prefix_len, padded_len)
        # true sequence length (padding tokens are masked by length)
        self.kv.set_length(slot, len(effective))
        self._register_prompt_blocks(req, slot, effective)
        self.scheduler.start(req, slot)

    def _register_prompt_blocks(self, req: Request, slot: int,
                                effective: Sequence[int]) -> None:
        """Register the prompt's full blocks with the cache manager and
        pin their pool pages for cross-request reuse."""
        mgr = self.manager
        bt = mgr.block_tokens
        n_full = (len(effective) // bt) * bt
        new_ids = mgr.register_sequence(
            list(effective[:n_full]), block_type=req.block_type,
            block_types=req.block_types,
            recompute_cost_per_block=self._block_recompute_cost())
        for i, bid in enumerate(new_ids):
            if bid not in mgr._payloads:
                pl = self.kv.extract_block(slot, i * bt, bt)
                mgr._payloads[bid] = pl
                # registration admitted the block metadata-first; give its
                # tier copy the real bytes so demotions checksum and move
                # actual payloads, not placeholders
                mgr.hierarchy.attach_payload(bid, pl)
            if self.paged:
                self.kv.register_block_pages(bid, slot, i * bt, bt)
            if mgr.fleet_bound:
                # publish-on-register: the block (payload included) joins
                # the fleet-shared tier so sibling replicas can import it
                mgr.publish_block(bid)
        req.block_ids = new_ids
        if new_ids:
            self._block_epoch += 1

    # ------------------------------------------------------------------
    # chunked prefill (token-budget mixed batches)
    # ------------------------------------------------------------------
    def _finish_prefill(self, req: Request) -> None:
        """Chunk cursor reached the prompt end: register prompt blocks
        and transition PREFILL -> DECODE."""
        self._register_prompt_blocks(req, req.slot, req.prefill_tokens)
        self._prefix_checked.pop(req.request_id, None)
        self._admit_transition.pop(req.request_id, None)
        self.scheduler.begin_decode(req)

    def _extend_prefix(self, req: Request) -> int:
        """Mid-prefill prefix extension: blocks registered since this
        request was admitted (e.g. by a sibling sharing the same system
        prompt, finished earlier this step) advance the chunk cursor for
        free — zero prompt tokens spent from the step budget."""
        mgr, bt = self.manager, self.manager.block_tokens
        if req.prefill_pos % bt != 0:
            return 0
        if self._prefix_checked.get(req.request_id) == self._block_epoch:
            return 0               # nothing registered since last match
        self._prefix_checked[req.request_id] = self._block_epoch
        transition = self._admit_transition.get(req.request_id,
                                                "reasoning_step")
        matched = mgr.match_prefix(req.prefill_tokens)
        covered = set()
        for (s, n) in req.seg_spans:
            covered.update(range(s, s + n))
        advanced = 0
        for i in range(req.prefill_pos // bt, len(matched)):
            if i in covered:
                # block already resident via a resumed segment (counted
                # at admission) — the prefix walk just steps over it
                req.prefill_pos += bt
                self.kv.set_length(req.slot, req.prefill_pos)
                continue
            bid = matched[i]
            res = mgr.access(bid, transition=transition)
            if res.recomputed:
                break                  # payload lost -> compute the rest
            if res.hit:
                req.hot_hit_blocks += 1
            if self.kv.can_share(bid):
                self.kv.share_block(req.slot, bid, i * bt)
                self.cow_share_hits += 1
            else:
                pl = mgr._payloads.get(bid)
                if pl is None:
                    break
                if req.seg_spans:
                    # a resumed span above may have advanced the mapped
                    # frontier past this block's pages, leaving them
                    # table holes the contiguous allocator would skip
                    pg = self.kv.page
                    self.kv.ensure_pages_at(
                        req.slot,
                        list(range(i * bt // pg, (i * bt + bt) // pg)))
                self.kv.inject_block(req.slot, pl, i * bt)
                self.inject_hits += 1
            req.prefill_pos += bt
            req.prefix_hit_blocks += 1
            advanced += bt
            self.kv.set_length(req.slot, req.prefill_pos)
        return advanced

    def _skip_resumed(self, req: Request) -> None:
        """Jump the chunk cursor over resumed segments that touch it.
        Spans are ascending and never adjacent (a failed or unmatched
        block always separates them), so one pass suffices."""
        bt = self.manager.block_tokens
        for (s, n) in req.seg_spans:
            if s * bt == req.prefill_pos:
                req.prefill_pos = (s + n) * bt
                self.kv.set_length(req.slot, req.prefill_pos)

    def _gap_positions(self, req: Request, n: int) -> list:
        """The next <= ``n`` unfilled prompt positions at/after the
        chunk cursor, skipping resumed spans — ascending, so every
        position below the last one is either already resident or in
        the returned list (the segment kernel's contract)."""
        bt = self.manager.block_tokens
        spans = [(s * bt, (s + k) * bt) for (s, k) in req.seg_spans]
        out = []
        p = req.prefill_pos
        L = len(req.prefill_tokens)
        while len(out) < n and p < L:
            inside = next((e for (s, e) in spans if s <= p < e), None)
            if inside is not None:
                p = inside
                continue
            out.append(p)
            p += 1
        return out

    def _run_seg_chunk(self, req: Request, n_tokens: int) -> int:
        """One position-explicit prefill chunk over the next gap tokens
        (may span several gaps around resumed islands).  Pad positions
        are -1: the kernel masks them out and RoPE sees position 0."""
        C = self.ecfg.prefill_chunk_tokens
        positions = self._gap_positions(req, min(C, n_tokens))
        n = len(positions)
        if n == 0:
            return 0
        toks = req.prefill_tokens
        chunk = [toks[p] for p in positions]
        arr = jnp.asarray([chunk + [0] * (C - n)], jnp.int32)
        cpos = jnp.asarray([positions + [-1] * (C - n)], jnp.int32)
        state1 = self._prefill_chunk_seg(
            self.params, self.kv.chunk_state(req.slot), arr, cpos)
        self.kv.write_chunk_positions(req.slot, state1, positions)
        # every gap below positions[-1] is now filled, so the contiguous
        # frontier advances to just past it (then over any island there)
        req.prefill_pos = positions[-1] + 1
        self.kv.set_length(req.slot, req.prefill_pos)
        self._skip_resumed(req)
        self.prefill_chunks += 1
        self.segment_chunks += 1
        return n

    def _run_prefill_chunks(self, req: Request, n_tokens: int) -> int:
        """Advance ``req``'s chunk cursor by up to ``n_tokens`` prompt
        tokens in fixed-size kernel chunks (prefix-hit blocks at the
        cursor advance it for free); returns budget tokens consumed."""
        C = self.ecfg.prefill_chunk_tokens
        toks = req.prefill_tokens
        done = 0
        self._extend_prefix(req)
        self._skip_resumed(req)
        while done < n_tokens and req.prefill_pos < len(toks):
            if req.seg_spans:
                # resumed islands ahead (or table holes below them):
                # position-explicit chunks over the gap tokens, written
                # through the hole-aware scatter
                n = self._run_seg_chunk(req, n_tokens - done)
                if n == 0:
                    break
                done += n
            else:
                n = min(C, n_tokens - done, len(toks) - req.prefill_pos)
                chunk = list(toks[req.prefill_pos:req.prefill_pos + n])
                arr = jnp.asarray([chunk + [0] * (C - n)], jnp.int32)
                off = jnp.asarray([req.prefill_pos], jnp.int32)
                state1 = self._prefill_chunk(
                    self.params, self.kv.chunk_state(req.slot), arr, off)
                self.kv.write_chunk(req.slot, state1, req.prefill_pos, n)
                req.prefill_pos += n
                done += n
                self.prefill_chunks += 1
            self._extend_prefix(req)
            self._skip_resumed(req)
        if req.prefill_left == 0:
            self._finish_prefill(req)
        return done

    def _block_recompute_cost(self) -> float:
        """Seconds to re-prefill one block on the target chip."""
        flops = 2 * self.cfg.active_param_count() * self.manager.block_tokens
        return flops / 197e12

    # ------------------------------------------------------------------
    # async transfer bookkeeping
    # ------------------------------------------------------------------
    def _drop_tier_copy(self, request_id: int) -> None:
        bid = f"preempt-{request_id}"
        loc = self.manager.hierarchy.locate(bid)
        if loc is not None:
            self.manager.hierarchy[loc].evict(bid)

    def _poll_transfers(self) -> None:
        for ev in self.scheduler.poll_transfers(self.worker):
            req = ev.request
            if req.kind == "demote" and req.tag:
                rid = int(req.tag)
                if self._demote_tickets.get(rid) != req.ticket:
                    continue           # stale epoch: a newer demote (FIFO
                    #                    after this one) owns the tier copy
                self._demote_tickets.pop(rid, None)
                ent = self._preempted_payloads.get(rid)
                if ent is not None and ev.ok:
                    ent[0] = None          # staging buffer released
                elif ent is None:
                    # restored from the buffer before the write landed:
                    # the tier copy is stale
                    self._drop_tier_copy(rid)
            elif req.kind == "fetch" and req.tag:
                rid = int(req.tag)
                ent = self._preempted_payloads.get(rid)
                if ev.ok and ev.payload is not None and ent is not None:
                    ent[0] = ev.payload
                else:
                    # payload lost (exhausted retries, corrupt copy, or
                    # transfer timeout): recovery re-prefills the full
                    # context — the blocked request unblocks instead of
                    # hanging on a dead tier
                    if ent is not None and not ev.ok:
                        self.manager.stats.fetch_recomputes += 1
                    self._preempted_payloads.pop(rid, None)
                self.scheduler.on_transfer_complete(rid)
            elif req.tag == "prefetch":
                self._inflight_prefetch.discard(req.block_id)

    def _begin_async_restore(self, req: Request) -> None:
        bid = f"preempt-{req.request_id}"
        loc = self.manager.hierarchy.locate(bid)
        if loc is None:
            # demoted copy lost entirely: recompute path
            self._preempted_payloads.pop(req.request_id, None)
            slot = self.kv.acquire(req.request_id, req.prompt_len)
            self._admit(req, slot)
            return
        self.scheduler.block_on_transfer(req)
        self.worker.submit(TransferRequest(
            bid, loc, 0, kind="fetch", evict_src=True,
            tag=str(req.request_id)))

    def _submit_prefetch(self, block_ids: Sequence[str],
                         position: int) -> None:
        if self.worker is None:
            self.manager.prefetch_for_position(block_ids, position)
            return
        for bid, loc in self.manager.plan_prefetch(block_ids, position):
            if bid in self._inflight_prefetch:
                continue
            self._inflight_prefetch.add(bid)
            self.worker.submit(TransferRequest(
                bid, loc, 0, kind="custom", tag="prefetch",
                execute=(lambda h, b=bid, l=loc:
                         (self.manager.promote_async(b, l), None))))

    def _submit_prefetch_many(self, items) -> None:
        """Batched prefetch for the fused step: plan every decoding
        request's window under one manager lock, then submit."""
        if not items:
            return
        if self.worker is None:
            for block_ids, position in items:
                self.manager.prefetch_for_position(block_ids, position)
            return
        for bid, loc in self.manager.plan_prefetch_many(items):
            if bid in self._inflight_prefetch:
                continue
            self._inflight_prefetch.add(bid)
            self.worker.submit(TransferRequest(
                bid, loc, 0, kind="custom", tag="prefetch",
                execute=(lambda h, b=bid, l=loc:
                         (self.manager.promote_async(b, l), None))))

    # ------------------------------------------------------------------
    # batched decode: fused (default) and per-request-sampling A/B paths
    # ------------------------------------------------------------------
    def _decode_fused(self, decode_reqs) -> int:
        """One fused jitted call for the whole decode batch — block-table
        gather, paged attention, logits and per-row sampling — with the
        KV state donated through the closure and ONE device->host sync
        for the sampled tokens.  In steady-state decode the device state
        from the previous step is reused verbatim (no table rebuild, no
        upload); any host-side mutation (admission, prefill write, CoW
        copy, release, page-boundary crossing) triggers a rebuild via
        ``PagedKVCache.state_version``."""
        sa = self.scheduler.step_arrays(decode_reqs, self.kv.n_slots)
        self._rng, step_key = jax.random.split(self._rng)
        if self.paged:
            slots = [r.slot for r in decode_reqs]
            state = self.kv.decode_state(slots, reuse=True)
            toks, new_state = self._fused_decode(
                self.params, state, jnp.asarray(sa["tokens"]),
                jnp.asarray(sa["active"]), step_key,
                jnp.asarray(sa["temperature"]), jnp.asarray(sa["top_k"]),
                jnp.asarray(sa["top_p"]))
            self.kv.absorb(new_state, decode_slots=slots)
        else:
            toks, self.kv.state = self._fused_decode(
                self.params, self.kv.state, jnp.asarray(sa["tokens"]),
                step_key, jnp.asarray(sa["temperature"]),
                jnp.asarray(sa["top_k"]), jnp.asarray(sa["top_p"]))
        out = np.asarray(toks)     # single sync point for the step
        now = time.monotonic()
        produced = 0
        prefetch = []
        for req in sorted(decode_reqs, key=lambda r: r.slot):
            req.generated.append(int(out[req.slot]))
            if req.t_first_token is None:
                req.t_first_token = now
            produced += 1
            self.kv.advance(req.slot)
            if req.block_ids:
                prefetch.append((req.block_ids,
                                 self.kv.slots[req.slot].length))
        # RoPE prefetch promotions, planned once per step under one
        # manager lock (async when the transfer worker is on)
        self._submit_prefetch_many(prefetch)
        return produced

    def _decode_unfused(self, decode_reqs) -> int:
        """Pre-PR7 A/B path: one decode dispatch, then one sampling
        dispatch + device sync per request."""
        tokens = np.zeros((self.kv.n_slots,), np.int32)
        for req in decode_reqs:
            last = (req.generated[-1] if req.generated
                    else req.prompt[-1])
            tokens[req.slot] = last
        # advance the stream once per step (per-request sampling keys
        # are split below)
        self._rng, _ = jax.random.split(self._rng)
        if self.paged:
            state = self.kv.decode_state([r.slot for r in decode_reqs])
            logits, new_state = self._decode(self.params, state,
                                             jnp.asarray(tokens))
            self.kv.absorb(new_state)
        else:
            logits, self.kv.state = self._decode(
                self.params, self.kv.state, jnp.asarray(tokens))
        now = time.monotonic()
        produced = 0
        # per-request sampling (params differ per request)
        for req in sorted(decode_reqs, key=lambda r: r.slot):
            slot = req.slot
            self._rng, r = jax.random.split(self._rng)
            tok = sampler_mod.sample(
                logits[slot:slot + 1], r,
                temperature=req.params.temperature,
                top_k=req.params.top_k, top_p=req.params.top_p)
            req.generated.append(int(tok[0]))
            if req.t_first_token is None:
                req.t_first_token = now
            produced += 1
            self.kv.advance(slot)
            # RoPE prefetch hook: promote blocks around the decode
            # position (async when the transfer worker is on)
            if req.block_ids:
                self._submit_prefetch(req.block_ids,
                                      self.kv.slots[slot].length)
        return produced

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine iteration (poll transfers -> admit -> budget-select
        -> prefill chunks -> batched decode -> sample/finish); returns
        #tokens generated."""
        sch = self.scheduler
        # completion events (scheduler polls; engine interprets)
        self._poll_transfers()
        # straggler handling
        for req in sch.check_stragglers():
            self.preempt(req)
        # admission
        for req in sch.admissible(len(self.kv.free_slots())):
            ent = self._preempted_payloads.get(req.request_id)
            if ent is not None and ent[0] is None:
                self._begin_async_restore(req)
                continue
            slot = self.kv.acquire(req.request_id, req.prompt_len)
            self._admit(req, slot)
        if not sch.running:
            return 0
        # budget-select the mixed batch: the decode set is snapshotted
        # *before* prefill runs, so this step's token count is bounded
        # by max_step_tokens even when a chunk finishes a prompt
        decode_reqs, grants = sch.plan_step()
        prefill_tokens = 0
        for req, n in grants:
            prefill_tokens += self._run_prefill_chunks(req, n)
        self.last_step_prefill_tokens = prefill_tokens
        self.max_step_prefill_tokens = max(self.max_step_prefill_tokens,
                                           prefill_tokens)
        self.prefill_tokens_total += prefill_tokens
        produced = 0
        if decode_reqs:
            produced = (self._decode_fused(decode_reqs) if self.fused
                        else self._decode_unfused(decode_reqs))
            # lengths already advanced; sync infos + finish bookkeeping
            for req in decode_reqs:
                if (req.finished()
                        or req.total_len >= self.ecfg.max_len - 1):
                    # retain_blocks (session continuation) balances the
                    # dedup refcount but keeps the blocks registered for
                    # the next turn's prefix match
                    self.manager.release_sequence(
                        req.block_ids, retain=req.retain_blocks)
                    sch.finish(req)
                    self.kv.release(req.slot)
        if self.paged:
            # unpin pages of blocks the manager demoted or dropped
            self.kv.gc_blocks(self.manager)
        self.manager.tick()
        self.manager.age_all()
        self.steps += 1
        return produced

    def preempt(self, req: Request) -> None:
        """Demote a running request's KV into the tier hierarchy —
        asynchronously when the transfer worker is on (the step loop
        never waits on the write)."""
        if req.phase is Phase.PREFILL and req.prefill_pos <= 0:
            # nothing prefilled yet: no KV worth demoting — release the
            # slot and requeue for a fresh prefill
            req.prefill_tokens, req.prefill_pos = None, 0
            req.seg_spans = []
            self.kv.release(req.slot)
            self.scheduler.preempt(req)
            return
        if req.seg_spans and req.prefill_left > 0:
            # the demoted payload covers [0, frontier) only — resumed
            # islands beyond it die with the slot; the restore re-enters
            # chunked prefill without them
            bt = self.manager.block_tokens
            req.seg_spans = [sp for sp in req.seg_spans
                             if (sp[0] + sp[1]) * bt <= req.prefill_pos]
        payload, length = self.kv.evict_slot_to_payload(req.slot)
        self._preempted_payloads[req.request_id] = [payload, length]
        bid = f"preempt-{req.request_id}"
        # drop any previous-epoch tier copy so size accounting matches
        # the new payload (the in-flight old write, if any, is superseded
        # FIFO by the one submitted below)
        self._drop_tier_copy(req.request_id)
        if self.worker is not None:
            ticket = self.worker.submit(TransferRequest(
                bid, 0, 1, kind="demote", payload=payload,
                nbytes=float(payload.nbytes), tag=str(req.request_id)))
            self._demote_tickets[req.request_id] = ticket
        else:
            self.manager.hierarchy[1].write(bid, payload,
                                            nbytes=float(payload.nbytes))
        self.kv.release(req.slot)
        self.scheduler.preempt(req)

    # ------------------------------------------------------------------
    def run(self, max_steps: int = 10_000) -> dict:
        while self.scheduler.has_work() and self.steps < max_steps:
            produced = self.step()
            if (produced == 0 and not self.scheduler.running
                    and self.scheduler.blocked):
                self.idle_transfer_waits += 1
                time.sleep(1e-3)       # idle: only fetches in flight
        return self.stats()

    def recompiles(self) -> dict:
        """Compiled-variant count per jitted step-loop closure (the jit
        cache size).  Steady-state serving must hold every count
        constant — growth means a shape or dtype is leaking into a
        trace (the exact compile storm the fixed-width scatter and the
        reused step buffers exist to prevent); a test gates on this."""
        out = {}
        closures = [("decode", self._decode),
                    ("fused_decode", self._fused_decode),
                    ("prefill", self._prefill),
                    ("prefill_chunk", self._prefill_chunk)]
        if self._prefill_chunk_seg is not None:
            closures.append(("prefill_chunk_seg", self._prefill_chunk_seg))
        for name, fn in closures:
            try:
                out[name] = int(fn._cache_size())
            except Exception:          # jax-version-dependent private API
                out[name] = -1
        return out

    def stats(self) -> dict:
        out = {"scheduler": self.scheduler.stats(),
               "cache": self.manager.metrics(),
               "kernel_backend": self.kernel_backend,
               "steps": self.steps,
               "idle_transfer_waits": self.idle_transfer_waits,
               "paged": self.paged,
               "chunked": self.chunked,
               "fused": self.fused,
               "recompiles": self.recompiles(),
               "prefill_chunks": self.prefill_chunks,
               "prefill_tokens": self.prefill_tokens_total,
               "max_step_prefill_tokens": self.max_step_prefill_tokens,
               "cow_share_hits": self.cow_share_hits,
               "inject_hits": self.inject_hits,
               "shared_fetch_hits": self.shared_fetch_hits,
               "segment_reuse": self.seg_enabled,
               "segment_share_hits": self.segment_share_hits,
               "segment_inject_hits": self.segment_inject_hits,
               "segment_chunks": self.segment_chunks}
        if self.paged:
            out["allocator"] = self.kv.allocator.stats_dict()
            out["decode_state_reuses"] = self.kv.state_reuses
            out["decode_state_rebuilds"] = self.kv.state_rebuilds
        if self.worker is not None:
            out["async_transfers"] = self.worker.stats()
        out["faults"] = self.manager.hierarchy.fault_stats()
        return out

    def cancel_request(self, req: Request) -> bool:
        """Drop one live request from every scheduler queue and release
        its resources (slot pages via ``kv.release``, dedup refs via
        ``release_sequence``, staged preempt payloads) — the frontend's
        drain-deadline shed path.  Returns False when the request is not
        live on this engine (already finished, or another replica's)."""
        sch = self.scheduler
        rid = req.request_id
        found = False
        if rid in sch.running:
            sch.running.pop(rid)
            if req.slot is not None and req.slot >= 0:
                self.kv.release(req.slot)
            found = True
        elif rid in sch.blocked:
            sch.blocked.pop(rid)
            found = True
        elif req in sch.waiting:
            sch.waiting.remove(req)
            found = True
        elif req in sch.preempted:
            sch.preempted.remove(req)
            found = True
        if not found:
            return False
        self.manager.release_sequence(req.block_ids,
                                      retain=req.retain_blocks)
        self._preempted_payloads.pop(rid, None)
        self._demote_tickets.pop(rid, None)
        self._drop_tier_copy(rid)
        req.phase = Phase.DONE
        if self.paged:
            self.kv.gc_blocks(self.manager)
        return True

    def shutdown(self) -> None:
        if self.worker is not None:
            # escalate at the deadline: injected stalls become failed
            # TransferEvents instead of a hung shutdown
            self.worker.drain(timeout=5.0, escalate=True)
            self.worker.close()
            self.worker = None

    def release_resources(self) -> None:
        """Failover teardown: close the transfer worker and release the
        cache manager's block/tier registrations (payload copies, tier
        residency, radix index, dedup store) so a failed replica frees
        its memory instead of leaking it.  ``ManagerStats`` survive for
        fleet-level aggregation."""
        self.shutdown()
        self._preempted_payloads.clear()
        self._demote_tickets.clear()
        self._inflight_prefetch.clear()
        self.manager.release_all()
