"""The serving engine: continuous batching over a slot KV cache, with the
paper's predictive multi-tier cache manager on the prompt-block level.

Per step:
  1. admit waiting requests into free slots — radix-tree prefix match
     fetches reusable KV blocks from whatever tier holds them (hit
     accounting per (block-type, transition)), then prefill runs only on
     the unmatched suffix;
  2. one batched decode_step over all active slots; sample next tokens;
  3. finished requests release their blocks (refcounted; reusable blocks
     linger per predicted reuse probability);
  4. agentic tool switches update the Markov predictor and trigger
     §III-G pre-allocation and head-multiplier hooks;
  5. stragglers are preempted: their slot KV is demoted into the tier
     hierarchy and restored on resume.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import MLA, ModelConfig
from repro.core import sizing
from repro.core.cache_manager import PredictiveCacheManager
from repro.core.tiers import TPU_V5E_TIER_SPECS, TierSpec
from repro.models.model import build_model
from repro.serving import sampler as sampler_mod
from repro.serving.kvcache import SlotKVCache
from repro.serving.request import Phase, Request, SamplingParams
from repro.serving.scheduler import Scheduler, SchedulerConfig


@dataclass
class EngineConfig:
    max_len: int = 512
    kv_budget_bytes: float = float(1 << 30)
    policy: str = "bayesian"
    enable_dedup: bool = True
    enable_prefetch: bool = True
    enable_multi_tier: bool = True
    status_quo_sizing: bool = False
    deadline_s: float = 600.0
    seed: int = 0
    tier_specs: Tuple[TierSpec, ...] = TPU_V5E_TIER_SPECS
    pad_prefill_to: int = 32          # bucket suffix lengths (jit cache)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, engine_cfg: EngineConfig = EngineConfig(),
                 params=None, rng: Optional[jax.Array] = None):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.model = build_model(cfg)
        rng = jax.random.PRNGKey(engine_cfg.seed) if rng is None else rng
        self.params = params if params is not None else \
            self.model.init_params(rng)
        self.scheduler = Scheduler(cfg, SchedulerConfig(
            kv_budget_bytes=engine_cfg.kv_budget_bytes,
            max_len=engine_cfg.max_len,
            deadline_s=engine_cfg.deadline_s,
            status_quo_sizing=engine_cfg.status_quo_sizing))
        self.kv = SlotKVCache(self.model, self.scheduler.n_slots,
                              engine_cfg.max_len)
        # scale tier-0 capacity to the configured budget so eviction and
        # tier demotion actually engage at live-test scale
        specs = list(engine_cfg.tier_specs)
        specs[0] = TierSpec(0, specs[0].name, specs[0].bandwidth,
                            specs[0].latency, specs[0].cost_per_gb_hour,
                            engine_cfg.kv_budget_bytes)
        self.manager = PredictiveCacheManager(
            cfg, specs=tuple(specs), policy=engine_cfg.policy,
            enable_dedup=engine_cfg.enable_dedup,
            enable_prefetch=engine_cfg.enable_prefetch,
            enable_multi_tier=engine_cfg.enable_multi_tier)
        self._rng = jax.random.PRNGKey(engine_cfg.seed + 1)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        self._prefill = jax.jit(self.model.prefill)
        self._preempted_payloads: Dict[int, Tuple[np.ndarray, int]] = {}
        self._session_tool: Dict[str, Optional[str]] = {}
        self.steps = 0

    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int], *, params: SamplingParams = None,
               session_id: str = None, block_type: str = "user_context",
               tool: str = None) -> Request:
        req = Request(prompt=list(prompt),
                      params=params or SamplingParams(),
                      session_id=session_id, block_type=block_type,
                      tool=tool)
        pad = self.ecfg.pad_prefill_to
        need = ((req.prompt_len + pad - 1) // pad) * pad \
            + req.params.max_new_tokens + 1
        if need > self.ecfg.max_len:
            raise ValueError(
                f"request needs {need} cache slots > max_len "
                f"{self.ecfg.max_len} (prompt {req.prompt_len} + "
                f"max_new {req.params.max_new_tokens})")
        self.scheduler.submit(req)
        return req

    # ------------------------------------------------------------------
    # admission: prefix reuse + suffix prefill
    # ------------------------------------------------------------------
    def _admit(self, req: Request, slot: int) -> None:
        mgr = self.manager
        bt = mgr.block_tokens
        transition = "reasoning_step"
        if req.tool is not None:
            prev = self._session_tool.get(req.session_id)
            transition = mgr.on_tool_switch(prev, req.tool,
                                            kv_bytes=sizing.decode_state_bytes(
                                                self.cfg, req.prompt_len))
            self._session_tool[req.session_id] = req.tool

        # restore a preempted request wholesale
        if req.request_id in self._preempted_payloads:
            payload, length = self._preempted_payloads.pop(req.request_id)
            self.kv.restore_slot(slot, payload, length)
            self.scheduler.start(req, slot)
            return

        # prefill covers prompt[:-1]; the first decode step consumes the
        # final prompt token (so prefill logits are never needed and pad
        # positions never produce the sampled token)
        effective = req.prompt[:-1]
        matched = mgr.match_prefix(effective)
        payloads: List[np.ndarray] = []
        for bid in matched:
            res = mgr.access(bid, transition=transition)
            pl = mgr._payloads.get(bid)
            if pl is None or res.recomputed:
                break                      # payload lost -> recompute rest
            payloads.append(pl)
        prefix_len = len(payloads) * bt
        req.prefix_hit_blocks = len(payloads)
        if payloads:
            self.kv.inject_blocks(slot, payloads, bt)

        # prefill the unmatched suffix
        suffix = list(effective[prefix_len:])
        pad = self.ecfg.pad_prefill_to
        padded_len = max(pad, ((len(suffix) + pad - 1) // pad) * pad)
        toks = jnp.asarray(
            [suffix + [0] * (padded_len - len(suffix))], jnp.int32)
        if prefix_len == 0:
            logits, state1 = self._prefill(self.params, {"tokens": toks})
            self.kv.write_prefill(slot, state1, padded_len)
        else:
            prefix_kv = self.kv.prefix_kv(slot, prefix_len)
            logits, suffix_kv = self.model.prefill_suffix(
                self.params, {"tokens": toks}, prefix_kv, prefix_len)
            state1 = (dict(latent=suffix_kv[0])
                      if self.cfg.attention_variant == MLA
                      else dict(k=suffix_kv[0], v=suffix_kv[1]))
            # place suffix KV after the prefix
            if self.cfg.attention_variant == MLA:
                self.kv.state["latent"] = self.kv.state["latent"].at[
                    :, slot, prefix_len:prefix_len + padded_len].set(
                    state1["latent"][:, 0])
            else:
                self.kv.state["k"] = self.kv.state["k"].at[
                    :, slot, prefix_len:prefix_len + padded_len].set(
                    state1["k"][:, 0])
                self.kv.state["v"] = self.kv.state["v"].at[
                    :, slot, prefix_len:prefix_len + padded_len].set(
                    state1["v"][:, 0])
        # true sequence length (padding tokens are masked by length)
        self.kv.set_length(slot, len(effective))

        # register this prompt's full blocks with the manager
        n_full = (len(effective) // bt) * bt
        new_ids = mgr.register_sequence(
            list(effective[:n_full]), block_type=req.block_type,
            recompute_cost_per_block=self._block_recompute_cost())
        for i, bid in enumerate(new_ids[len(payloads):], start=len(payloads)):
            mgr._payloads[bid] = self.kv.extract_block(slot, i * bt, bt)
        req.block_ids = new_ids
        self.scheduler.start(req, slot)

    def _block_recompute_cost(self) -> float:
        """Seconds to re-prefill one block on the target chip."""
        flops = 2 * self.cfg.active_param_count() * self.manager.block_tokens
        return flops / 197e12

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine iteration; returns #tokens generated."""
        sch = self.scheduler
        # straggler handling
        for req in sch.check_stragglers():
            self.preempt(req)
        # admission
        for req in sch.admissible(len(self.kv.free_slots())):
            slot = self.kv.acquire(req.request_id, req.prompt_len)
            self._admit(req, slot)
        if not sch.running:
            return 0
        # batched decode over all slots
        tokens = np.zeros((self.kv.n_slots,), np.int32)
        for req in sch.running.values():
            last = (req.generated[-1] if req.generated
                    else req.prompt[-1])
            tokens[req.slot] = last
        self._rng, step_rng = jax.random.split(self._rng)
        logits, self.kv.state = self._decode(
            self.params, self.kv.state, jnp.asarray(tokens))
        produced = 0
        now = time.monotonic()
        by_slot = {r.slot: r for r in sch.running.values()}
        # per-request sampling (params differ per request)
        logits_np = None
        for slot, req in sorted(by_slot.items()):
            self._rng, r = jax.random.split(self._rng)
            tok = sampler_mod.sample(
                logits[slot:slot + 1], r,
                temperature=req.params.temperature,
                top_k=req.params.top_k, top_p=req.params.top_p)
            req.generated.append(int(tok[0]))
            if req.t_first_token is None:
                req.t_first_token = now
            produced += 1
            self.kv.slots[slot].length += 1
            # RoPE prefetch hook: promote blocks around the decode position
            if req.block_ids:
                self.manager.prefetch_for_position(
                    req.block_ids, self.kv.slots[slot].length)
        # lengths already advanced inside decode_step state; sync infos
        for slot, req in by_slot.items():
            if req.finished() or req.total_len >= self.ecfg.max_len - 1:
                self.manager.release_sequence(req.block_ids)
                sch.finish(req)
                self.kv.release(req.slot)
        self.manager.tick()
        self.manager.age_all()
        self.steps += 1
        return produced

    def preempt(self, req: Request) -> None:
        """Demote a running request's KV into the tier hierarchy."""
        payload, length = self.kv.evict_slot_to_payload(req.slot)
        self._preempted_payloads[req.request_id] = (payload, length)
        # account the demotion as tier-1 writes
        self.manager.hierarchy[1].write(
            f"preempt-{req.request_id}", payload,
            nbytes=float(payload.nbytes))
        self.kv.release(req.slot)
        self.scheduler.preempt(req)

    # ------------------------------------------------------------------
    def run(self, max_steps: int = 10_000) -> dict:
        while self.scheduler.has_work() and self.steps < max_steps:
            self.step()
        return self.stats()

    def stats(self) -> dict:
        return {"scheduler": self.scheduler.stats(),
                "cache": self.manager.metrics(),
                "steps": self.steps}
