from repro.serving.engine import ServingEngine, EngineConfig
from repro.serving.request import Request, SamplingParams, Phase
from repro.serving.scheduler import Scheduler, SchedulerConfig
