from repro.serving.block_allocator import AllocatorStats, BlockAllocator
from repro.serving.cluster import (LeastLoadedRouter, ReplicaCluster,
                                   RoundRobinRouter, RoutingPolicy,
                                   SessionAffinityRouter, make_router)
from repro.serving.engine import ServingEngine, EngineConfig
from repro.serving.frontend import (AdmissionSnapshot, ServingFrontend,
                                    SLOConfig, StreamHandle, VirtualClock,
                                    admission_decision, projected_ttft_s)
from repro.serving.kvcache import PagedKVCache, SlotKVCache
from repro.serving.request import Request, SamplingParams, Phase
from repro.serving.scheduler import Scheduler, SchedulerConfig
