"""Continuous-batching scheduler with sizing-engine admission, a
per-step token budget (Sarathi-style mixed batches), preemption and
straggler mitigation.

Admission control uses the paper's architecture-aware sizing engine
(§III-A): the decode slot count is B_s* = floor(M_target / (L * B(n_max)))
— an MLA model gets ~7x the slots of its MHA-equivalent sizing on the
same budget, which is where the paper's throughput claim comes from.

Each step's work is budget-selected (``plan_step``): every running
decode stream contributes one token, then prefill chunks from
``Phase.PREFILL`` requests (per-request chunk cursors) fill whatever is
left of ``max_step_tokens`` in admission order — decode is never
starved by a long prompt, and no step prefills more prompt tokens than
the budget allows.

Straggler mitigation: requests that exceed ``deadline_s`` *in their
current phase* are preempted (KV demoted to lower tiers) and re-queued
at the head; ``phase_start`` resets on every (re)admission, so a
preempted-then-readmitted request gets a fresh deadline instead of
instantly re-tripping it.  The cluster-level dispatcher
(launch/serve.py) additionally re-dispatches to a backup replica.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.config import ModelConfig
from repro.core import sizing
from repro.serving.request import Phase, Request


@dataclass
class SchedulerConfig:
    kv_budget_bytes: float = 1 << 30        # live-engine KV budget
    max_len: int = 512
    max_slots: int = 64
    deadline_s: float = 60.0
    status_quo_sizing: bool = False         # ablation: MHA-equivalent
    max_step_tokens: int = 256              # per-step token budget


class Scheduler:
    def __init__(self, cfg: ModelConfig, sched: SchedulerConfig):
        self.cfg = cfg
        self.sched = sched
        if sched.status_quo_sizing:
            n = sizing.status_quo_max_batch(cfg, sched.kv_budget_bytes,
                                            sched.max_len, tp=1)
        else:
            n = sizing.max_batch(cfg, sched.kv_budget_bytes, sched.max_len)
        self.n_slots = max(1, min(sched.max_slots, n))
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}
        self.preempted: Deque[Request] = deque()
        self.blocked: Dict[int, Request] = {}   # awaiting an async KV fetch
        self.done: List[Request] = []
        self.stragglers = 0
        self.transfer_events = 0
        self.async_restores = 0
        self._step_bufs: Optional[Dict[str, np.ndarray]] = None

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.phase = Phase.WAITING
        req.phase_start = time.monotonic()
        self.waiting.append(req)

    def admissible(self, free_slots: int) -> List[Request]:
        """Next requests to admit (preempted ones first)."""
        out: List[Request] = []
        while free_slots > 0 and (self.preempted or self.waiting):
            q = self.preempted or self.waiting
            out.append(q.popleft())
            free_slots -= 1
        return out

    def start(self, req: Request, slot: int) -> None:
        req.phase = Phase.DECODE
        req.phase_start = time.monotonic()
        req.slot = slot
        self.running[req.request_id] = req

    def start_prefill(self, req: Request, slot: int) -> None:
        """Admit into the chunked-prefill phase: the request holds a
        slot and consumes budget via ``plan_step`` until its chunk
        cursor reaches the prompt end."""
        req.phase = Phase.PREFILL
        req.phase_start = time.monotonic()
        req.slot = slot
        self.running[req.request_id] = req

    def begin_decode(self, req: Request) -> None:
        """PREFILL -> DECODE transition (cursor reached the prompt end)."""
        req.phase = Phase.DECODE
        req.phase_start = time.monotonic()

    # ------------------------------------------------------------------
    # token-budget step planning (the mixed batch)
    # ------------------------------------------------------------------
    def plan_step(self) -> Tuple[List[Request], List[Tuple[Request, int]]]:
        """Select this step's work: (decode requests, prefill grants).

        Every ``Phase.DECODE`` request decodes one token — decode is
        never starved.  The remaining budget is granted to
        ``Phase.PREFILL`` requests in admission order as
        ``(request, n_tokens)`` pairs (the engine splits a grant into
        fixed-size kernel chunks).  Total per-step prompt tokens never
        exceed ``max_step_tokens``.
        """
        decode = [r for r in self.running.values()
                  if r.phase is Phase.DECODE]
        budget = self.sched.max_step_tokens - len(decode)
        grants: List[Tuple[Request, int]] = []
        for r in self.running.values():
            if r.phase is not Phase.PREFILL:
                continue
            if budget <= 0:
                break
            n = min(r.prefill_left, budget)
            if n > 0:
                grants.append((r, n))
                budget -= n
        return decode, grants

    def step_arrays(self, decode_reqs: List[Request],
                    n_slots: int) -> Dict[str, np.ndarray]:
        """Per-slot input tensors for the fused step closure — last
        token, active mask, and sampling params — pre-built host-side in
        ONE pass over the decode set.  The buffers are allocated once
        and reused every step (the closure's input shapes depend only on
        ``n_slots``, so the jit cache sees one signature)."""
        bufs = self._step_bufs
        if bufs is None or len(bufs["tokens"]) != n_slots:
            bufs = self._step_bufs = {
                "tokens": np.zeros((n_slots,), np.int32),
                "active": np.zeros((n_slots,), np.int32),
                "temperature": np.zeros((n_slots,), np.float32),
                "top_k": np.zeros((n_slots,), np.int32),
                "top_p": np.ones((n_slots,), np.float32),
            }
        bufs["tokens"][:] = 0
        bufs["active"][:] = 0
        bufs["temperature"][:] = 0.0
        bufs["top_k"][:] = 0
        bufs["top_p"][:] = 1.0
        for r in decode_reqs:
            s = r.slot
            bufs["tokens"][s] = (r.generated[-1] if r.generated
                                 else r.prompt[-1])
            bufs["active"][s] = 1
            bufs["temperature"][s] = r.params.temperature
            bufs["top_k"][s] = r.params.top_k
            bufs["top_p"][s] = r.params.top_p
        return bufs

    def finish(self, req: Request) -> None:
        req.phase = Phase.DONE
        req.t_done = time.monotonic()
        self.running.pop(req.request_id, None)
        self.done.append(req)

    def preempt(self, req: Request) -> None:
        req.phase = Phase.PREEMPTED
        req.phase_start = time.monotonic()
        self.running.pop(req.request_id, None)
        self.preempted.appendleft(req)

    # ------------------------------------------------------------------
    # async tier transfers (core/tiers.AsyncTierTransferWorker)
    # ------------------------------------------------------------------
    def poll_transfers(self, worker) -> list:
        """Drain the transfer worker's completion events (the engine
        interprets them; the scheduler only accounts and unblocks)."""
        if worker is None:
            return []
        events = worker.poll()
        self.transfer_events += len(events)
        return events

    def block_on_transfer(self, req: Request) -> None:
        """Park a request until its KV fetch from a lower tier lands."""
        req.phase = Phase.RESTORING
        self.blocked[req.request_id] = req
        self.async_restores += 1

    def on_transfer_complete(self, request_id: int) -> Optional[Request]:
        """Un-park a request whose restore fetch completed; it re-enters
        the admission queue at the head."""
        req = self.blocked.pop(request_id, None)
        if req is not None:
            req.phase = Phase.PREEMPTED
            self.preempted.appendleft(req)
        return req

    def check_stragglers(self, now: Optional[float] = None) -> List[Request]:
        """Requests over their deadline *in the current phase* ->
        candidates for preempt + re-dispatch.  Measured from
        ``phase_start`` (reset on every (re)admission), not ``arrival``
        — otherwise a preempted-then-readmitted request instantly
        exceeds the deadline again and livelocks."""
        now = time.monotonic() if now is None else now
        out = [r for r in self.running.values()
               if now - r.phase_start > self.sched.deadline_s]
        self.stragglers += len(out)
        return out

    def drain_requests(self) -> List[Request]:
        """Remove and return every live request — waiting, running,
        preempted AND transfer-blocked — in a deterministic order
        (failover requeue hook for the cluster dispatcher; finished
        requests stay in ``done``)."""
        out: List[Request] = list(self.waiting)
        out.extend(self.running[rid] for rid in sorted(self.running))
        out.extend(self.preempted)
        out.extend(self.blocked[rid] for rid in sorted(self.blocked))
        self.waiting.clear()
        self.running.clear()
        self.preempted.clear()
        self.blocked.clear()
        return out

    def has_work(self) -> bool:
        return bool(self.waiting or self.running or self.preempted
                    or self.blocked)

    def live_count(self) -> int:
        """Live (unfinished) requests across every queue — the load
        signal for least-loaded routing and failover victim choice."""
        return (len(self.waiting) + len(self.running)
                + len(self.preempted) + len(self.blocked))

    def session_stats(self) -> Dict[str, dict]:
        """Per-session rollup over finished requests (the trace replay's
        multi-turn sessions map one request per turn)."""
        out: Dict[str, dict] = {}
        for r in self.done:
            sid = r.session_id or f"req{r.request_id}"
            s = out.setdefault(sid, {"turns": 0, "prefix_hit_blocks": 0,
                                     "generated_tokens": 0,
                                     "prompt_tokens": 0})
            s["turns"] += 1
            s["prefix_hit_blocks"] += r.prefix_hit_blocks
            s["generated_tokens"] += len(r.generated)
            s["prompt_tokens"] += r.prompt_len
        return out

    def stats(self) -> dict:
        ttfts = sorted(r.ttft for r in self.done if r.ttft is not None)

        def pct(p):
            if not ttfts:
                return 0.0
            return ttfts[min(len(ttfts) - 1, int(p * len(ttfts)))]

        total_tokens = sum(len(r.generated) for r in self.done)
        return {"done": len(self.done), "slots": self.n_slots,
                "ttft_p50": pct(0.50), "ttft_p99": pct(0.99),
                "generated_tokens": total_tokens,
                "stragglers": self.stragglers,
                "transfer_events": self.transfer_events,
                "async_restores": self.async_restores,
                "prefix_hit_blocks": sum(r.prefix_hit_blocks
                                         for r in self.done),
                "hot_hit_blocks": sum(r.hot_hit_blocks for r in self.done)}
