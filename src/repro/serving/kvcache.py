"""Slot-based KV cache for the live engine + block payload conversion.

The live (CPU/TPU-host) engine decodes from a contiguous per-slot cache
(the Model decode API); the paper's multi-tier block machinery operates on
*prompt-prefix blocks*: after prefill, each 128-token block of a prompt's
KV state is registered with the PredictiveCacheManager (payload = host
numpy), enabling cross-request prefix reuse, preemption/restore and tier
demotion.  On TPU the ragged decode fast path is the paged-attention
Pallas kernel (kernels/paged_attention.py); block tables map 1:1 onto
this block layout.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import MLA, ModelConfig
from repro.models.model import Model


@dataclass
class SlotInfo:
    request_id: int = -1
    length: int = 0
    active: bool = False


class SlotKVCache:
    """Fixed decode slots over the model's contiguous DecodeState."""

    def __init__(self, model: Model, n_slots: int, max_len: int):
        self.model = model
        self.cfg = model.cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.state = model.init_decode_state(n_slots, max_len)
        self.slots = [SlotInfo() for _ in range(n_slots)]

    # ------------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.active]

    def acquire(self, request_id: int, length: int) -> int:
        for i, s in enumerate(self.slots):
            if not s.active:
                self.slots[i] = SlotInfo(request_id, length, True)
                return i
        raise RuntimeError("no free slot")

    def release(self, slot: int) -> None:
        self.slots[slot] = SlotInfo()
        self.state["lengths"] = self.state["lengths"].at[slot].set(0)

    def set_length(self, slot: int, length: int) -> None:
        self.slots[slot].length = length
        self.state["lengths"] = self.state["lengths"].at[slot].set(length)

    # ------------------------------------------------------------------
    # moving KV between the slot cache and block payloads (numpy)
    # ------------------------------------------------------------------
    def write_prefill(self, slot: int, state1: Dict, length: int) -> None:
        """Copy a batch-1 prefill state into slot `slot`."""
        if self.cfg.attention_variant == MLA:
            self.state["latent"] = self.state["latent"].at[
                :, slot, :length].set(state1["latent"][:, 0, :length])
        else:
            self.state["k"] = self.state["k"].at[:, slot, :length].set(
                state1["k"][:, 0, :length])
            self.state["v"] = self.state["v"].at[:, slot, :length].set(
                state1["v"][:, 0, :length])
        self.set_length(slot, length)

    def extract_block(self, slot: int, start: int, n_tokens: int) -> np.ndarray:
        """Slot KV -> block payload [2, L, n_tokens, H, hd] (or MLA
        [1, L, n_tokens, dl+dr])."""
        if self.cfg.attention_variant == MLA:
            lat = self.state["latent"][:, slot, start:start + n_tokens]
            return np.asarray(lat)[None]
        k = np.asarray(self.state["k"][:, slot, start:start + n_tokens])
        v = np.asarray(self.state["v"][:, slot, start:start + n_tokens])
        return np.stack([k, v])

    def inject_blocks(self, slot: int, payloads: Sequence[np.ndarray],
                      block_tokens: int) -> int:
        """Write reused prefix blocks into a slot; returns prefix length."""
        pos = 0
        for pl in payloads:
            n = pl.shape[2]
            if self.cfg.attention_variant == MLA:
                self.state["latent"] = self.state["latent"].at[
                    :, slot, pos:pos + n].set(jnp.asarray(pl[0]))
            else:
                self.state["k"] = self.state["k"].at[
                    :, slot, pos:pos + n].set(jnp.asarray(pl[0]))
                self.state["v"] = self.state["v"].at[
                    :, slot, pos:pos + n].set(jnp.asarray(pl[1]))
            pos += n
        return pos

    def prefix_kv(self, slot: int, length: int):
        """Cached prefix (k, v) for suffix-prefill, batch dim restored."""
        if self.cfg.attention_variant == MLA:
            return (self.state["latent"][:, slot:slot + 1, :length],)
        return (self.state["k"][:, slot:slot + 1, :length],
                self.state["v"][:, slot:slot + 1, :length])

    # ------------------------------------------------------------------
    def evict_slot_to_payload(self, slot: int) -> Tuple[np.ndarray, int]:
        """Preemption: extract the whole slot state for tier demotion."""
        length = self.slots[slot].length
        payload = self.extract_block(slot, 0, length)
        return payload, length

    def restore_slot(self, slot: int, payload: np.ndarray,
                     length: int) -> None:
        self.inject_blocks(slot, [payload], length)
        self.set_length(slot, length)
